"""Export a calibration text set for ``samp plan`` (JSONL, one text per line).

The Rust planner measures per-layer quantization sensitivity by running a
calibration set through the native backend.  It accepts any JSONL file with
``{"text": ..., "label": ...}`` rows; this script renders one from the
deterministic ``calib`` split of a synthetic task (:mod:`compile.data`), so
the calibration distribution matches the dev distribution without touching
the dev set itself.

numpy-only — usable in environments without jax.

Usage::

    python -m compile.export_calib --task tnews \
        --out artifacts/data/tnews_calib.jsonl [--n 64] [--seed-base 1234]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from .data import TASKS, generate, render_text


def export(task: str, out_path: str, n: int, seed_base: int = 1234) -> int:
    """Write ``n`` calibration texts for ``task``; returns rows written."""
    if task not in TASKS:
        raise ValueError(f"unknown task `{task}` (have {sorted(TASKS)})")
    ids, _segs, _mask, labels = generate(task, "calib", n, seed_base)
    out_dir = os.path.dirname(out_path)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    rows = 0
    with open(out_path, "w", encoding="utf-8") as fh:
        for row, label in zip(ids, labels):
            text = render_text(row)
            if not text:
                continue
            label_value = (label.tolist() if getattr(label, "ndim", 0)
                           else int(label))
            fh.write(json.dumps({"text": text, "label": label_value},
                                ensure_ascii=False) + "\n")
            rows += 1
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--task", required=True, help=f"one of {sorted(TASKS)}")
    ap.add_argument("--out", required=True, help="output .jsonl path")
    ap.add_argument("--n", type=int, default=64,
                    help="number of calibration examples (default 64)")
    ap.add_argument("--seed-base", type=int, default=1234)
    args = ap.parse_args(argv)

    rows = export(args.task, args.out, args.n, args.seed_base)
    print(f"wrote {args.out}: {rows} calibration texts for {args.task}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
