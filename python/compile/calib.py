"""PTQ calibration: activation-range collection + the four TensorRT calibrators.

The paper calibrates with NVIDIA pytorch-quantization (§4.1 footnote 4), which
offers four PTQ calibrators.  We reimplement all four over absolute-value
histograms so users can pick per deployment, exactly as the paper suggests
("Users can select appropriate calibrators to generate scale values"):

  * ``minmax``      — scale = amax / 127.
  * ``percentile``  — scale = (percentile of |x|) / 127 (default 99.9%).
  * ``entropy``     — TensorRT-style KL-divergence minimization between the
                      original distribution and its quantized projection.
  * ``mse``         — sweep candidate clip points, minimize the expected
                      squared quantization error estimated from the histogram.

Collection is two-pass (amax first, then fixed-range histograms) so memory
stays bounded regardless of calibration-set size.
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable

import numpy as np

from .kernels.common import QMAX, amax_to_scale

NUM_BINS = 2048
CALIBRATORS = ("minmax", "percentile", "entropy", "mse")


class HistogramCollector:
    """Two-pass per-tensor |x| statistics: pass 1 amax, pass 2 histogram."""

    def __init__(self, num_bins: int = NUM_BINS):
        self.num_bins = num_bins
        self.amax: Dict[str, float] = {}
        self.hist: Dict[str, np.ndarray] = {}
        self._pass = 1

    def start_histogram_pass(self):
        self._pass = 2

    def add(self, name: str, arr) -> None:
        a = np.abs(np.asarray(arr, dtype=np.float32)).ravel()
        if self._pass == 1:
            m = float(a.max()) if a.size else 0.0
            self.amax[name] = max(self.amax.get(name, 0.0), m)
        else:
            top = self.amax.get(name, 0.0)
            if top <= 0.0:
                return
            h, _ = np.histogram(a, bins=self.num_bins, range=(0.0, top))
            if name in self.hist:
                self.hist[name] += h
            else:
                self.hist[name] = h.astype(np.int64)

    def bin_width(self, name: str) -> float:
        return self.amax[name] / self.num_bins


# ---------------------------------------------------------------------------
# Calibrators: histogram -> symmetric INT8 scale
# ---------------------------------------------------------------------------

def scale_minmax(amax: float, hist=None, bin_width: float = 0.0) -> float:
    return amax_to_scale(amax)


def scale_percentile(amax: float, hist: np.ndarray, bin_width: float,
                     percentile: float = 99.9) -> float:
    if hist is None or hist.sum() == 0:
        return amax_to_scale(amax)
    cdf = np.cumsum(hist) / hist.sum()
    idx = int(np.searchsorted(cdf, percentile / 100.0))
    clip = (idx + 1) * bin_width
    return amax_to_scale(min(clip, amax))


def _kl_divergence(p: np.ndarray, q: np.ndarray) -> float:
    p = p / max(p.sum(), 1e-12)
    q = q / max(q.sum(), 1e-12)
    mask = p > 0
    qm = np.where(q[mask] > 0, q[mask], 1e-12)
    return float(np.sum(p[mask] * np.log(p[mask] / qm)))


def scale_entropy(amax: float, hist: np.ndarray, bin_width: float,
                  start_bin: int = 128, stride: int = 16) -> float:
    """TensorRT's KL calibrator: pick the clip that minimizes KL(P || Q_quant).

    For every candidate clip point i, the first i bins are requantized into
    128 levels (the non-negative half of the symmetric range) and the tail
    mass is folded into the last bin; the clip with minimal divergence wins.
    """
    if hist is None or hist.sum() == 0:
        return amax_to_scale(amax)
    n = len(hist)
    best_div, best_i = float("inf"), n
    for i in range(start_bin, n + 1, stride):
        p = hist[:i].astype(np.float64).copy()
        p[i - 1] += hist[i:].sum()                      # fold clipped tail
        # project onto 128 quantization levels
        chunk = i / 128.0
        q = np.zeros(i)
        edges = (np.arange(i) / chunk).astype(int)
        counts = np.bincount(edges, weights=hist[:i], minlength=128)
        nonzero = np.bincount(edges, weights=(hist[:i] > 0).astype(float),
                              minlength=128)
        level_avg = counts / np.maximum(nonzero, 1)
        q = np.where(hist[:i] > 0, level_avg[edges], 0.0)
        div = _kl_divergence(p, q)
        if div < best_div:
            best_div, best_i = div, i
    clip = best_i * bin_width
    return amax_to_scale(min(clip, amax))


def scale_mse(amax: float, hist: np.ndarray, bin_width: float,
              num_candidates: int = 64) -> float:
    """Pick the clip minimizing E[(x - dequant(quant(x)))^2] over the histogram."""
    if hist is None or hist.sum() == 0:
        return amax_to_scale(amax)
    n = len(hist)
    centers = (np.arange(n) + 0.5) * bin_width
    weights = hist.astype(np.float64)
    best_err, best_clip = float("inf"), amax
    for frac in np.linspace(0.2, 1.0, num_candidates):
        clip = frac * amax
        scale = clip / QMAX
        q = np.clip(np.round(centers / scale), -QMAX, QMAX)
        err = float(np.sum(weights * (centers - q * scale) ** 2))
        if err < best_err:
            best_err, best_clip = err, clip
    return amax_to_scale(best_clip)


_CALIB_FNS: Dict[str, Callable] = {
    "minmax": scale_minmax,
    "percentile": scale_percentile,
    "entropy": scale_entropy,
    "mse": scale_mse,
}


def compute_scales(collector: HistogramCollector,
                   method: str = "minmax") -> Dict[str, float]:
    """Turn collected statistics into per-tensor scales with one calibrator."""
    assert method in _CALIB_FNS, f"unknown calibrator {method}"
    fn = _CALIB_FNS[method]
    out = {}
    for name, amax in collector.amax.items():
        hist = collector.hist.get(name)
        bw = collector.bin_width(name) if name in collector.hist else 0.0
        out[name] = fn(amax, hist, bw)
    return out


def calibrate_model(params, cfg, batches: Iterable, method: str = "minmax",
                    collector: HistogramCollector | None = None):
    """Run the two-pass calibration over ``batches`` of (ids, segs, mask).

    Returns a dict of activation scales keyed by tap name (see model.LAYER_TAPS)
    merged with min-max weight scales.  This is the python mirror of the
    paper's calibration tool flow (Appendix A: "loads the pretrained language
    model weights..., runs the calibration process and dumps the weights").
    """
    import jax

    from .model import ScaleSet, encoder_forward_with_taps

    coll = collector or HistogramCollector()
    fwd = jax.jit(lambda i, s, m: encoder_forward_with_taps(params, cfg, i, s, m)[1])
    cached = [(ids, segs, mask) for ids, segs, mask in batches]
    for ids, segs, mask in cached:                      # pass 1: amax
        taps = fwd(ids, segs, mask)
        for name, arr in taps.items():
            coll.add(name, arr)
    coll.start_histogram_pass()
    for ids, segs, mask in cached:                      # pass 2: histograms
        taps = fwd(ids, segs, mask)
        for name, arr in taps.items():
            coll.add(name, arr)
    scales = compute_scales(coll, method)
    scales.update(ScaleSet.weight_scales(params, cfg.layers))
    return scales
