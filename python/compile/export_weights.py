"""Export encoder weights for the Rust native backend (`SAMPNATW` v1).

The native backend (`rust/src/backend/native/`) runs the full
mixed-precision encoder from a flat binary weights file when no AOT HLO
artifact is present.  This script emits that file from a parameter pytree —
either trained params saved as `.npz` (via ``np.savez(path, **params)``,
the `l{i}/wq`-style keys of :func:`compile.model.init_params`) or freshly
initialized ones.

Format (little-endian, no padding):

    magic    8 bytes  b"SAMPNATW"
    version  u32      1
    geometry u32 x 8  vocab, max_len, type_vocab, hidden, layers, heads,
                      ffn, num_labels
    tensors  f32      fixed order (see rust/src/backend/native/io.rs)

Usage::

    python -m compile.export_weights --out artifacts/tnews.natw \
        [--npz params.npz] [--vocab-size 2048] [--hidden 128] \
        [--layers 12] [--heads 4] [--ffn 512] [--max-len 128] \
        [--num-labels 2] [--seed 0]
"""

from __future__ import annotations

import argparse
import struct
import sys

import numpy as np

MAGIC = b"SAMPNATW"
VERSION = 1

# per-layer tensor order — must match rust/src/backend/native/io.rs
LAYER_TENSORS = ("wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
                 "ln1_g", "ln1_b", "w1", "b1", "w2", "b2", "ln2_g", "ln2_b")


def export(params: dict, cfg, out_path: str) -> int:
    """Serialize a param pytree to `out_path`; returns bytes written."""
    chunks = [MAGIC, struct.pack("<I", VERSION)]
    chunks.append(struct.pack(
        "<8I", cfg.vocab_size, cfg.max_len, cfg.type_vocab, cfg.hidden,
        cfg.layers, cfg.heads, cfg.ffn, cfg.num_labels))

    def push(key: str, shape) -> None:
        t = np.asarray(params[key], dtype=np.float32)
        if t.shape != tuple(shape):
            raise ValueError(f"{key}: shape {t.shape} != expected {shape}")
        chunks.append(t.tobytes(order="C"))

    h, f = cfg.hidden, cfg.ffn
    push("emb/tok", (cfg.vocab_size, h))
    push("emb/seg", (cfg.type_vocab, h))
    push("emb/pos", (cfg.max_len, h))
    push("emb/ln_g", (h,))
    push("emb/ln_b", (h,))
    shapes = {"wq": (h, h), "wk": (h, h), "wv": (h, h), "wo": (h, h),
              "w1": (h, f), "w2": (f, h), "bq": (h,), "bk": (h,),
              "bv": (h,), "bo": (h,), "b1": (f,), "b2": (h,),
              "ln1_g": (h,), "ln1_b": (h,), "ln2_g": (h,), "ln2_b": (h,)}
    for l in range(cfg.layers):
        for nm in LAYER_TENSORS:
            push(f"l{l}/{nm}", shapes[nm])
    push("pool/w", (h, h))
    push("pool/b", (h,))
    push("head/w", (h, cfg.num_labels))
    push("head/b", (cfg.num_labels,))

    blob = b"".join(chunks)
    with open(out_path, "wb") as fh:
        fh.write(blob)
    return len(blob)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", required=True, help="output .natw path")
    ap.add_argument("--npz", help="trained params (np.savez of the pytree)")
    ap.add_argument("--vocab-size", type=int, default=2048)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--ffn", type=int, default=512)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--type-vocab", type=int, default=2)
    ap.add_argument("--num-labels", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # ModelConfig mirrors compile.model; imported lazily because model.py
    # pulls in jax, which an export-only environment may not have
    try:
        from .model import ModelConfig, init_params
    except ImportError:
        from dataclasses import dataclass

        @dataclass(frozen=True)
        class ModelConfig:  # noqa: D401 - minimal stand-in
            vocab_size: int = 2048
            hidden: int = 128
            layers: int = 12
            heads: int = 4
            ffn: int = 512
            max_len: int = 128
            type_vocab: int = 2
            num_labels: int = 2

        init_params = None

    cfg = ModelConfig(
        vocab_size=args.vocab_size, hidden=args.hidden, layers=args.layers,
        heads=args.heads, ffn=args.ffn, max_len=args.max_len,
        type_vocab=args.type_vocab, num_labels=args.num_labels)

    if args.npz:
        params = dict(np.load(args.npz))
    elif init_params is not None:
        params = init_params(cfg, seed=args.seed)
    else:
        print("error: no --npz given and compile.model (jax) unavailable",
              file=sys.stderr)
        return 2

    n = export(params, cfg, args.out)
    print(f"wrote {args.out}: {n} bytes "
          f"(H={cfg.hidden} L={cfg.layers} F={cfg.ffn} "
          f"V={cfg.vocab_size} labels={cfg.num_labels})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
