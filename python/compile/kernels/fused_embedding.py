"""Pallas fused embedding: token+segment+position gather-sum + LayerNorm (+Quant).

The paper's first "advanced fusion strategy" (§3.1, Fig 1): BERT's embedding is
the sum of three table lookups, which FasterTransformer launches as three CUDA
kernels; SAMP fuses them into one, and in Fully-Quant mode also folds in the
encoder-input quantization so the Embedding module hands the encoder INT8
directly (Fig 2a), saving a separate quantize kernel call.

We additionally fold the embedding LayerNorm (BERT applies LN right after the
sum) into the same kernel — one kernel where the baseline launches five
(3 gathers + add + LN), which is exactly the kernel-call-halving arithmetic of
§3.1 applied at the embedding.

Hardware adaptation: each grid step processes one batch row; the three tables
are staged into VMEM whole.  For the model geometries in this repo
(vocab<=4096, H<=256) a table is <= 4 MiB which fits the ~16 MiB VMEM budget;
for BERT-base-scale vocabularies a real TPU kernel would gather via dynamic
slices from HBM instead — the dataflow (one fused kernel, quantized output)
is what the reproduction preserves.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, QMAX, QMIN, vmem_bytes


def _kernel(tok_ref, seg_ref, tok_tab_ref, seg_tab_ref, pos_tab_ref,
            gamma_ref, beta_ref, o_ref, *, out_scale, eps):
    ids = tok_ref[0, :]
    segs = seg_ref[0, :]
    emb = (jnp.take(tok_tab_ref[...], ids, axis=0)
           + jnp.take(seg_tab_ref[...], segs, axis=0)
           + pos_tab_ref[...])
    mean = jnp.mean(emb, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(emb - mean), axis=-1, keepdims=True)
    h = (emb - mean) * jax.lax.rsqrt(var + eps) * gamma_ref[...] + beta_ref[...]
    if out_scale is not None:
        q = jnp.clip(jnp.round(h / out_scale), QMIN, QMAX)
        o_ref[0, :, :] = q.astype(jnp.int8)
    else:
        o_ref[0, :, :] = h


def fused_embedding(token_ids, segment_ids, tok_table, seg_table, pos_table,
                    gamma, beta, out_scale: float | None = None,
                    eps: float = 1e-12):
    """Fused BERT embedding.

    Args:
      token_ids:   int32 [B, S].
      segment_ids: int32 [B, S].
      tok_table:   f32 [V, H]; seg_table: f32 [2, H]; pos_table: f32 [P, H]
                   (P >= S; the first S rows are used).
      gamma, beta: f32 [H] LayerNorm parameters.
      out_scale:   if given, output is int8 [B, S, H] (Fully-Quant encoder
                   input); else f32 [B, S, H].

    Returns: [B, S, H] embedding, LayerNormed, optionally INT8.
    """
    b, s = token_ids.shape
    v, h = tok_table.shape
    pos = pos_table[:s, :]
    out_dtype = jnp.int8 if out_scale is not None else jnp.float32
    kern = functools.partial(
        _kernel,
        out_scale=None if out_scale is None else float(out_scale),
        eps=eps,
    )
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((v, h), lambda i: (0, 0)),
            pl.BlockSpec(seg_table.shape, lambda i: (0, 0)),
            pl.BlockSpec((s, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, s, h), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h), out_dtype),
        interpret=INTERPRET,
    )(token_ids, segment_ids, tok_table, seg_table, pos, gamma, beta)


def vmem_estimate(seq: int, vocab: int, hidden: int, out_int8: bool = True) -> int:
    """VMEM working set (bytes) of one grid step — perf-pass instrumentation."""
    return vmem_bytes(
        ((vocab, hidden), jnp.float32),
        ((2, hidden), jnp.float32),
        ((seq, hidden), jnp.float32),
        ((seq,), jnp.int32), ((seq,), jnp.int32),
        ((hidden,), jnp.float32), ((hidden,), jnp.float32),
        ((seq, hidden), jnp.int8 if out_int8 else jnp.float32),
    )
