"""Pallas INT8 GEMM with INT32 accumulation and fused requantization.

This is the SAMP quantized GEMM (Fig 2): both operands are INT8, the MXU
accumulates in INT32, and the epilogue dequantizes by ``s_x * s_w``, adds the
FP32 bias and optionally requantizes the result so the inter-kernel dataflow
stays 8-bit (the "all green arrows" property of Fully-Quant mode).

Hardware adaptation (DESIGN.md §3): the CUDA version tiles for threadblocks +
DP4A/IMMA tensor cores; here the BlockSpec expresses the same schedule for the
TPU memory hierarchy — (bm, K) x (K, bn) operand blocks resident in VMEM, the
INT8 MXU path giving the 2x-over-bf16 throughput the paper exploits on tensor
cores.  The K dimension is kept whole per block (our model K <= 512, so the
working set is a few hundred KiB — see ``vmem_estimate``).

interpret=True everywhere: the CPU PJRT client cannot run Mosaic custom-calls,
so the kernel body lowers to plain HLO.  Numerics are identical either way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, QMAX, QMIN, pick_block, vmem_bytes

# Default MXU-friendly tile targets.  128 matches both the TPU MXU edge and
# the cuBLASLt INT8 tile the paper's GEMMs use.
DEFAULT_BM = 128
DEFAULT_BN = 128


def _kernel(x_ref, w_ref, b_ref, o_ref, *, combined_scale: float,
            out_scale: float | None, use_bias: bool):
    """One (bm, bn) output tile: INT8 dot -> INT32 acc -> epilogue."""
    acc = jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    y = acc.astype(jnp.float32) * combined_scale
    if use_bias:
        y = y + b_ref[...]
    if out_scale is not None:
        q = jnp.clip(jnp.round(y / out_scale), QMIN, QMAX)
        o_ref[...] = q.astype(jnp.int8)
    else:
        o_ref[...] = y


def int8_matmul(q_x, q_w, x_scale: float, w_scale: float, bias=None,
                out_scale: float | None = None,
                bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """Compute ``requant(dequant(q_x @ q_w) + bias)`` as a tiled Pallas kernel.

    Args:
      q_x: int8 [M, K] quantized activations (scale ``x_scale``).
      q_w: int8 [K, N] quantized weights (scale ``w_scale``).
      x_scale, w_scale: symmetric per-tensor scales (baked as constants).
      bias: optional f32 [N].
      out_scale: if given, output is int8 quantized with this scale; else f32.
      bm, bn: output tile targets (clamped to divisors of M / N).

    Returns: int8 or f32 [M, N].
    """
    m, k = q_x.shape
    k2, n = q_w.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    use_bias = bias is not None
    if not use_bias:
        bias = jnp.zeros((n,), jnp.float32)
    bias2d = bias.reshape(1, n).astype(jnp.float32)

    out_dtype = jnp.int8 if out_scale is not None else jnp.float32
    kern = functools.partial(
        _kernel,
        combined_scale=float(x_scale) * float(w_scale),
        out_scale=None if out_scale is None else float(out_scale),
        use_bias=use_bias,
    )
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=INTERPRET,
    )(q_x, q_w, bias2d)


def vmem_estimate(m: int, k: int, n: int,
                  bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                  out_int8: bool = True) -> int:
    """VMEM working set (bytes) of one grid step — perf-pass instrumentation."""
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    return vmem_bytes(
        ((bm, k), jnp.int8),      # activation block
        ((k, bn), jnp.int8),      # weight block
        ((1, bn), jnp.float32),   # bias block
        ((bm, bn), jnp.int32),    # accumulator
        ((bm, bn), jnp.int8 if out_int8 else jnp.float32),
    )
