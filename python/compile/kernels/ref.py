"""Pure-jnp reference oracles for every SAMP Pallas kernel.

These are the *semantic ground truth*: each Pallas kernel in this package must
produce bit-identical (integer outputs) or allclose (float outputs) results
against the function of the same name here.  pytest + hypothesis sweep shapes,
dtypes and seeds (python/tests/test_kernels.py).

The references are deliberately written in the most straightforward jnp style —
no tiling, no fusion — so a reviewer can audit the math against the paper:

  * symmetric INT8 quantization (Appendix B)
  * INT8 GEMM with INT32 accumulation and requantization
  * the AddBias+AddResidual+LayerNorm (+Quant/deQuant) "big kernel" (Fig 2)
  * attention-softmax output quantization (the Fig 4 accuracy culprit)
  * the fused token+segment+position embedding (Fig 1)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import QMAX, QMIN, dequantize, quantize


# ---------------------------------------------------------------------------
# Embedding (tensor fusion: 3 gathers + add -> one op)
# ---------------------------------------------------------------------------

def ref_fused_embedding(token_ids, segment_ids, tok_table, seg_table, pos_table,
                        gamma, beta, out_scale: float | None = None):
    """token+segment+position embedding sum, then LayerNorm, optional INT8 out.

    Position ids are implicit ``arange(seq)`` as in BERT.  When ``out_scale``
    is given the output is quantized (Fully-Quant mode feeds the encoder INT8
    straight from the embedding, Fig 2a).
    """
    seq = token_ids.shape[-1]
    emb = (jnp.take(tok_table, token_ids, axis=0)
           + jnp.take(seg_table, segment_ids, axis=0)
           + pos_table[None, :seq, :])
    emb = ref_layernorm(emb, gamma, beta)
    if out_scale is not None:
        return quantize(emb, out_scale)
    return emb


def ref_layernorm(x, gamma, beta, eps: float = 1e-12):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma + beta


# ---------------------------------------------------------------------------
# INT8 GEMM with INT32 accumulation + requantization
# ---------------------------------------------------------------------------

def ref_int8_matmul(q_x, q_w, x_scale: float, w_scale: float,
                    bias=None, out_scale: float | None = None):
    """INT8xINT8 -> INT32 GEMM, dequant by s_x*s_w, +bias, optional requant.

    Mirrors the cuBLASLt INT8 GEMM + epilogue the paper uses: accumulation is
    exact 32-bit integer, all rounding happens at the requantization step.
    """
    acc = jax.lax.dot_general(
        q_x, q_w,
        dimension_numbers=(((q_x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    y = acc.astype(jnp.float32) * (x_scale * w_scale)
    if bias is not None:
        y = y + bias
    if out_scale is not None:
        return quantize(y, out_scale)
    return y


# ---------------------------------------------------------------------------
# Fused epilogues ("big kernels", Fig 2): AddBias+Residual+LayerNorm, Bias+GELU
# ---------------------------------------------------------------------------

def ref_bias_residual_layernorm(x, bias, residual, gamma, beta,
                                x_scale: float | None = None,
                                residual_scale: float | None = None,
                                out_scale: float | None = None,
                                eps: float = 1e-12):
    """The SAMP "big kernel": (deQuant) + AddBias + AddResidual + LayerNorm (+ Quant).

    * ``x`` is the GEMM output: int32 accumulator if ``x_scale`` is given
      (Fully-Quant dataflow — the green INT8/INT32 arrows in Fig 2a), else f32.
    * ``residual`` is int8 if ``residual_scale`` is given, else f32.
    * output is int8 if ``out_scale`` is given, else f32.
    """
    if x_scale is not None:
        x = x.astype(jnp.float32) * x_scale
    if residual_scale is not None:
        residual = dequantize(residual, residual_scale)
    h = x + bias + residual
    h = ref_layernorm(h, gamma, beta, eps)
    if out_scale is not None:
        return quantize(h, out_scale)
    return h


def ref_bias_gelu(x, bias, x_scale: float | None = None,
                  out_scale: float | None = None):
    """AddBias + GELU (+Quant) epilogue after the FFN fc1 GEMM (tanh approx)."""
    if x_scale is not None:
        x = x.astype(jnp.float32) * x_scale
    h = x + bias
    h = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h * h * h)))
    if out_scale is not None:
        return quantize(h, out_scale)
    return h


# ---------------------------------------------------------------------------
# Softmax (+ INT8 output quantization — the Fig 4 phenomenon)
# ---------------------------------------------------------------------------

def ref_softmax_quant(logits, mask_bias, out_scale: float | None = None):
    """Masked softmax over the last axis, optional INT8 output quantization.

    Appendix B: softmax outputs live in [0, 1]; under symmetric quantization
    the [-128, 0) half of the INT8 range is unused and short sequences push
    mass toward large values — quantizing here is the dominant accuracy loss
    of Fully-Quant mode.  The oracle (and kernel) reproduce that faithfully.
    """
    x = logits + mask_bias
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    if out_scale is not None:
        return quantize(p, out_scale)
    return p


# ---------------------------------------------------------------------------
# Fused scaled-dot-product attention (FP16/FP32 MHA path)
# ---------------------------------------------------------------------------

def ref_attention(q, k, v, mask_bias, sm_scale: float):
    """softmax(q k^T * sm_scale + mask) v with f32 accumulation.

    ``q,k,v``: [rows, seq, head_dim] where rows = batch*heads; ``mask_bias``:
    [rows, seq] additive (0 for keep, large-negative for pad).
    """
    acc_t = jnp.float32
    s = jnp.einsum("rqd,rkd->rqk", q.astype(acc_t), k.astype(acc_t)) * sm_scale
    s = s + mask_bias[:, None, :]
    p = ref_softmax_quant(s, jnp.zeros_like(s))
    o = jnp.einsum("rqk,rkd->rqd", p, v.astype(acc_t))
    return o.astype(q.dtype)


__all__ = [
    "ref_fused_embedding", "ref_layernorm", "ref_int8_matmul",
    "ref_bias_residual_layernorm", "ref_bias_gelu", "ref_softmax_quant",
    "ref_attention", "quantize", "dequantize", "QMIN", "QMAX",
]
