"""Pallas fused epilogue "big kernels": deQuant+AddBias+AddResidual+LayerNorm+Quant
and deQuant+AddBias+GELU+Quant.

These are the paper's second "advanced fusion strategy" (§3.2, Fig 2): in
Fully-Quant mode every arrow between GEMMs stays INT8 because the Quant/deQuant
steps are folded into the same kernel as AddResidual/AddBias/LayerNorm.  That
halves both the number of kernel launches and the bit-width of the inter-kernel
HBM traffic — the two effects the latency cost model (rust/src/latency/)
credits SAMP for over FasterTransformer-INT8 (§4.3's 5~10%).

Each variant of the epilogue is selected statically at trace time (scales are
either None or baked floats), so a given precision plan lowers to exactly the
kernel sequence of Fig 2a / 2b with no runtime branching.

Hardware adaptation: row-parallel grid; each step owns a (rows_per_block, H)
tile in VMEM.  LayerNorm reductions are along the lane dimension, which is the
cheap direction on both GPU warps and TPU vector units.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, QMAX, QMIN, pick_block, vmem_bytes

# Rows of the flattened [B*S, H] activation matrix handled per grid step.
DEFAULT_BLOCK_ROWS = 64


def _ln_kernel(x_ref, b_ref, r_ref, g_ref, bt_ref, o_ref, *,
               x_scale, residual_scale, out_scale, eps):
    x = x_ref[...]
    if x_scale is not None:
        x = x.astype(jnp.float32) * x_scale
    r = r_ref[...]
    if residual_scale is not None:
        r = r.astype(jnp.float32) * residual_scale
    h = x + b_ref[...] + r
    mean = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(h - mean), axis=-1, keepdims=True)
    h = (h - mean) * jax.lax.rsqrt(var + eps) * g_ref[...] + bt_ref[...]
    if out_scale is not None:
        q = jnp.clip(jnp.round(h / out_scale), QMIN, QMAX)
        o_ref[...] = q.astype(jnp.int8)
    else:
        o_ref[...] = h.astype(o_ref.dtype)


def bias_residual_layernorm(x, bias, residual, gamma, beta,
                            x_scale: float | None = None,
                            residual_scale: float | None = None,
                            out_scale: float | None = None,
                            eps: float = 1e-12,
                            block_rows: int = DEFAULT_BLOCK_ROWS,
                            out_dtype=None):
    """(deQuant) + AddBias + AddResidual + LayerNorm (+ Quant), one kernel.

    Args:
      x:        [R, H] GEMM output — int32 if ``x_scale`` given, else float.
      bias:     [H] f32.
      residual: [R, H] — int8 if ``residual_scale`` given, else float.
      gamma, beta: [H] f32 LayerNorm parameters.
      out_scale: int8 output quantization scale, or None for float output.
      out_dtype: float output dtype (defaults to f32; pass jnp.float16 for the
                 FP16 pipeline).
    """
    r_, h_ = x.shape
    br = pick_block(r_, block_rows)
    if out_scale is not None:
        odt = jnp.int8
    else:
        odt = out_dtype or jnp.float32
    kern = functools.partial(
        _ln_kernel,
        x_scale=None if x_scale is None else float(x_scale),
        residual_scale=None if residual_scale is None else float(residual_scale),
        out_scale=None if out_scale is None else float(out_scale),
        eps=eps,
    )
    return pl.pallas_call(
        kern,
        grid=(r_ // br,),
        in_specs=[
            pl.BlockSpec((br, h_), lambda i: (i, 0)),
            pl.BlockSpec((h_,), lambda i: (0,)),
            pl.BlockSpec((br, h_), lambda i: (i, 0)),
            pl.BlockSpec((h_,), lambda i: (0,)),
            pl.BlockSpec((h_,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, h_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_, h_), odt),
        interpret=INTERPRET,
    )(x, bias, residual, gamma, beta)


def _gelu_kernel(x_ref, b_ref, o_ref, *, x_scale, out_scale):
    x = x_ref[...]
    if x_scale is not None:
        x = x.astype(jnp.float32) * x_scale
    h = x + b_ref[...]
    h = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h * h * h)))
    if out_scale is not None:
        q = jnp.clip(jnp.round(h / out_scale), QMIN, QMAX)
        o_ref[...] = q.astype(jnp.int8)
    else:
        o_ref[...] = h.astype(o_ref.dtype)


def bias_gelu(x, bias, x_scale: float | None = None,
              out_scale: float | None = None,
              block_rows: int = DEFAULT_BLOCK_ROWS,
              out_dtype=None):
    """(deQuant) + AddBias + GELU (+ Quant) — the FFN fc1 epilogue (tanh approx)."""
    r_, h_ = x.shape
    br = pick_block(r_, block_rows)
    if out_scale is not None:
        odt = jnp.int8
    else:
        odt = out_dtype or jnp.float32
    kern = functools.partial(
        _gelu_kernel,
        x_scale=None if x_scale is None else float(x_scale),
        out_scale=None if out_scale is None else float(out_scale),
    )
    return pl.pallas_call(
        kern,
        grid=(r_ // br,),
        in_specs=[
            pl.BlockSpec((br, h_), lambda i: (i, 0)),
            pl.BlockSpec((h_,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, h_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_, h_), odt),
        interpret=INTERPRET,
    )(x, bias)


def vmem_estimate(hidden: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                  quantized: bool = True) -> int:
    """VMEM working set (bytes) of one LN-epilogue grid step."""
    act_dtype = jnp.int32 if quantized else jnp.float32
    res_dtype = jnp.int8 if quantized else jnp.float32
    out_dtype = jnp.int8 if quantized else jnp.float32
    return vmem_bytes(
        ((block_rows, hidden), act_dtype),
        ((block_rows, hidden), res_dtype),
        ((hidden,), jnp.float32), ((hidden,), jnp.float32), ((hidden,), jnp.float32),
        ((block_rows, hidden), out_dtype),
    )
