"""Pallas masked softmax with fused INT8 output quantization.

This kernel exists because it is the *scientific core* of the paper's Appendix
B / Figure 4 analysis: in Fully-Quant mode the attention probabilities P =
softmax(QK^T) are quantized so the PV GEMM can run INT8, but P lives in [0, 1]
— under symmetric quantization the codes [-127, 0) are dead, and with the
row-sum-to-1 constraint short sequences concentrate mass into a few large
codes.  The accuracy damage compounds with depth, which is why Quant-FFN-Only
(which never runs this kernel) is the recommended mode.

The kernel fuses mask-add + max-subtract + exp + normalize + quantize into one
launch (FasterTransformer launches softmax and quantize separately; this is
part of SAMP's §4.3 5~10% INT8 edge, and the cost model credits it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, QMAX, QMIN, pick_block, vmem_bytes

# Attention-score rows handled per grid step.
DEFAULT_BLOCK_ROWS = 64


def _kernel(x_ref, m_ref, o_ref, *, out_scale):
    x = x_ref[...].astype(jnp.float32) + m_ref[...]
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    if out_scale is not None:
        q = jnp.clip(jnp.round(p / out_scale), QMIN, QMAX)
        o_ref[...] = q.astype(jnp.int8)
    else:
        o_ref[...] = p.astype(o_ref.dtype)


def softmax_quant(logits, mask_bias, out_scale: float | None = None,
                  block_rows: int = DEFAULT_BLOCK_ROWS, out_dtype=None):
    """Masked softmax over the last axis, optionally INT8-quantized.

    Args:
      logits:    [R, S] attention scores (any float dtype; math in f32).
      mask_bias: [R, S] additive mask (0 keep / -1e9 pad), broadcast-ready.
      out_scale: INT8 scale for the quantized probabilities, or None.

    Returns: int8 or float [R, S].
    """
    r_, s_ = logits.shape
    br = pick_block(r_, block_rows)
    if out_scale is not None:
        odt = jnp.int8
    else:
        odt = out_dtype or logits.dtype
    kern = functools.partial(
        _kernel, out_scale=None if out_scale is None else float(out_scale))
    return pl.pallas_call(
        kern,
        grid=(r_ // br,),
        in_specs=[
            pl.BlockSpec((br, s_), lambda i: (i, 0)),
            pl.BlockSpec((br, s_), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, s_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_, s_), odt),
        interpret=INTERPRET,
    )(logits, mask_bias)


def vmem_estimate(seq: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                  quantized: bool = True) -> int:
    """VMEM working set (bytes) of one grid step."""
    return vmem_bytes(
        ((block_rows, seq), jnp.float32),
        ((block_rows, seq), jnp.float32),
        ((block_rows, seq), jnp.int8 if quantized else jnp.float32),
    )
