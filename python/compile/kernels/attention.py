"""Pallas fused scaled-dot-product attention for the FP32/FP16 MHA path.

In Quant-FFN-Only mode (the paper's recommended mode, Fig 2b) the whole MHA
block stays floating point; SAMP still fuses QK^T-scale-mask-softmax-PV into a
single kernel to cut launches.  This kernel is that fusion: one grid step per
(batch*head), the full [S, D] Q/K/V panels resident in VMEM (S <= 256,
D <= 64 in this repo, so the working set is well under the VMEM budget — the
flash-style K-blocking of a production TPU kernel is unnecessary at these
geometries and would only obscure the numerics).

Accumulation is always f32 regardless of the I/O dtype, matching tensor-core
FP16 GEMM semantics (f16 multiplicands, f32 accumulator).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, vmem_bytes


def _kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, sm_scale):
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    s = s + m_ref[0][None, :]
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)


def attention(q, k, v, mask_bias, sm_scale: float):
    """Fused softmax(q k^T * sm_scale + mask) v.

    Args:
      q, k, v:  [R, S, D] with R = batch*heads; f32 or f16.
      mask_bias: [R, S] additive key mask (0 keep / -1e9 pad).
      sm_scale: 1/sqrt(head_dim).

    Returns: [R, S, D] in the dtype of ``q``.
    """
    r_, s_, d_ = q.shape
    kern = functools.partial(_kernel, sm_scale=float(sm_scale))
    return pl.pallas_call(
        kern,
        grid=(r_,),
        in_specs=[
            pl.BlockSpec((1, s_, d_), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s_, d_), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s_, d_), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s_), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, s_, d_), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((r_, s_, d_), q.dtype),
        interpret=INTERPRET,
    )(q, k, v, mask_bias)


def vmem_estimate(seq: int, head_dim: int, dtype=jnp.float32) -> int:
    """VMEM working set (bytes) of one grid step (one batch*head panel)."""
    return vmem_bytes(
        ((seq, head_dim), dtype), ((seq, head_dim), dtype),
        ((seq, head_dim), dtype),
        ((seq,), jnp.float32),
        ((seq, seq), jnp.float32),   # score/prob panel
        ((seq, head_dim), dtype),
    )
