"""SAMP Layer-1: Pallas kernels for the paper's fused/quantized hot-spots.

Every kernel here has a pure-jnp oracle of the same name prefixed ``ref_`` in
:mod:`compile.kernels.ref`; pytest + hypothesis enforce equivalence.  All
kernels run with ``interpret=True`` (see common.INTERPRET) so they lower to
plain HLO executable by the CPU PJRT client used at serving time.
"""

from .attention import attention
from .common import (INTERPRET, QMAX, QMIN, amax_to_scale, dequantize,
                     pick_block, quantize)
from .fused_embedding import fused_embedding
from .fused_ln_quant import bias_gelu, bias_residual_layernorm
from .int8_matmul import int8_matmul
from .softmax_quant import softmax_quant

__all__ = [
    "attention", "fused_embedding", "bias_gelu", "bias_residual_layernorm",
    "int8_matmul", "softmax_quant",
    "quantize", "dequantize", "amax_to_scale", "pick_block",
    "QMIN", "QMAX", "INTERPRET",
]
