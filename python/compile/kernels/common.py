"""Shared quantization math and Pallas blocking helpers for SAMP kernels.

All SAMP quantization is *symmetric per-tensor INT8* (the paper follows NVIDIA
pytorch-quantization's symmetric scheme, Appendix B):

    q = clip(round(x / s), -127, 127)  -> int8
    x' = q * s                         -> dequantized float

``-128`` is never produced (symmetric range [-127, 127]), matching
pytorch-quantization's convention.

Scales are *baked into the HLO as constants* at AOT time: the calibration pass
(python/compile/calib.py) produces them once, and ``aot.py`` closes over them
when tracing each precision variant.  This mirrors the paper's deployment flow
where calibrated scales are fixed at engine-build time (Appendix B: "the scale
in the same layer is pre-computed in calibration process and is fixed in
inference process").
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# INT8 symmetric range (pytorch-quantization convention: -127..127, -128 unused).
QMIN = -127
QMAX = 127

# Pallas kernels must run in interpret mode in this environment: the CPU PJRT
# plugin cannot execute Mosaic (real-TPU) custom-calls.  interpret=True lowers
# the kernel body to plain HLO so the same artifact runs anywhere.
INTERPRET = True


def quantize(x: jax.Array, scale: float) -> jax.Array:
    """Symmetric per-tensor quantization to int8."""
    q = jnp.clip(jnp.round(x / scale), QMIN, QMAX)
    return q.astype(jnp.int8)


def dequantize(q: jax.Array, scale: float) -> jax.Array:
    """Inverse of :func:`quantize` (up to rounding error <= scale/2)."""
    return q.astype(jnp.float32) * scale


def amax_to_scale(amax: float) -> float:
    """Convert a calibrated absolute-max to a symmetric INT8 scale."""
    amax = float(amax)
    if amax <= 0.0 or not math.isfinite(amax):
        # Degenerate tensor (all zeros): any scale works; pick 1.0 so that
        # quantize() produces zeros and dequantize() reproduces them.
        return 1.0
    return amax / QMAX


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= ``target``.

    SAMP static shapes are chosen so the hot dimensions are multiples of the
    MXU-friendly tile sizes (128/64/32); for oddball shapes from the property
    tests this degrades gracefully down to 1.
    """
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return 1


def pad_to_multiple(x: jax.Array, axis: int, multiple: int, value=0):
    """Pad ``x`` along ``axis`` up to the next multiple. Returns (padded, orig)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x, size
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value), size


def vmem_bytes(*shapes_dtypes) -> int:
    """Estimate the VMEM working set of a kernel from its block shapes.

    Used by the perf pass (EXPERIMENTS.md §Perf) to keep every kernel's
    resident blocks under the ~16 MiB TPU VMEM budget.  ``shapes_dtypes`` is a
    sequence of (shape_tuple, dtype) pairs.
    """
    total = 0
    for shape, dtype in shapes_dtypes:
        n = 1
        for d in shape:
            n *= int(d)
        total += n * jnp.dtype(dtype).itemsize
    return total
