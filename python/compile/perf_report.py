"""L1/L2 structural performance report (EXPERIMENTS.md §Perf).

Interpret-mode Pallas gives no TPU wall-clock, so L1 is assessed structurally
(DESIGN.md §8): per-kernel VMEM working set vs the ~16 MiB budget, MXU
utilization estimate from block shapes, and HBM traffic per fused op vs the
unfused baseline.  L2 is assessed from the lowered HLO: module size, op
histogram, fusion-relevant op counts per precision variant.

Usage: cd python && python -m compile.perf_report [--artifacts ../artifacts]
"""

from __future__ import annotations

import argparse
import os
import re
from collections import Counter

import jax.numpy as jnp

# NB: compile.kernels re-exports the kernel *functions* under the same
# names as their submodules; importlib dodges the attribute shadowing.
import importlib

attn_k = importlib.import_module("compile.kernels.attention")
emb_k = importlib.import_module("compile.kernels.fused_embedding")
ln_k = importlib.import_module("compile.kernels.fused_ln_quant")
mm_k = importlib.import_module("compile.kernels.int8_matmul")
sm_k = importlib.import_module("compile.kernels.softmax_quant")

VMEM_BUDGET = 16 * 1024 * 1024  # ~16 MiB per TPU core
MXU = 128                        # systolic array edge


def fmt_bytes(n: int) -> str:
    if n >= 1 << 20:
        return f"{n / (1<<20):.2f} MiB"
    return f"{n / 1024:.1f} KiB"


def mxu_utilization(bm: int, bn: int, k: int) -> float:
    """Fraction of the 128x128 MXU tile the operand block shapes fill."""
    return min(bm, MXU) * min(bn, MXU) / (MXU * MXU)


def l1_report(geoms) -> None:
    print("== L1 Pallas kernels: VMEM working set & MXU estimate ==")
    print(f"   (budget {fmt_bytes(VMEM_BUDGET)}; serving geometries)")
    for name, (batch, seq, hidden, ffn, vocab) in geoms.items():
        rows = batch * seq
        print(f"\n-- geometry {name}: B={batch} S={seq} H={hidden} F={ffn}")
        checks = [
            ("int8_matmul qkv   ", mm_k.vmem_estimate(rows, hidden, hidden),
             mxu_utilization(mm_k.pick_block(rows, mm_k.DEFAULT_BM),
                             mm_k.pick_block(hidden, mm_k.DEFAULT_BN), hidden)),
            ("int8_matmul fc1   ", mm_k.vmem_estimate(rows, hidden, ffn),
             mxu_utilization(mm_k.pick_block(rows, mm_k.DEFAULT_BM),
                             mm_k.pick_block(ffn, mm_k.DEFAULT_BN), hidden)),
            ("int8_matmul fc2   ", mm_k.vmem_estimate(rows, ffn, hidden),
             mxu_utilization(mm_k.pick_block(rows, mm_k.DEFAULT_BM),
                             mm_k.pick_block(hidden, mm_k.DEFAULT_BN), ffn)),
            ("fused_embedding   ", emb_k.vmem_estimate(seq, vocab, hidden),
             None),
            ("bias_res_ln(+q)   ", ln_k.vmem_estimate(hidden), None),
            ("softmax_quant     ", sm_k.vmem_estimate(seq), None),
            ("fused_attention   ", attn_k.vmem_estimate(seq, hidden // 4),
             mxu_utilization(seq, seq, hidden // 4)),
        ]
        for kname, vmem, mxu in checks:
            ok = "OK " if vmem <= VMEM_BUDGET else "OVER"
            mxu_s = f"  mxu~{mxu*100:4.0f}%" if mxu is not None else ""
            print(f"  {kname} vmem={fmt_bytes(vmem):>10} [{ok}]{mxu_s}")

    # fusion savings: HBM traffic of the fused LN epilogue vs unfused chain
    rows, hidden = 8 * 64, 64
    f32 = 4
    unfused = (  # add-bias read+write, residual read+write, LN stats+norm
        2 * rows * hidden * f32 + 3 * rows * hidden * f32
        + rows * hidden * f32 + 2 * rows * hidden * f32)
    fused = 2 * rows * hidden * 4 + rows * hidden * 1  # int32 in, int8 res+out
    print(f"\n  big-kernel HBM traffic (B8,S64,H64): unfused {fmt_bytes(unfused)}"
          f" -> fused {fmt_bytes(fused)} ({unfused/fused:.1f}x less)")


HLO_INTERESTING = ("dot", "convert", "multiply", "add", "round-nearest-afz",
                   "clamp", "exponential", "transpose", "fusion")


def l2_report(artifacts: str, task: str = "tnews") -> None:
    hdir = os.path.join(artifacts, "hlo", task)
    if not os.path.isdir(hdir):
        print(f"\n== L2: no artifacts at {hdir} (run make artifacts) ==")
        return
    print(f"\n== L2 lowered HLO per variant ({task}) ==")
    print(f"{'variant':>22} {'KiB':>8} {'ops':>6} {'dots':>5} {'converts':>8} "
          f"{'rounds':>6}")
    for fname in sorted(os.listdir(hdir)):
        if not fname.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(hdir, fname)).read()
        ops = Counter()
        for line in text.splitlines():
            m = re.search(r"=\s+\S+\s+(\w[\w-]*)\(", line)
            if m:
                ops[m.group(1)] += 1
        total = sum(ops.values())
        print(f"{fname[:-8]:>22} {len(text)//1024:>8} {total:>6} "
              f"{ops.get('dot', 0):>5} {ops.get('convert', 0):>8} "
              f"{ops.get('round-nearest-afz', 0):>6}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args(argv)
    geoms = {
        "tnews  (B8,S32,H64)": (8, 32, 64, 256, 2048),
        "iflytek(B8,S128,H64)": (8, 128, 64, 256, 2048),
        "bert-base(B8,S64)": (8, 64, 768, 3072, 30522),
    }
    l1_report(geoms)
    l2_report(args.artifacts)


if __name__ == "__main__":
    main()
