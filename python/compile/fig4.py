"""Export the Figure-4 activations: quantized-softmax-output vs MHA-output.

Appendix B counts the INT8 code usage of (a) the MHA (attention-context)
output and (b) the attention-softmax output P over 64 TNEWS sequences.  This
tool runs the tap forward on the trained FP32 model and dumps both float
tensors so the Rust side (`bench_fig4`, `examples/softmax_distribution.rs`)
can quantize them with the calibrated scales and histogram the codes.

Binary format: magic "SAMPFIG4", then per array: u32 name_len, name bytes,
u64 element count, f32 data (little-endian).  Arrays: "p_out" and "ctx"
(mid-stack layer), plus "p_scale"/"ctx_scale" as 1-element arrays.
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .model import encoder_forward_with_taps
from .train import config_for_task, load_params


def write_array(f, name: str, arr: np.ndarray):
    nb = name.encode()
    f.write(struct.pack("<I", len(nb)))
    f.write(nb)
    a = np.ascontiguousarray(arr, dtype="<f4").ravel()
    f.write(struct.pack("<Q", a.size))
    f.write(a.tobytes())


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--task", default="tnews")
    ap.add_argument("--layer", type=int, default=6,
                    help="which layer's taps to dump (paper counts mid-stack)")
    ap.add_argument("--sequences", type=int, default=64,
                    help="64 sequences, as in Appendix B")
    args = ap.parse_args(argv)

    cfg = config_for_task(args.task)
    params = load_params(os.path.join(args.artifacts, "weights",
                                      f"{args.task}.npz"))
    manifest = json.load(open(os.path.join(args.artifacts, "manifest.json")))
    model = next(m for m in manifest["models"] if m["task"] == args.task)
    scales = model["scales"]

    ids, segs, mask, _ = data_mod.generate(args.task, "dev",
                                           n=args.sequences)
    # run in chunks of 16 to bound memory
    p_chunks, ctx_chunks = [], []
    for i in range(0, args.sequences, 16):
        _, taps = encoder_forward_with_taps(
            params, cfg, jnp.asarray(ids[i:i + 16]), jnp.asarray(segs[i:i + 16]),
            jnp.asarray(mask[i:i + 16].astype(np.float32)))
        p_chunks.append(np.asarray(taps[f"l{args.layer}/p_out"]))
        ctx_chunks.append(np.asarray(taps[f"l{args.layer}/ctx"]))
    p_out = np.concatenate(p_chunks, axis=0)
    ctx = np.concatenate(ctx_chunks, axis=0)

    out = os.path.join(args.artifacts, f"fig4_{args.task}.bin")
    with open(out, "wb") as f:
        f.write(b"SAMPFIG4")
        write_array(f, "p_out", p_out)
        write_array(f, "ctx", ctx)
        write_array(f, "p_scale",
                    np.array([scales[f"l{args.layer}/p_out"]], np.float32))
        write_array(f, "ctx_scale",
                    np.array([scales[f"l{args.layer}/ctx"]], np.float32))
    print(f"[fig4] wrote {out}: p_out {p_out.shape}, ctx {ctx.shape}")


if __name__ == "__main__":
    main()
