"""Synthetic CLUE-like datasets for the SAMP reproduction.

The paper evaluates on three CLUE text-classification tasks (AFQMC sentence-
pair matching, IFLYTEK long-text classification, TNEWS short-text news
classification) plus NER/matching capabilities in the Target module.  The real
CLUE corpora are not available offline, so we synthesize tasks with the same
*statistical shape* (DESIGN.md §4 Substitutions):

  * ``afqmc``   — sentence-pair matching, 2 labels, seq 64, [CLS] a [SEP] b
                  [SEP] with segment ids; pairs share a latent topic when
                  positive.
  * ``tnews``   — short-text classification, 15 labels, seq 32; heavily
                  overlapping class keyword sets make it the hardest task
                  (paper dev accuracy 0.56).
  * ``iflytek`` — long-text classification, 20 labels, seq 128; sparse
                  keywords in long noisy documents (paper 0.60).
  * ``cluener`` — BIO tagging over 4 entity types, 9 labels, seq 32 (the NER
                  downstream task of Table 1).

Every example also carries a *text* rendering (space-joined vocabulary words)
so the Rust tokenizer can reproduce the exact id sequence end-to-end; the
shared vocabulary is emitted by :func:`build_vocab` (word ``w00042`` <-> id 42
plus BERT specials and a CJK block for the multi-granularity tokenizer).

Everything is deterministic in (task, split, seed).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Tuple

import numpy as np


def _stable_hash(s: str) -> int:
    """Process-independent string hash (python's hash() is randomized per
    process by PYTHONHASHSEED — using it for dataset seeds silently decouples
    weights trained in one process from datasets generated in another)."""
    return zlib.crc32(s.encode())

VOCAB_SIZE = 2048
PAD, UNK, CLS, SEP, MASK = 0, 1, 2, 3, 4
N_SPECIAL = 5
# ids [CJK_BASE, CJK_BASE+CJK_COUNT) render as CJK chars (multi-granularity
# tokenization support); the rest render as ASCII words "w%05d".
CJK_BASE = 1900
CJK_COUNT = 100

NER_LABELS = ["O", "B-PER", "I-PER", "B-ORG", "I-ORG", "B-LOC", "I-LOC",
              "B-PRO", "I-PRO"]


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    kind: str             # classification | matching | ner
    num_labels: int
    seq_len: int
    n_train: int
    n_dev: int
    n_classeswords: int    # keywords per class
    keyword_prob: float    # P(token is a class keyword)
    confusion: float       # P(keyword drawn from a *confusable* class)
    label_noise: float     # P(label replaced by a uniform random label)


# label_noise is the difficulty knob that pins each task's Bayes ceiling near
# the paper's BERT-base dev accuracy (AFQMC 0.73, IFLYTEK 0.60, TNEWS 0.56):
# with noise q and K classes the ceiling is 1 - q + q/K.  Features themselves
# are kept easy so the tiny encoder converges in a few hundred CPU steps.
TASKS: Dict[str, TaskSpec] = {
    "afqmc": TaskSpec("afqmc", "matching", 2, 64, 8000, 1024, 48,
                      0.45, 0.10, 0.52),
    "tnews": TaskSpec("tnews", "classification", 15, 32, 8000, 1024, 32,
                      0.50, 0.15, 0.46),
    "iflytek": TaskSpec("iflytek", "classification", 20, 128, 8000, 1024, 40,
                        0.35, 0.15, 0.41),
    "cluener": TaskSpec("cluener", "ner", len(NER_LABELS), 32, 8000, 1024,
                        24, 0.25, 0.20, 0.0),
}


def word_for_id(tok: int) -> str:
    """Deterministic surface form for a vocabulary id (see build_vocab)."""
    if tok == PAD:
        return "[PAD]"
    if tok == UNK:
        return "[UNK]"
    if tok == CLS:
        return "[CLS]"
    if tok == SEP:
        return "[SEP]"
    if tok == MASK:
        return "[MASK]"
    if CJK_BASE <= tok < CJK_BASE + CJK_COUNT:
        return chr(0x4E00 + (tok - CJK_BASE))
    return f"w{tok:05d}"


def build_vocab() -> List[str]:
    """The shared vocab file contents (line i = token id i)."""
    return [word_for_id(i) for i in range(VOCAB_SIZE)]


def _class_keywords(spec: TaskSpec, rng: np.random.Generator) -> np.ndarray:
    """[num_labels, n_classeswords] keyword ids; neighbours share some words
    (that is what makes TNEWS-like tasks hard)."""
    pool = np.arange(N_SPECIAL, CJK_BASE)
    kws = np.zeros((spec.num_labels, spec.n_classeswords), dtype=np.int64)
    for c in range(spec.num_labels):
        kws[c] = rng.choice(pool, size=spec.n_classeswords, replace=False)
    return kws


def _fill_tokens(spec: TaskSpec, rng: np.random.Generator, kws: np.ndarray,
                 label: int, length: int) -> np.ndarray:
    """Sample a token sequence for class ``label``."""
    common = rng.integers(N_SPECIAL, CJK_BASE, size=length)
    is_kw = rng.random(length) < spec.keyword_prob
    confus = rng.random(length) < spec.confusion
    # confusable class: ring neighbour, which shares the keyword *style*
    other = (label + rng.integers(1, spec.num_labels, size=length)) % spec.num_labels
    src = np.where(is_kw & ~confus, label, np.where(is_kw & confus, other, -1))
    kw_idx = rng.integers(0, spec.n_classeswords, size=length)
    toks = np.where(src >= 0, kws[np.clip(src, 0, None), kw_idx], common)
    return toks


def _apply_label_noise(labels, num_labels, noise, rng):
    flip = rng.random(len(labels)) < noise
    rand = rng.integers(0, num_labels, size=len(labels)).astype(labels.dtype)
    return np.where(flip, rand, labels)


def _gen_classification(spec: TaskSpec, n: int, seed: int, noisy: bool):
    rng = np.random.default_rng(seed)
    kws = _class_keywords(spec, np.random.default_rng(_stable_hash(spec.name) % 2**31))
    ids = np.full((n, spec.seq_len), PAD, dtype=np.int32)
    segs = np.zeros((n, spec.seq_len), dtype=np.int32)
    mask = np.zeros((n, spec.seq_len), dtype=np.int32)
    labels = rng.integers(0, spec.num_labels, size=n).astype(np.int32)
    lo = max(6, spec.seq_len // 4)
    hi = spec.seq_len - 2
    for i in range(n):
        length = int(rng.integers(lo, hi + 1))
        toks = _fill_tokens(spec, rng, kws, int(labels[i]), length)
        row = [CLS] + list(toks[: spec.seq_len - 2]) + [SEP]
        ids[i, : len(row)] = row
        mask[i, : len(row)] = 1
    if noisy:
        labels = _apply_label_noise(labels, spec.num_labels, spec.label_noise,
                                    rng)
    return ids, segs, mask, labels


def _gen_matching(spec: TaskSpec, n: int, seed: int, noisy: bool):
    """AFQMC-like: two 'questions'; positive pairs share a latent topic."""
    rng = np.random.default_rng(seed)
    n_topics = 8
    topic_spec = dataclasses.replace(spec, num_labels=n_topics)
    kws = _class_keywords(topic_spec,
                          np.random.default_rng(_stable_hash(spec.name) % 2**31))
    ids = np.full((n, spec.seq_len), PAD, dtype=np.int32)
    segs = np.zeros((n, spec.seq_len), dtype=np.int32)
    mask = np.zeros((n, spec.seq_len), dtype=np.int32)
    labels = rng.integers(0, 2, size=n).astype(np.int32)
    half = (spec.seq_len - 3) // 2
    for i in range(n):
        t_a = int(rng.integers(0, n_topics))
        if labels[i] == 1:
            t_b = t_a
        else:
            # negatives are *near* topics half the time — hard negatives
            t_b = (int(rng.integers(1, n_topics)) + t_a) % n_topics
        la = int(rng.integers(half // 2, half + 1))
        lb = int(rng.integers(half // 2, half + 1))
        a = _fill_tokens(topic_spec, rng, kws, t_a, la)
        b = _fill_tokens(topic_spec, rng, kws, t_b, lb)
        row = [CLS] + list(a) + [SEP] + list(b) + [SEP]
        ids[i, : len(row)] = row[: spec.seq_len]
        mask[i, : len(row)] = 1
        segs[i, 2 + la : min(len(row), spec.seq_len)] = 1
    if noisy:
        labels = _apply_label_noise(labels, 2, spec.label_noise, rng)
    return ids, segs, mask, labels


def _gen_ner(spec: TaskSpec, n: int, seed: int):
    """CLUENER-like BIO tagging: entity tokens come from type-specific ranges."""
    rng = np.random.default_rng(seed)
    n_types = (spec.num_labels - 1) // 2
    # entity surface vocab: disjoint id blocks per type
    blk = (CJK_BASE - N_SPECIAL) // (n_types + 1)
    ids = np.full((n, spec.seq_len), PAD, dtype=np.int32)
    segs = np.zeros((n, spec.seq_len), dtype=np.int32)
    mask = np.zeros((n, spec.seq_len), dtype=np.int32)
    tags = np.zeros((n, spec.seq_len), dtype=np.int32)
    for i in range(n):
        length = int(rng.integers(spec.seq_len // 2, spec.seq_len - 2 + 1))
        row = [CLS]
        tag_row = [0]
        while len(row) < length:
            if rng.random() < 0.25 and len(row) + 3 < length:
                t = int(rng.integers(0, n_types))
                span = int(rng.integers(1, 4))
                base = N_SPECIAL + (t + 1) * blk
                for j in range(span):
                    row.append(int(rng.integers(base, base + blk // 4)))
                    tag_row.append(1 + 2 * t + (0 if j == 0 else 1))
            else:
                row.append(int(rng.integers(N_SPECIAL, N_SPECIAL + blk)))
                tag_row.append(0)
        row = row[: spec.seq_len - 1] + [SEP]
        tag_row = tag_row[: spec.seq_len - 1] + [0]
        ids[i, : len(row)] = row
        mask[i, : len(row)] = 1
        tags[i, : len(tag_row)] = tag_row
    return ids, segs, mask, tags


def generate(task: str, split: str, n: int | None = None,
             seed_base: int = 1234):
    """Generate (ids, segs, mask, labels) for ``task``/``split``."""
    spec = TASKS[task]
    n = n or (spec.n_train if split == "train" else spec.n_dev)
    seed = seed_base + {"train": 0, "dev": 1, "calib": 2}[split] * 7919 \
        + _stable_hash(task) % 1000
    # Label noise pins the dev-accuracy ceiling at the paper's numbers
    # (1 - q + q/K); the train split stays clean so the tiny encoder reaches
    # that ceiling within a few hundred CPU steps.
    noisy = split == "dev"
    if spec.kind == "matching":
        return _gen_matching(spec, n, seed, noisy)
    if spec.kind == "ner":
        return _gen_ner(spec, n, seed)
    return _gen_classification(spec, n, seed, noisy)


def render_text(ids_row: np.ndarray) -> str:
    """Detokenize one id row to the text the Rust tokenizer will re-tokenize.

    [CLS]/[SEP]/[PAD] are stripped: the serving path re-adds them.  For the
    matching task the [SEP] between the two sentences is rendered as a tab so
    the server can rebuild the pair.
    """
    words = []
    seen_sep = False
    for tok in ids_row:
        tok = int(tok)
        if tok in (PAD, CLS):
            continue
        if tok == SEP:
            if not seen_sep:
                words.append("\t")
                seen_sep = True
            continue
        words.append(word_for_id(tok))
    text = " ".join(words).replace(" \t ", "\t").replace(" \t", "\t")
    return text.strip()


def batches(ids, segs, mask, labels, batch_size: int):
    """Yield fixed-size batches, dropping the ragged remainder."""
    n = (len(ids) // batch_size) * batch_size
    for i in range(0, n, batch_size):
        sl = slice(i, i + batch_size)
        yield ids[sl], segs[sl], mask[sl], labels[sl]
