"""FP32 baseline training for the SAMP reproduction.

The paper trains FP32 baselines by "Pre-training and Fine-tuning" on each CLUE
task (§4.1); offline we train the tiny-BERT from scratch on the synthetic
tasks — what matters for SAMP is a *converged floating-point network whose
activations have task-shaped distributions*, which PTQ then quantizes.

Plain JAX: hand-rolled Adam (optax is not available offline), jitted update
with donated state, deterministic seeds.  Weights are cached to
``artifacts/weights/{task}.npz`` and re-used by ``aot.py`` unless the geometry
changes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .model import (FP32, ModelConfig, PrecisionPlan, head_forward,
                    init_params, encoder_forward, encoder_forward_ref)

# 12 transformer layers to keep the paper's sweep axis (k of 12); small
# hidden so CPU training + the 40-variant AOT sweep stay tractable.
DEFAULT_GEOMETRY = dict(vocab_size=data_mod.VOCAB_SIZE, hidden=64, layers=12,
                        heads=4, ffn=256)


def config_for_task(task: str, layers: int | None = None,
                    hidden: int | None = None) -> ModelConfig:
    spec = data_mod.TASKS[task]
    geo = dict(DEFAULT_GEOMETRY)
    if layers:
        geo["layers"] = layers
    if hidden:
        geo["hidden"] = hidden
        geo["ffn"] = hidden * 4
    head = {"classification": "classification", "matching": "matching",
            "ner": "ner"}[spec.kind]
    return ModelConfig(max_len=spec.seq_len, num_labels=spec.num_labels,
                       head_type=head, **geo)


@dataclasses.dataclass
class TrainSettings:
    steps: int = 450
    batch_size: int = 32
    lr: float = 1e-3
    warmup: int = 50
    weight_decay: float = 0.01
    seed: int = 0
    log_every: int = 100


# ---------------------------------------------------------------------------
# Hand-rolled Adam (no optax offline)
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, weight_decay=0.0,
                b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k])
         for k in params}
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    new_params = {}
    for k in params:
        update = (m[k] / bc1) / (jnp.sqrt(v[k] / bc2) + eps)
        if weight_decay and not k.endswith(("_b", "_g", "/b", "bq", "bk", "bv",
                                            "bo", "b1", "b2")):
            update = update + weight_decay * params[k]
        new_params[k] = params[k] - lr * update
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Loss / eval
# ---------------------------------------------------------------------------

def _loss_fn(params, cfg: ModelConfig, plan, ids, segs, mask, labels):
    # Training uses the pure-jnp differentiable path (encoder_forward_ref);
    # interpret-mode Pallas has no reverse-mode autodiff, and inference never
    # backprops anyway (see model.py).
    logits = head_forward(params, cfg,
                          encoder_forward_ref(params, cfg, ids, segs, mask))
    if cfg.head_type == "ner":
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def accuracy(params, cfg: ModelConfig, plan, ids, segs, mask, labels,
             batch_size: int = 64) -> float:
    """Dev accuracy. For NER: token accuracy over non-pad positions."""
    fwd = jax.jit(lambda i, s, m: head_forward(
        params, cfg, encoder_forward(params, cfg, plan, i, s, m)))
    correct, total = 0, 0
    for bi, bs, bm, bl in data_mod.batches(ids, segs, mask, labels, batch_size):
        logits = np.asarray(fwd(jnp.asarray(bi), jnp.asarray(bs),
                                jnp.asarray(bm)))
        pred = logits.argmax(-1)
        if cfg.head_type == "ner":
            sel = bm.astype(bool)
            correct += int((pred[sel] == bl[sel]).sum())
            total += int(sel.sum())
        else:
            correct += int((pred == bl).sum())
            total += len(bl)
    return correct / max(total, 1)


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------

def train_task(task: str, cfg: ModelConfig | None = None,
               settings: TrainSettings | None = None,
               verbose: bool = True) -> Tuple[Dict[str, np.ndarray], ModelConfig, dict]:
    """Train the FP32 baseline for ``task``; returns (params, cfg, report)."""
    st = settings or TrainSettings()
    cfg = cfg or config_for_task(task)
    plan = PrecisionPlan.uniform(FP32, cfg.layers, fp_dtype=jnp.float32)

    ids, segs, mask, labels = data_mod.generate(task, "train")
    d_ids, d_segs, d_mask, d_labels = data_mod.generate(task, "dev")
    params = {k: jnp.asarray(v) for k, v in init_params(cfg, st.seed).items()}
    opt = adam_init(params)

    def lr_at(step):
        warm = jnp.minimum(step / max(st.warmup, 1), 1.0)
        decay = 1.0 - 0.9 * jnp.maximum(step - st.warmup, 0) / max(
            st.steps - st.warmup, 1)
        return st.lr * warm * decay

    @jax.jit
    def update(params, opt, bi, bs, bm, bl, step):
        loss, grads = jax.value_and_grad(_loss_fn)(params, cfg, plan,
                                                   bi, bs, bm, bl)
        # global-norm gradient clipping (BERT practice): without it the
        # 12-layer stack oscillates at lr ~1e-3 and never descends.
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads.values()))
        clip = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-6))
        grads = {k: g * clip for k, g in grads.items()}
        params, opt = adam_update(params, grads, opt, lr_at(step),
                                  st.weight_decay)
        return params, opt, loss

    rng = np.random.default_rng(st.seed)
    n = len(ids)
    losses = []
    for step in range(st.steps):
        idx = rng.integers(0, n, st.batch_size)
        params, opt, loss = update(params, opt,
                                   jnp.asarray(ids[idx]), jnp.asarray(segs[idx]),
                                   jnp.asarray(mask[idx]), jnp.asarray(labels[idx]),
                                   jnp.asarray(step, jnp.float32))
        losses.append(float(loss))
        if verbose and (step % st.log_every == 0 or step == st.steps - 1):
            print(f"[train:{task}] step {step:4d} loss {float(loss):.4f}")

    dev_acc = accuracy(params, cfg, plan, d_ids, d_segs, d_mask, d_labels)
    if verbose:
        print(f"[train:{task}] dev accuracy (FP32) = {dev_acc:.4f}")
    report = {"dev_accuracy_fp32": dev_acc, "final_loss": losses[-1],
              "first_loss": losses[0], "steps": st.steps,
              "loss_curve": losses[:: max(st.steps // 50, 1)]}
    params_np = {k: np.asarray(v) for k, v in params.items()}
    return params_np, cfg, report


def save_params(path: str, params: Dict[str, np.ndarray]):
    np.savez_compressed(path, **{k.replace("/", "__"): v
                                 for k, v in params.items()})


def load_params(path: str) -> Dict[str, np.ndarray]:
    raw = np.load(path)
    return {k.replace("__", "/"): raw[k] for k in raw.files}
