"""SAMP Layer-2: BERT-style encoder parameterized by a per-layer PrecisionPlan.

This is the paper's Self-Adaptive Mixed-Precision Encoder (§3.2, Fig 2) as a
JAX compute graph.  Every quantized hot-spot calls the L1 Pallas kernels
(:mod:`compile.kernels`) so they lower into the same HLO module; ``aot.py``
traces one module per (task, precision-variant) pair and the Rust coordinator
picks among them at serving time.

Precision plan semantics (one mode string per Transformer layer):

  ``fp32``      — all GEMMs FP32 (PyTorch-style baseline numerics)
  ``fp16``      — all GEMMs FP16 with FP32 accumulation (tensor-core analogue)
  ``int8_ffn``  — Quant-FFN-Only (Fig 2b): MHA stays floating point, the two
                  FFN GEMMs run INT8; activations are quantized after the
                  post-MHA LayerNorm and requantized after GELU.
  ``int8_full`` — Fully-Quant (Fig 2a): the six MHA GEMMs (QKV projections,
                  QK^T, PV, output projection) *and* both FFN GEMMs run INT8;
                  the inter-kernel dataflow stays 8-bit, including the
                  attention probabilities (softmax output) — the Appendix-B
                  accuracy culprit.

The paper's "k of 12 layers quantized" sweep quantizes a prefix of layers
(layers 0..k-1); when layer 0 is ``int8_full`` the embedding output itself is
quantized inside the fused embedding kernel, which is the Fig-2a trick of
making the encoder input INT8 for free.

Calibration scales arrive as a :class:`ScaleSet` (see calib.py) and are baked
into the traced graph as constants, mirroring the paper's fixed-at-build-time
scales (Appendix B).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import (attention, bias_gelu, bias_residual_layernorm,
                      fused_embedding, int8_matmul, quantize, softmax_quant)

# Layer precision modes.
FP32 = "fp32"
FP16 = "fp16"
INT8_FFN = "int8_ffn"
INT8_FULL = "int8_full"
MODES = (FP32, FP16, INT8_FFN, INT8_FULL)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static geometry of the encoder + downstream head."""
    vocab_size: int = 2048
    hidden: int = 128
    layers: int = 12
    heads: int = 4
    ffn: int = 512
    max_len: int = 128
    type_vocab: int = 2
    num_labels: int = 2
    head_type: str = "classification"   # classification | matching | ner
    layer_norm_eps: float = 1e-12

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """Per-layer numeric mode + the floating dtype used by non-INT8 math."""
    layer_modes: tuple
    fp_dtype: Any = jnp.float16     # dtype of the fp pipeline (fp16 per paper)

    def __post_init__(self):
        for m in self.layer_modes:
            assert m in MODES, m

    @property
    def embedding_quant(self) -> bool:
        """Fig 2a: encoder input is INT8 iff the first layer is Fully-Quant."""
        return self.layer_modes[0] == INT8_FULL

    @staticmethod
    def uniform(mode: str, layers: int, fp_dtype=jnp.float16) -> "PrecisionPlan":
        return PrecisionPlan(tuple([mode] * layers), fp_dtype)

    @staticmethod
    def prefix(mode: str, k: int, layers: int, rest: str = FP16,
               fp_dtype=jnp.float16) -> "PrecisionPlan":
        """The paper's sweep: first ``k`` layers in ``mode``, rest floating."""
        assert 0 <= k <= layers
        return PrecisionPlan(tuple([mode] * k + [rest] * (layers - k)), fp_dtype)

    def name(self) -> str:
        """Stable identifier used for artifact file names."""
        n_full = sum(m == INT8_FULL for m in self.layer_modes)
        n_ffn = sum(m == INT8_FFN for m in self.layer_modes)
        base = jnp.dtype(self.fp_dtype).name
        if n_full == 0 and n_ffn == 0:
            return base
        if n_full and not n_ffn:
            return f"full_quant_{n_full}of{len(self.layer_modes)}_{base}"
        if n_ffn and not n_full:
            return f"ffn_only_{n_ffn}of{len(self.layer_modes)}_{base}"
        return "mixed_" + "".join(
            {"fp32": "F", "fp16": "H", "int8_ffn": "f", "int8_full": "q"}[m]
            for m in self.layer_modes)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """BERT-style initialization (trunc-normal 0.02), numpy pytree."""
    rng = np.random.default_rng(seed)

    def tn(*shape):
        return np.clip(rng.normal(0.0, 0.02, shape), -0.04, 0.04).astype(np.float32)

    p: Dict[str, np.ndarray] = {
        "emb/tok": tn(cfg.vocab_size, cfg.hidden),
        "emb/seg": tn(cfg.type_vocab, cfg.hidden),
        "emb/pos": tn(cfg.max_len, cfg.hidden),
        "emb/ln_g": np.ones(cfg.hidden, np.float32),
        "emb/ln_b": np.zeros(cfg.hidden, np.float32),
        "pool/w": tn(cfg.hidden, cfg.hidden),
        "pool/b": np.zeros(cfg.hidden, np.float32),
        "head/w": tn(cfg.hidden, cfg.num_labels),
        "head/b": np.zeros(cfg.num_labels, np.float32),
    }
    for l in range(cfg.layers):
        pre = f"l{l}/"
        for nm, shape in [
            ("wq", (cfg.hidden, cfg.hidden)), ("wk", (cfg.hidden, cfg.hidden)),
            ("wv", (cfg.hidden, cfg.hidden)), ("wo", (cfg.hidden, cfg.hidden)),
            ("w1", (cfg.hidden, cfg.ffn)), ("w2", (cfg.ffn, cfg.hidden)),
        ]:
            p[pre + nm] = tn(*shape)
        for nm, size in [("bq", cfg.hidden), ("bk", cfg.hidden), ("bv", cfg.hidden),
                         ("bo", cfg.hidden), ("b1", cfg.ffn), ("b2", cfg.hidden)]:
            p[pre + nm] = np.zeros(size, np.float32)
        for nm in ["ln1_g", "ln2_g"]:
            p[pre + nm] = np.ones(cfg.hidden, np.float32)
        for nm in ["ln1_b", "ln2_b"]:
            p[pre + nm] = np.zeros(cfg.hidden, np.float32)
    return p


# Calibration tap names collected per layer (see calib.py / DESIGN.md §2-L2).
LAYER_TAPS = ("attn_in", "q_out", "k_out", "v_out", "p_out", "ctx",
              "ffn_in", "act", "layer_out")
GLOBAL_TAPS = ("emb_out",)


class ScaleSet:
    """Per-tensor symmetric INT8 scales for every quantization point.

    Keys: ``emb_out`` and ``l{i}/{tap}`` for tap in LAYER_TAPS, plus weight
    scales ``l{i}/w{q,k,v,o,1,2}`` computed directly from the weights.
    Missing keys default to 1.0 (only legitimate for never-quantized points).
    """

    def __init__(self, scales: Optional[Dict[str, float]] = None):
        self.scales = dict(scales or {})

    def __getitem__(self, key: str) -> float:
        return float(self.scales.get(key, 1.0))

    def __setitem__(self, key: str, value: float):
        self.scales[key] = float(value)

    def __contains__(self, key):
        return key in self.scales

    def to_dict(self) -> Dict[str, float]:
        return dict(self.scales)

    @staticmethod
    def weight_scales(params: Dict[str, np.ndarray], layers: int) -> Dict[str, float]:
        """Min-max symmetric weight scales (weights need no data calibration)."""
        out = {}
        for l in range(layers):
            for w in ("wq", "wk", "wv", "wo", "w1", "w2"):
                amax = float(np.abs(params[f"l{l}/{w}"]).max())
                out[f"l{l}/{w}"] = amax / 127.0 if amax > 0 else 1.0
        return out


# ---------------------------------------------------------------------------
# Encoder forward
# ---------------------------------------------------------------------------

def _fp_matmul(x, w, b, dtype):
    """Floating GEMM with f32 accumulation (tensor-core FP16 semantics)."""
    y = jax.lax.dot_general(
        x.astype(dtype), w.astype(dtype),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return (y + b).astype(dtype)


def _split_heads(x, b, s, heads, hd):
    # [B*S, H] -> [B*heads, S, hd]
    return (x.reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
            .reshape(b * heads, s, hd))


def _merge_heads(x, b, s, heads, hd):
    return (x.reshape(b, heads, s, hd).transpose(0, 2, 1, 3)
            .reshape(b * s, heads * hd))


def _int8_bmm(qa, qb_t, sa, sb):
    """Batched INT8 GEMM (QK^T / PV): int8 operands, int32 accumulation.

    The cuBLAS strided-batched INT8 GEMM analogue — per DESIGN.md the fused
    Pallas kernels cover SAMP's custom fusions while batched GEMMs map to the
    library GEMM, here ``lax.dot_general`` over the batch dim.
    Contracts last dim of ``qa`` with last dim of ``qb_t`` ([R,M,D]x[R,N,D]).
    """
    acc = jax.lax.dot_general(
        qa, qb_t,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (sa * sb)


def _layer_fp(h, p, l, cfg, b, s, mask_bias, dtype, eps):
    """FP32/FP16 Transformer layer: fused attention + fused LN epilogues."""
    pre = f"l{l}/"
    q = _fp_matmul(h, p[pre + "wq"], p[pre + "bq"], dtype)
    k = _fp_matmul(h, p[pre + "wk"], p[pre + "bk"], dtype)
    v = _fp_matmul(h, p[pre + "wv"], p[pre + "bv"], dtype)
    hd = cfg.head_dim
    qh = _split_heads(q, b, s, cfg.heads, hd)
    kh = _split_heads(k, b, s, cfg.heads, hd)
    vh = _split_heads(v, b, s, cfg.heads, hd)
    mb = jnp.repeat(mask_bias, cfg.heads, axis=0)          # [B*heads, S]
    ctx = attention(qh, kh, vh, mb, 1.0 / np.sqrt(hd))
    ctx = _merge_heads(ctx, b, s, cfg.heads, hd)
    attn_out = jax.lax.dot_general(
        ctx.astype(dtype), p[pre + "wo"].astype(dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    h1 = bias_residual_layernorm(
        attn_out.astype(jnp.float32), p[pre + "bo"], h.astype(jnp.float32),
        p[pre + "ln1_g"], p[pre + "ln1_b"], eps=eps, out_dtype=dtype)
    ffn1 = jax.lax.dot_general(
        h1.astype(dtype), p[pre + "w1"].astype(dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    act = bias_gelu(ffn1, p[pre + "b1"], out_dtype=dtype)
    ffn2 = jax.lax.dot_general(
        act.astype(dtype), p[pre + "w2"].astype(dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    h2 = bias_residual_layernorm(
        ffn2, p[pre + "b2"], h1.astype(jnp.float32),
        p[pre + "ln2_g"], p[pre + "ln2_b"], eps=eps, out_dtype=dtype)
    return h2


def _layer_ffn_only(h, p, l, cfg, b, s, mask_bias, dtype, sc: ScaleSet,
                    qw, eps):
    """Quant-FFN-Only layer (Fig 2b): FP MHA, INT8 FFN."""
    pre = f"l{l}/"
    q = _fp_matmul(h, p[pre + "wq"], p[pre + "bq"], dtype)
    k = _fp_matmul(h, p[pre + "wk"], p[pre + "bk"], dtype)
    v = _fp_matmul(h, p[pre + "wv"], p[pre + "bv"], dtype)
    hd = cfg.head_dim
    qh = _split_heads(q, b, s, cfg.heads, hd)
    kh = _split_heads(k, b, s, cfg.heads, hd)
    vh = _split_heads(v, b, s, cfg.heads, hd)
    mb = jnp.repeat(mask_bias, cfg.heads, axis=0)
    ctx = attention(qh, kh, vh, mb, 1.0 / np.sqrt(hd))
    ctx = _merge_heads(ctx, b, s, cfg.heads, hd)
    attn_out = jax.lax.dot_general(
        ctx.astype(dtype), p[pre + "wo"].astype(dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # Fig 2b: quantize the floating-point result after the post-MHA LayerNorm.
    h1_q = bias_residual_layernorm(
        attn_out.astype(jnp.float32), p[pre + "bo"], h.astype(jnp.float32),
        p[pre + "ln1_g"], p[pre + "ln1_b"], eps=eps,
        out_scale=sc[f"l{l}/ffn_in"])
    # Residual of the FFN block is the (dequantized) LN1 output: in the real
    # engine the INT8 tensor itself is the residual, so we reuse it.
    ffn1 = int8_matmul(h1_q, qw[pre + "w1"], sc[f"l{l}/ffn_in"],
                       sc[f"l{l}/w1"])
    act_q = bias_gelu(ffn1, p[pre + "b1"], out_scale=sc[f"l{l}/act"])
    ffn2 = int8_matmul(act_q, qw[pre + "w2"], sc[f"l{l}/act"], sc[f"l{l}/w2"])
    # Last big kernel of the layer: floating output (Fig 2b "the only
    # difference is that quantization is not used in the last big kernel").
    h2 = bias_residual_layernorm(
        ffn2, p[pre + "b2"], h1_q, p[pre + "ln2_g"], p[pre + "ln2_b"],
        residual_scale=sc[f"l{l}/ffn_in"], eps=eps, out_dtype=dtype)
    return h2


def _layer_full(h_q, p, l, cfg, b, s, mask_bias, dtype, sc: ScaleSet, qw,
                eps, out_int8: bool):
    """Fully-Quant layer (Fig 2a): INT8 MHA + INT8 FFN, INT8 dataflow.

    ``h_q`` is int8 with scale ``l{l}/attn_in``; returns int8 with scale
    ``l{l}/layer_out`` when ``out_int8`` (next layer also Fully-Quant), else
    floating ``dtype``.
    """
    pre = f"l{l}/"
    s_in = sc[f"l{l}/attn_in"]
    # QKV projections: INT8 GEMM, requantized outputs feed the INT8 QK^T/PV.
    qq = int8_matmul(h_q, qw[pre + "wq"], s_in, sc[f"l{l}/wq"], p[pre + "bq"],
                     out_scale=sc[f"l{l}/q_out"])
    qk = int8_matmul(h_q, qw[pre + "wk"], s_in, sc[f"l{l}/wk"], p[pre + "bk"],
                     out_scale=sc[f"l{l}/k_out"])
    qv = int8_matmul(h_q, qw[pre + "wv"], s_in, sc[f"l{l}/wv"], p[pre + "bv"],
                     out_scale=sc[f"l{l}/v_out"])
    hd = cfg.head_dim
    qh = _split_heads(qq, b, s, cfg.heads, hd)
    kh = _split_heads(qk, b, s, cfg.heads, hd)
    vh = _split_heads(qv, b, s, cfg.heads, hd)
    # INT8 QK^T with INT32 accumulation, dequant by s_q*s_k.
    scores = _int8_bmm(qh, kh, sc[f"l{l}/q_out"], sc[f"l{l}/k_out"])
    scores = scores * (1.0 / np.sqrt(hd))
    mb = jnp.repeat(mask_bias, cfg.heads, axis=0)          # [B*heads, S]
    # Fused softmax + quantize: P is INT8 — the Appendix-B accuracy culprit.
    r = b * cfg.heads
    p_q = softmax_quant(scores.reshape(r * s, s),
                        jnp.repeat(mb, s, axis=0).reshape(r * s, s),
                        out_scale=sc[f"l{l}/p_out"]).reshape(r, s, s)
    # INT8 PV GEMM: contract over keys.
    ctx = _int8_bmm(p_q, vh.transpose(0, 2, 1), sc[f"l{l}/p_out"],
                    sc[f"l{l}/v_out"])                     # [R, S, hd] f32
    ctx_q = quantize(ctx, sc[f"l{l}/ctx"])
    ctx_q = _merge_heads(ctx_q, b, s, cfg.heads, hd)
    # Output projection INT8; epilogue handled by the fused big kernel.
    attn_out = int8_matmul(ctx_q, qw[pre + "wo"], sc[f"l{l}/ctx"],
                           sc[f"l{l}/wo"])
    h1_q = bias_residual_layernorm(
        attn_out, p[pre + "bo"], h_q, p[pre + "ln1_g"], p[pre + "ln1_b"],
        residual_scale=s_in, eps=eps, out_scale=sc[f"l{l}/ffn_in"])
    ffn1 = int8_matmul(h1_q, qw[pre + "w1"], sc[f"l{l}/ffn_in"], sc[f"l{l}/w1"])
    act_q = bias_gelu(ffn1, p[pre + "b1"], out_scale=sc[f"l{l}/act"])
    ffn2 = int8_matmul(act_q, qw[pre + "w2"], sc[f"l{l}/act"], sc[f"l{l}/w2"])
    h2 = bias_residual_layernorm(
        ffn2, p[pre + "b2"], h1_q, p[pre + "ln2_g"], p[pre + "ln2_b"],
        residual_scale=sc[f"l{l}/ffn_in"], eps=eps,
        out_scale=sc[f"l{l}/layer_out"] if out_int8 else None,
        out_dtype=None if out_int8 else dtype)
    return h2


def quantize_weights(params: Dict[str, np.ndarray], cfg: ModelConfig,
                     sc: ScaleSet) -> Dict[str, jnp.ndarray]:
    """Pre-quantize all GEMM weights (done once at engine build)."""
    qw = {}
    for l in range(cfg.layers):
        for w in ("wq", "wk", "wv", "wo", "w1", "w2"):
            key = f"l{l}/{w}"
            qw[key] = quantize(jnp.asarray(params[key]), sc[key])
    return qw


def encoder_forward(params, cfg: ModelConfig, plan: PrecisionPlan,
                    token_ids, segment_ids, attn_mask,
                    scales: Optional[ScaleSet] = None):
    """Run the mixed-precision encoder.

    Args:
      params: numpy/jnp param dict from :func:`init_params` (or trained).
      plan:   per-layer precision plan.
      token_ids, segment_ids: int32 [B, S]; attn_mask: f32/int [B, S] 1=keep.
      scales: calibration ScaleSet (required if any layer is INT8).

    Returns: float32 [B, S, H] final hidden states.
    """
    sc = scales or ScaleSet()
    b, s = token_ids.shape
    dtype = plan.fp_dtype
    eps = cfg.layer_norm_eps
    mask_bias = (1.0 - attn_mask.astype(jnp.float32)) * -1e9   # [B, S]

    needs_q = any(m in (INT8_FFN, INT8_FULL) for m in plan.layer_modes)
    qw = quantize_weights(params, cfg, sc) if needs_q else {}

    emb_scale = sc["emb_out"] if plan.embedding_quant else None
    h = fused_embedding(token_ids, segment_ids,
                        jnp.asarray(params["emb/tok"]),
                        jnp.asarray(params["emb/seg"]),
                        jnp.asarray(params["emb/pos"]),
                        jnp.asarray(params["emb/ln_g"]),
                        jnp.asarray(params["emb/ln_b"]),
                        out_scale=emb_scale, eps=eps)
    h = h.reshape(b * s, cfg.hidden)
    if not plan.embedding_quant:
        h = h.astype(dtype)

    for l, mode in enumerate(plan.layer_modes):
        if mode == INT8_FULL:
            if h.dtype != jnp.int8:
                # Mode boundary fp -> int8: quantize with this layer's scale.
                h = quantize(h.astype(jnp.float32), sc[f"l{l}/attn_in"])
            nxt_full = (l + 1 < cfg.layers
                        and plan.layer_modes[l + 1] == INT8_FULL)
            h = _layer_full(h, params, l, cfg, b, s, mask_bias, dtype, sc, qw,
                            eps, out_int8=nxt_full)
        else:
            if h.dtype == jnp.int8:
                # int8 -> fp boundary (never happens in prefix plans, but the
                # graph supports arbitrary mode interleavings).
                h = (h.astype(jnp.float32) *
                     sc[f"l{l-1}/layer_out"]).astype(dtype)
            if mode == INT8_FFN:
                h = _layer_ffn_only(h, params, l, cfg, b, s, mask_bias, dtype,
                                    sc, qw, eps)
            elif mode == FP16:
                h = _layer_fp(h, params, l, cfg, b, s, mask_bias,
                              jnp.float16, eps)
            else:
                h = _layer_fp(h, params, l, cfg, b, s, mask_bias,
                              jnp.float32, eps)
    if h.dtype == jnp.int8:
        h = h.astype(jnp.float32) * sc[f"l{cfg.layers-1}/layer_out"]
    return h.astype(jnp.float32).reshape(b, s, cfg.hidden)


# ---------------------------------------------------------------------------
# Differentiable pure-jnp forward (training path)
# ---------------------------------------------------------------------------

def encoder_forward_ref(params, cfg: ModelConfig, token_ids, segment_ids,
                        attn_mask):
    """FP32 forward built only from jnp ops — the *training* path.

    Interpret-mode Pallas calls do not support reverse-mode autodiff, and the
    paper trains in a standard framework anyway (PyTorch); inference engines
    never backprop.  This path is the training-framework analogue; parity with
    the Pallas inference path is enforced by python/tests/test_model.py.
    """
    from .kernels import ref as R

    b, s = token_ids.shape
    p = params
    eps = cfg.layer_norm_eps
    mask_bias = (1.0 - attn_mask.astype(jnp.float32)) * -1e9
    h = R.ref_fused_embedding(token_ids, segment_ids, p["emb/tok"],
                              p["emb/seg"], p["emb/pos"], p["emb/ln_g"],
                              p["emb/ln_b"]).reshape(b * s, cfg.hidden)
    hd = cfg.head_dim
    for l in range(cfg.layers):
        pre = f"l{l}/"
        q = h @ p[pre + "wq"] + p[pre + "bq"]
        k = h @ p[pre + "wk"] + p[pre + "bk"]
        v = h @ p[pre + "wv"] + p[pre + "bv"]
        qh = _split_heads(q, b, s, cfg.heads, hd)
        kh = _split_heads(k, b, s, cfg.heads, hd)
        vh = _split_heads(v, b, s, cfg.heads, hd)
        mb = jnp.repeat(mask_bias, cfg.heads, axis=0)
        ctx = R.ref_attention(qh, kh, vh, mb, 1.0 / np.sqrt(hd))
        ctx = _merge_heads(ctx, b, s, cfg.heads, hd)
        h1 = R.ref_bias_residual_layernorm(ctx @ p[pre + "wo"], p[pre + "bo"],
                                           h, p[pre + "ln1_g"],
                                           p[pre + "ln1_b"], eps=eps)
        act = R.ref_bias_gelu(h1 @ p[pre + "w1"], p[pre + "b1"])
        h = R.ref_bias_residual_layernorm(act @ p[pre + "w2"], p[pre + "b2"],
                                          h1, p[pre + "ln2_g"],
                                          p[pre + "ln2_b"], eps=eps)
    return h.reshape(b, s, cfg.hidden)


# ---------------------------------------------------------------------------
# Calibration-tap forward (FP32, returns intermediate activations)
# ---------------------------------------------------------------------------

def encoder_forward_with_taps(params, cfg: ModelConfig, token_ids, segment_ids,
                              attn_mask):
    """FP32 forward that also returns every calibration-tap activation.

    Used by calib.py (PTQ needs the float activation distribution at each
    quantization point) and by the Fig-4 distribution study (taps ``p_out``
    and ``ctx``).
    """
    b, s = token_ids.shape
    eps = cfg.layer_norm_eps
    p = params
    taps: Dict[str, jnp.ndarray] = {}
    mask_bias = (1.0 - attn_mask.astype(jnp.float32)) * -1e9

    emb = fused_embedding(token_ids, segment_ids,
                          jnp.asarray(p["emb/tok"]), jnp.asarray(p["emb/seg"]),
                          jnp.asarray(p["emb/pos"]), jnp.asarray(p["emb/ln_g"]),
                          jnp.asarray(p["emb/ln_b"]), eps=eps)
    h = emb.reshape(b * s, cfg.hidden)
    taps["emb_out"] = h

    hd = cfg.head_dim
    for l in range(cfg.layers):
        pre = f"l{l}/"
        taps[f"l{l}/attn_in"] = h
        q = _fp_matmul(h, p[pre + "wq"], p[pre + "bq"], jnp.float32)
        k = _fp_matmul(h, p[pre + "wk"], p[pre + "bk"], jnp.float32)
        v = _fp_matmul(h, p[pre + "wv"], p[pre + "bv"], jnp.float32)
        taps[f"l{l}/q_out"], taps[f"l{l}/k_out"], taps[f"l{l}/v_out"] = q, k, v
        qh = _split_heads(q, b, s, cfg.heads, hd)
        kh = _split_heads(k, b, s, cfg.heads, hd)
        vh = _split_heads(v, b, s, cfg.heads, hd)
        mb = jnp.repeat(mask_bias, cfg.heads, axis=0)
        scores = jnp.einsum("rqd,rkd->rqk", qh, kh) / np.sqrt(hd)
        scores = scores + mb[:, None, :]
        probs = jax.nn.softmax(scores, axis=-1)
        taps[f"l{l}/p_out"] = probs
        ctx = jnp.einsum("rqk,rkd->rqd", probs, vh)
        ctx = _merge_heads(ctx, b, s, cfg.heads, hd)
        taps[f"l{l}/ctx"] = ctx
        attn_out = ctx @ p[pre + "wo"]
        h1 = bias_residual_layernorm(attn_out, p[pre + "bo"], h,
                                     p[pre + "ln1_g"], p[pre + "ln1_b"], eps=eps)
        taps[f"l{l}/ffn_in"] = h1
        act = bias_gelu(h1 @ p[pre + "w1"], p[pre + "b1"])
        taps[f"l{l}/act"] = act
        h2 = bias_residual_layernorm(act @ p[pre + "w2"], p[pre + "b2"], h1,
                                     p[pre + "ln2_g"], p[pre + "ln2_b"], eps=eps)
        taps[f"l{l}/layer_out"] = h2
        h = h2
    return h.reshape(b, s, cfg.hidden), taps


# ---------------------------------------------------------------------------
# Downstream-task heads (the paper's Target module)
# ---------------------------------------------------------------------------

def head_forward(params, cfg: ModelConfig, hidden):
    """Downstream target layer on the encoder output.

    classification / matching: tanh pooler over [CLS] then linear -> [B, C].
    ner: per-token linear -> [B, S, C].
    """
    if cfg.head_type in ("classification", "matching"):
        cls = hidden[:, 0, :]                              # [B, H]
        pooled = jnp.tanh(cls @ params["pool/w"] + params["pool/b"])
        return pooled @ params["head/w"] + params["head/b"]
    elif cfg.head_type == "ner":
        return hidden @ params["head/w"] + params["head/b"]
    raise ValueError(f"unknown head_type {cfg.head_type}")


def model_forward(params, cfg: ModelConfig, plan: PrecisionPlan,
                  token_ids, segment_ids, attn_mask,
                  scales: Optional[ScaleSet] = None):
    """Full model: encoder + head. Convenience for python-side evaluation."""
    hidden = encoder_forward(params, cfg, plan, token_ids, segment_ids,
                             attn_mask, scales)
    return head_forward(params, cfg, hidden)
