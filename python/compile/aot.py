"""AOT pipeline: train -> calibrate -> lower every precision variant to HLO text.

This is the single build-time Python entrypoint (``make artifacts``).  It
produces everything the Rust coordinator needs to serve with Python fully out
of the request path:

  artifacts/
    manifest.json              - the engine manifest (models, variants, shapes,
                                 scales, dev accuracy, golden digests)
    vocab.txt                  - shared vocabulary for the Rust tokenizer
    weights/{task}.npz         - trained FP32 weights (build cache)
    hlo/{task}/encoder_{variant}.hlo.txt
    hlo/{task}/head.hlo.txt
    data/{task}_dev.bin        - pre-tokenized dev set (SAMP binary format)
    data/{task}_dev.jsonl      - dev set as text for the end-to-end path
    goldens/{task}_{variant}.json - logits of a fixed batch, for the Rust
                                 integration tests (runtime parity)
    model.hlo.txt              - compatibility alias of the default variant

Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the Rust ``xla`` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.

Variant grid (the Table-2 sweep): for every task,
  fp32, fp16,
  full_quant_k  for k in {2,4,6,8,10,12}   (Fully-Quant prefix, Fig 2a)
  ffn_only_k    for k in {2,4,6,8,10,12}   (Quant-FFN-Only prefix, Fig 2b)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import struct
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .calib import CALIBRATORS, calibrate_model
from .model import (FP16, FP32, INT8_FFN, INT8_FULL, ModelConfig,
                    PrecisionPlan, ScaleSet, encoder_forward, head_forward)
from .train import TrainSettings, config_for_task, load_params, save_params, train_task

# Serving batch size baked into the static shapes (the Rust dynamic batcher
# pads to this).  One executable per (task, variant); heads are per-task.
SERVE_BATCH = 8

DEFAULT_TASKS = ("tnews", "afqmc", "iflytek", "cluener")
SWEEP_KS = (2, 4, 6, 8, 10, 12)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True is ESSENTIAL: the default printer elides big
    # weight tensors as `{...}` and xla_extension 0.5.1's text parser then
    # silently fills them with garbage (discovered the hard way — zeros/NaN
    # from every compiled artifact).
    return comp.as_hlo_text(print_large_constants=True)


def variant_plans(layers: int) -> Dict[str, PrecisionPlan]:
    """The Table-2 variant grid, keyed by stable variant name."""
    plans: Dict[str, PrecisionPlan] = {
        "fp32": PrecisionPlan.uniform(FP32, layers, fp_dtype=jnp.float32),
        "fp16": PrecisionPlan.uniform(FP16, layers, fp_dtype=jnp.float16),
    }
    for k in SWEEP_KS:
        if k > layers:
            continue
        plans[f"full_quant_{k}"] = PrecisionPlan.prefix(INT8_FULL, k, layers)
        plans[f"ffn_only_{k}"] = PrecisionPlan.prefix(INT8_FFN, k, layers)
    return plans


def lower_encoder(params, cfg: ModelConfig, plan: PrecisionPlan,
                  scales: ScaleSet, batch: int) -> str:
    """Lower the (embedding + encoder) bundle for one precision variant."""
    p_dev = {k: jnp.asarray(v) for k, v in params.items()}

    def fn(ids, segs, mask):
        return (encoder_forward(p_dev, cfg, plan, ids, segs, mask, scales),)

    spec_i = jax.ShapeDtypeStruct((batch, cfg.max_len), jnp.int32)
    spec_m = jax.ShapeDtypeStruct((batch, cfg.max_len), jnp.float32)
    lowered = jax.jit(fn).lower(spec_i, spec_i, spec_m)
    return to_hlo_text(lowered)


def lower_head(params, cfg: ModelConfig, batch: int) -> str:
    """Lower the downstream target layer (classification/matching/NER head)."""
    p_dev = {k: jnp.asarray(v) for k, v in params.items()}

    def fn(hidden):
        return (head_forward(p_dev, cfg, hidden),)

    spec = jax.ShapeDtypeStruct((batch, cfg.max_len, cfg.hidden), jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# SAMP binary dataset format (read by rust/src/data/)
# ---------------------------------------------------------------------------

def write_dataset_bin(path: str, ids, segs, mask, labels, per_token: bool):
    """Format: magic 'SAMPDAT1', n:u32, seq:u32, per_token:u8, pad[3],
    then i32 arrays: ids[n*seq], segs[n*seq], mask[n*seq],
    labels[n*seq if per_token else n]."""
    n, seq = ids.shape
    with open(path, "wb") as f:
        f.write(b"SAMPDAT1")
        f.write(struct.pack("<IIB3x", n, seq, 1 if per_token else 0))
        for arr in (ids, segs, mask):
            f.write(np.ascontiguousarray(arr, dtype="<i4").tobytes())
        f.write(np.ascontiguousarray(labels, dtype="<i4").tobytes())


def write_dataset_jsonl(path: str, ids, labels, per_token: bool):
    with open(path, "w") as f:
        for i in range(len(ids)):
            text = data_mod.render_text(ids[i])
            label = (labels[i].tolist() if per_token else int(labels[i]))
            f.write(json.dumps({"text": text, "label": label},
                               ensure_ascii=False) + "\n")


# ---------------------------------------------------------------------------
# Build steps
# ---------------------------------------------------------------------------

def _sha256(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def build_task(task: str, out_dir: str, calibrator: str, train_steps: int,
               calib_batches: int, quick: bool) -> dict:
    """Train (or load cached), calibrate, lower all variants for one task."""
    t_start = time.time()
    cfg = config_for_task(task) if not quick else config_for_task(
        task, layers=4, hidden=64)
    wpath = os.path.join(out_dir, "weights", f"{task}.npz")
    rpath = os.path.join(out_dir, "weights", f"{task}.report.json")
    if os.path.exists(wpath) and os.path.exists(rpath):
        print(f"[aot:{task}] loading cached weights {wpath}")
        params = load_params(wpath)
        report = json.load(open(rpath))
    else:
        print(f"[aot:{task}] training FP32 baseline ({cfg.layers}L-{cfg.hidden}H)")
        params, cfg, report = train_task(task, cfg,
                                         TrainSettings(steps=train_steps))
        save_params(wpath, params)
        json.dump(report, open(rpath, "w"), indent=1)

    # --- calibration (PTQ: no training data labels needed) ---
    spec = data_mod.TASKS[task]
    c_ids, c_segs, c_mask, _ = data_mod.generate(task, "calib",
                                                 n=calib_batches * 16)
    cal = [(jnp.asarray(c_ids[i:i + 16]), jnp.asarray(c_segs[i:i + 16]),
            jnp.asarray(c_mask[i:i + 16].astype(np.float32)))
           for i in range(0, len(c_ids), 16)]
    print(f"[aot:{task}] calibrating ({calibrator}, {len(cal)} batches)")
    scales = ScaleSet(calibrate_model(params, cfg, cal, calibrator))

    # --- datasets for the Rust side ---
    d_ids, d_segs, d_mask, d_labels = data_mod.generate(task, "dev")
    per_token = spec.kind == "ner"
    os.makedirs(os.path.join(out_dir, "data"), exist_ok=True)
    write_dataset_bin(os.path.join(out_dir, "data", f"{task}_dev.bin"),
                      d_ids, d_segs, d_mask, d_labels, per_token)
    write_dataset_jsonl(os.path.join(out_dir, "data", f"{task}_dev.jsonl"),
                        d_ids, d_labels, per_token)

    # --- lower encoder variants + head ---
    hlo_dir = os.path.join(out_dir, "hlo", task)
    os.makedirs(hlo_dir, exist_ok=True)
    plans = variant_plans(cfg.layers)
    if task == "cluener":
        # NER is a Table-1 capability demo, not part of the Table-2 sweep:
        # three representative variants keep the build time bounded.
        plans = {k: v for k, v in plans.items()
                 if k in ("fp32", "fp16", "ffn_only_6", "full_quant_6")}
    if quick:
        plans = {k: v for k, v in plans.items()
                 if k in ("fp32", "fp16", "full_quant_2", "ffn_only_2")}

    golden_ids = jnp.asarray(d_ids[:SERVE_BATCH])
    golden_segs = jnp.asarray(d_segs[:SERVE_BATCH])
    golden_mask = jnp.asarray(d_mask[:SERVE_BATCH].astype(np.float32))
    p_dev = {k: jnp.asarray(v) for k, v in params.items()}

    variants = {}
    os.makedirs(os.path.join(out_dir, "goldens"), exist_ok=True)
    for vname, plan in plans.items():
        t0 = time.time()
        hlo = lower_encoder(params, cfg, plan, scales, SERVE_BATCH)
        fname = f"encoder_{vname}.hlo.txt"
        with open(os.path.join(hlo_dir, fname), "w") as f:
            f.write(hlo)
        # golden logits through the *python* graph for runtime parity tests
        hidden = encoder_forward(p_dev, cfg, plan, golden_ids, golden_segs,
                                 golden_mask, scales)
        logits = np.asarray(head_forward(p_dev, cfg, hidden))
        gpath = os.path.join(out_dir, "goldens", f"{task}_{vname}.json")
        json.dump({"logits": np.round(logits.astype(float), 5).tolist()},
                  open(gpath, "w"))
        variants[vname] = {
            "hlo": f"hlo/{task}/{fname}",
            "sha256": _sha256(hlo),
            "layer_modes": list(plan.layer_modes),
            "n_full_quant": sum(m == INT8_FULL for m in plan.layer_modes),
            "n_ffn_only": sum(m == INT8_FFN for m in plan.layer_modes),
            "golden": f"goldens/{task}_{vname}.json",
        }
        print(f"[aot:{task}] lowered {vname:15s} "
              f"({len(hlo)//1024} KiB, {time.time()-t0:.1f}s)")

    head_hlo = lower_head(params, cfg, SERVE_BATCH)
    with open(os.path.join(hlo_dir, "head.hlo.txt"), "w") as f:
        f.write(head_hlo)

    return {
        "task": task,
        "kind": spec.kind,
        "num_labels": cfg.num_labels,
        "seq_len": cfg.max_len,
        "batch": SERVE_BATCH,
        "hidden": cfg.hidden,
        "layers": cfg.layers,
        "heads": cfg.heads,
        "ffn": cfg.ffn,
        "head_hlo": f"hlo/{task}/head.hlo.txt",
        "head_type": cfg.head_type,
        "dev_accuracy_fp32": report.get("dev_accuracy_fp32"),
        "train_report": {k: v for k, v in report.items() if k != "loss_curve"},
        "loss_curve": report.get("loss_curve", []),
        "calibrator": calibrator,
        "scales": scales.to_dict(),
        "variants": variants,
        "dev_data": f"data/{task}_dev.bin",
        "dev_jsonl": f"data/{task}_dev.jsonl",
        "ner_labels": data_mod.NER_LABELS if per_token else None,
        "build_seconds": round(time.time() - t_start, 1),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts output dir (default ../artifacts)")
    ap.add_argument("--tasks", default=",".join(DEFAULT_TASKS))
    ap.add_argument("--calibrator", default="minmax", choices=CALIBRATORS)
    ap.add_argument("--train-steps", type=int, default=900)
    ap.add_argument("--calib-batches", type=int, default=8)
    ap.add_argument("--quick", action="store_true",
                    help="tiny geometry + 4 variants (CI smoke)")
    ap.add_argument("--merge", action="store_true",
                    help="merge rebuilt tasks into an existing manifest.json "
                         "instead of replacing it (targeted rebuilds)")
    args = ap.parse_args(argv)

    out_dir = args.out
    # `--out ../artifacts/model.hlo.txt` (Makefile stamp) -> use its dirname.
    if out_dir.endswith(".hlo.txt"):
        out_dir = os.path.dirname(out_dir)
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)

    with open(os.path.join(out_dir, "vocab.txt"), "w") as f:
        f.write("\n".join(data_mod.build_vocab()) + "\n")

    manifest = {
        "format": 1,
        "created_unix": int(time.time()),
        "jax_version": jax.__version__,
        "serve_batch": SERVE_BATCH,
        "vocab": "vocab.txt",
        "vocab_size": data_mod.VOCAB_SIZE,
        "models": [],
    }
    mpath = os.path.join(out_dir, "manifest.json")
    for task in args.tasks.split(","):
        task = task.strip()
        if not task:
            continue
        manifest["models"].append(
            build_task(task, out_dir, args.calibrator, args.train_steps,
                       args.calib_batches, args.quick))
        # incremental write: a crash/kill mid-build still leaves a usable
        # manifest for the tasks completed so far
        with open(mpath + ".partial", "w") as f:
            json.dump(manifest, f, indent=1)

    if args.merge and os.path.exists(mpath):
        old = json.load(open(mpath))
        rebuilt = {m["task"] for m in manifest["models"]}
        kept = [m for m in old.get("models", []) if m["task"] not in rebuilt]
        manifest["models"] = kept + manifest["models"]
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)

    # Compatibility alias expected by the Makefile stamp rule.
    first = manifest["models"][0]
    alias_src = os.path.join(out_dir, first["variants"]
                             [list(first["variants"])[0]]["hlo"])
    with open(os.path.join(out_dir, "model.hlo.txt"), "w") as f:
        f.write(open(alias_src).read())
    print(f"[aot] manifest written: {os.path.join(out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
