"""Kernel-vs-oracle correctness: every Pallas kernel against its pure-jnp ref.

hypothesis sweeps shapes/dtypes/seeds; integer-output kernels must match the
oracle *bit-exactly* (quantization is deterministic), float-output kernels
must be allclose at dtype-appropriate tolerances.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (attention, bias_gelu, bias_residual_layernorm,
                             fused_embedding, int8_matmul, softmax_quant,
                             quantize, dequantize, amax_to_scale, pick_block,
                             QMIN, QMAX)
from compile.kernels import ref

# Keep hypothesis deadline off: interpret-mode pallas tracing is slow.
COMMON = dict(deadline=None, max_examples=25, derandomize=True)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# quantization primitives
# ---------------------------------------------------------------------------

class TestQuantPrimitives:
    @given(st.integers(0, 2**32 - 1), st.floats(0.01, 10.0))
    @settings(**COMMON)
    def test_roundtrip_error_bound(self, seed, scale):
        """|dequant(quant(x)) - x| <= scale/2 for x within the covered range."""
        x = _rng(seed).uniform(-scale * 126, scale * 126, 256).astype(np.float32)
        q = quantize(jnp.array(x), scale)
        x2 = np.array(dequantize(q, scale))
        assert np.abs(x2 - x).max() <= scale / 2 + 1e-6

    @given(st.integers(0, 2**32 - 1))
    @settings(**COMMON)
    def test_range_symmetric(self, seed):
        """Symmetric quantization never produces -128."""
        x = _rng(seed).normal(0, 100, 1024).astype(np.float32)
        q = np.array(quantize(jnp.array(x), 0.01))
        assert q.min() >= QMIN and q.max() <= QMAX

    def test_amax_to_scale(self):
        assert amax_to_scale(127.0) == pytest.approx(1.0)
        assert amax_to_scale(0.0) == 1.0          # degenerate tensor
        assert amax_to_scale(float("nan")) == 1.0

    def test_pick_block_divides(self):
        for dim in [1, 7, 12, 64, 96, 100, 128, 384, 1000]:
            for tgt in [1, 8, 32, 128]:
                b = pick_block(dim, tgt)
                assert dim % b == 0 and b <= max(tgt, 1)


# ---------------------------------------------------------------------------
# int8_matmul
# ---------------------------------------------------------------------------

class TestInt8Matmul:
    @given(
        st.integers(0, 2**32 - 1),
        st.sampled_from([(8, 16, 8), (32, 64, 32), (96, 64, 80), (128, 128, 128),
                         (64, 512, 128), (100, 60, 20)]),
        st.booleans(), st.booleans(),
    )
    @settings(**COMMON)
    def test_matches_ref(self, seed, shape, use_bias, quant_out):
        m, k, n = shape
        r = _rng(seed)
        qx = jnp.array(r.integers(-127, 128, (m, k), dtype=np.int8))
        qw = jnp.array(r.integers(-127, 128, (k, n), dtype=np.int8))
        bias = jnp.array(r.normal(size=n).astype(np.float32)) if use_bias else None
        sx, sw = float(r.uniform(0.001, 0.1)), float(r.uniform(0.001, 0.1))
        so = float(r.uniform(0.05, 1.0)) if quant_out else None
        got = int8_matmul(qx, qw, sx, sw, bias, out_scale=so)
        want = ref.ref_int8_matmul(qx, qw, sx, sw, bias, out_scale=so)
        if quant_out:
            assert (np.array(got) == np.array(want)).all()
        else:
            # bias broadcast order differs between kernel and ref -> f32 ULPs
            np.testing.assert_allclose(np.array(got), np.array(want),
                                       rtol=1e-6, atol=1e-4)

    def test_int32_accumulation_exact(self):
        """Accumulation must be exact int32 — max-magnitude operands, deep K."""
        k = 512
        qx = jnp.full((4, k), 127, jnp.int8)
        qw = jnp.full((k, 4), 127, jnp.int8)
        out = np.array(int8_matmul(qx, qw, 1.0, 1.0))
        assert (out == 127 * 127 * k).all()

    def test_rejects_k_mismatch(self):
        with pytest.raises(AssertionError):
            int8_matmul(jnp.zeros((4, 8), jnp.int8), jnp.zeros((9, 4), jnp.int8),
                        1.0, 1.0)


# ---------------------------------------------------------------------------
# fused_embedding
# ---------------------------------------------------------------------------

class TestFusedEmbedding:
    @given(
        st.integers(0, 2**32 - 1),
        st.sampled_from([(2, 8, 16, 32), (4, 16, 50, 32), (1, 32, 100, 64),
                         (8, 12, 64, 48)]),
        st.booleans(),
    )
    @settings(**COMMON)
    def test_matches_ref(self, seed, shape, quant_out):
        b, s, v, h = shape
        r = _rng(seed)
        tt = jnp.array(r.normal(size=(v, h)).astype(np.float32))
        sgt = jnp.array(r.normal(size=(2, h)).astype(np.float32))
        pt = jnp.array(r.normal(size=(s + 4, h)).astype(np.float32))
        g = jnp.array(r.normal(size=h).astype(np.float32))
        bt = jnp.array(r.normal(size=h).astype(np.float32))
        ids = jnp.array(r.integers(0, v, (b, s)).astype(np.int32))
        segs = jnp.array(r.integers(0, 2, (b, s)).astype(np.int32))
        so = 0.08 if quant_out else None
        got = fused_embedding(ids, segs, tt, sgt, pt, g, bt, out_scale=so)
        want = ref.ref_fused_embedding(ids, segs, tt, sgt, pt, g, bt, out_scale=so)
        if quant_out:
            assert (np.array(got) == np.array(want)).all()
        else:
            np.testing.assert_allclose(np.array(got), np.array(want),
                                       rtol=1e-5, atol=1e-5)

    def test_position_embedding_applied(self):
        """Identical tokens at different positions embed differently.

        (The position rows must be non-affine-equivalent — LayerNorm removes
        per-row shift/scale — so use random rows.)"""
        v, h, s = 10, 8, 4
        r = _rng(11)
        tt = jnp.zeros((v, h)); sgt = jnp.zeros((2, h))
        pt = jnp.array(r.normal(size=(s, h)).astype(np.float32))
        g = jnp.ones(h); bt = jnp.zeros(h)
        ids = jnp.zeros((1, s), jnp.int32); segs = jnp.zeros((1, s), jnp.int32)
        out = np.array(fused_embedding(ids, segs, tt, sgt, pt, g, bt))
        assert not np.allclose(out[0, 0], out[0, 1])


# ---------------------------------------------------------------------------
# fused big-kernel epilogues
# ---------------------------------------------------------------------------

class TestBiasResidualLayerNorm:
    @given(
        st.integers(0, 2**32 - 1),
        st.sampled_from([(16, 32), (64, 64), (128, 128), (60, 48)]),
        st.sampled_from(["fp", "quant_in", "quant_all"]),
    )
    @settings(**COMMON)
    def test_matches_ref(self, seed, shape, mode):
        r_, h_ = shape
        r = _rng(seed)
        bias = jnp.array(r.normal(size=h_).astype(np.float32))
        g = jnp.array(r.normal(size=h_).astype(np.float32))
        bt = jnp.array(r.normal(size=h_).astype(np.float32))
        if mode == "fp":
            x = jnp.array(r.normal(size=(r_, h_)).astype(np.float32))
            res = jnp.array(r.normal(size=(r_, h_)).astype(np.float32))
            kw = {}
        else:
            x = jnp.array(r.integers(-10**5, 10**5, (r_, h_), dtype=np.int32))
            res = jnp.array(r.integers(-127, 128, (r_, h_), dtype=np.int8))
            kw = dict(x_scale=1e-4, residual_scale=0.05)
            if mode == "quant_all":
                kw["out_scale"] = 0.07
        got = bias_residual_layernorm(x, bias, res, g, bt, **kw)
        want = ref.ref_bias_residual_layernorm(x, bias, res, g, bt, **kw)
        if mode == "quant_all":
            assert (np.array(got) == np.array(want)).all()
        else:
            np.testing.assert_allclose(np.array(got), np.array(want),
                                       rtol=1e-4, atol=1e-5)

    def test_fp16_output_dtype(self):
        x = jnp.zeros((8, 16), jnp.float32)
        out = bias_residual_layernorm(x, jnp.zeros(16), x, jnp.ones(16),
                                      jnp.zeros(16), out_dtype=jnp.float16)
        assert out.dtype == jnp.float16


class TestBiasGelu:
    @given(st.integers(0, 2**32 - 1),
           st.sampled_from([(16, 32), (64, 128), (100, 20)]),
           st.booleans(), st.booleans())
    @settings(**COMMON)
    def test_matches_ref(self, seed, shape, quant_in, quant_out):
        r_, h_ = shape
        r = _rng(seed)
        bias = jnp.array(r.normal(size=h_).astype(np.float32))
        kw = {}
        if quant_in:
            x = jnp.array(r.integers(-10**5, 10**5, (r_, h_), dtype=np.int32))
            kw["x_scale"] = 2e-5
        else:
            x = jnp.array(r.normal(size=(r_, h_)).astype(np.float32))
        if quant_out:
            kw["out_scale"] = 0.01
        got = bias_gelu(x, bias, **kw)
        want = ref.ref_bias_gelu(x, bias, **kw)
        if quant_out:
            assert (np.array(got) == np.array(want)).all()
        else:
            np.testing.assert_allclose(np.array(got), np.array(want),
                                       rtol=1e-5, atol=1e-5)

    def test_gelu_fixed_points(self):
        """GELU(0)=0, GELU(large)≈large, GELU(-large)≈0."""
        x = jnp.array([[0.0, 10.0, -10.0]])
        out = np.array(bias_gelu(x, jnp.zeros(3)))
        assert abs(out[0, 0]) < 1e-7
        assert abs(out[0, 1] - 10.0) < 1e-3
        assert abs(out[0, 2]) < 1e-3


# ---------------------------------------------------------------------------
# softmax_quant — including the Appendix-B range property
# ---------------------------------------------------------------------------

class TestSoftmaxQuant:
    @given(st.integers(0, 2**32 - 1),
           st.sampled_from([(8, 16), (32, 64), (64, 128), (30, 10)]),
           st.booleans())
    @settings(**COMMON)
    def test_matches_ref(self, seed, shape, quant_out):
        r_, s_ = shape
        r = _rng(seed)
        lg = jnp.array(r.normal(0, 3, (r_, s_)).astype(np.float32))
        mb = jnp.array(np.where(r.random((r_, s_)) < 0.2, -1e9, 0.0)
                       .astype(np.float32))
        so = 1.0 / 127 if quant_out else None
        got = softmax_quant(lg, mb, out_scale=so)
        want = ref.ref_softmax_quant(lg, mb, out_scale=so)
        if quant_out:
            assert (np.array(got) == np.array(want)).all()
        else:
            np.testing.assert_allclose(np.array(got), np.array(want),
                                       rtol=1e-5, atol=1e-6)

    @given(st.integers(0, 2**32 - 1))
    @settings(**COMMON)
    def test_rows_sum_to_one(self, seed):
        r = _rng(seed)
        lg = jnp.array(r.normal(size=(16, 32)).astype(np.float32))
        p = np.array(softmax_quant(lg, jnp.zeros((16, 32))))
        np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)

    def test_appendix_b_nonnegative_codes(self):
        """The Fig-4 phenomenon: quantized softmax codes are all >= 0 —
        the [-127, 0) half of the symmetric INT8 range is structurally dead."""
        r = _rng(7)
        lg = jnp.array(r.normal(0, 2, (64, 48)).astype(np.float32))
        q = np.array(softmax_quant(lg, jnp.zeros((64, 48)), out_scale=1.0 / 127))
        assert q.min() >= 0
        # and with the row-sum-to-1 constraint most codes go unused:
        used = np.unique(q).size
        assert used < 129  # cannot exceed the non-negative half


# ---------------------------------------------------------------------------
# fused attention
# ---------------------------------------------------------------------------

class TestAttention:
    @given(st.integers(0, 2**32 - 1),
           st.sampled_from([(2, 8, 4), (8, 16, 8), (4, 32, 16), (12, 24, 32)]),
           st.sampled_from([np.float32, np.float16]))
    @settings(**COMMON)
    def test_matches_ref(self, seed, shape, dtype):
        r_, s_, d_ = shape
        r = _rng(seed)
        q = jnp.array(r.normal(size=(r_, s_, d_)).astype(dtype))
        k = jnp.array(r.normal(size=(r_, s_, d_)).astype(dtype))
        v = jnp.array(r.normal(size=(r_, s_, d_)).astype(dtype))
        mb = jnp.array(np.where(r.random((r_, s_)) < 0.25, -1e9, 0.0)
                       .astype(np.float32))
        sm = 1.0 / np.sqrt(d_)
        got = np.array(attention(q, k, v, mb, sm))
        want = np.array(ref.ref_attention(q, k, v, mb, sm))
        tol = 1e-5 if dtype == np.float32 else 2e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_masked_keys_ignored(self):
        """Fully masking one key makes its V row irrelevant."""
        r_, s_, d_ = 1, 4, 8
        rng = _rng(3)
        q = jnp.array(rng.normal(size=(r_, s_, d_)).astype(np.float32))
        k = jnp.array(rng.normal(size=(r_, s_, d_)).astype(np.float32))
        v = np.asarray(rng.normal(size=(r_, s_, d_)).astype(np.float32))
        mb = np.zeros((r_, s_), np.float32); mb[0, -1] = -1e9
        out1 = np.array(attention(q, k, jnp.array(v), jnp.array(mb), 0.35))
        v2 = v.copy(); v2[0, -1] += 100.0
        out2 = np.array(attention(q, k, jnp.array(v2), jnp.array(mb), 0.35))
        np.testing.assert_allclose(out1, out2, atol=1e-4)
