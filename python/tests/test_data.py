"""Synthetic dataset generator tests: determinism, format, learnable signal,
text-rendering round-trip contract with the Rust tokenizer."""

import numpy as np
import pytest

from compile import data as D


class TestGenerate:
    @pytest.mark.parametrize("task", list(D.TASKS))
    def test_shapes_and_ranges(self, task):
        spec = D.TASKS[task]
        ids, segs, mask, labels = D.generate(task, "dev", n=64)
        assert ids.shape == (64, spec.seq_len)
        assert segs.shape == mask.shape == ids.shape
        assert ids.min() >= 0 and ids.max() < D.VOCAB_SIZE
        assert set(np.unique(segs)).issubset({0, 1})
        assert set(np.unique(mask)).issubset({0, 1})
        if spec.kind == "ner":
            assert labels.shape == ids.shape
            assert labels.max() < spec.num_labels
        else:
            assert labels.shape == (64,)
            assert labels.max() < spec.num_labels

    def test_deterministic(self):
        a = D.generate("tnews", "dev", n=32)
        b = D.generate("tnews", "dev", n=32)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_splits_differ(self):
        a, *_ = D.generate("tnews", "train", n=32)
        b, *_ = D.generate("tnews", "dev", n=32)
        assert not np.array_equal(a, b)

    def test_rows_start_with_cls_end_with_sep(self):
        ids, _, mask, _ = D.generate("tnews", "dev", n=16)
        for i in range(16):
            n = int(mask[i].sum())
            assert ids[i, 0] == D.CLS
            assert ids[i, n - 1] == D.SEP
            assert (ids[i, n:] == D.PAD).all()

    def test_matching_has_two_segments(self):
        ids, segs, mask, _ = D.generate("afqmc", "dev", n=16)
        for i in range(16):
            n = int(mask[i].sum())
            assert segs[i, :n].max() == 1
            # two [SEP]s
            assert (ids[i, :n] == D.SEP).sum() == 2

    def test_signal_is_learnable_bayes(self):
        """A trivial keyword-count classifier must beat chance on clean train
        labels — guards against generator regressions that kill the signal."""
        spec = D.TASKS["tnews"]
        kws = D._class_keywords(
            spec, np.random.default_rng(hash("tnews") % 2**31))
        ids, _, _, labels = D.generate("tnews", "train", n=256)
        kwsets = [set(k) for k in kws]
        correct = 0
        for i in range(256):
            toks = set(ids[i].tolist())
            scores = [len(toks & s) for s in kwsets]
            if int(np.argmax(scores)) == labels[i]:
                correct += 1
        assert correct / 256 > 0.5, f"bayes proxy acc {correct/256}"

    def test_dev_label_noise_applied(self):
        """dev is noisy (the accuracy ceiling), train is clean."""
        spec = D.TASKS["tnews"]
        kws = D._class_keywords(
            spec, np.random.default_rng(hash("tnews") % 2**31))
        kwsets = [set(k) for k in kws]

        def bayes_acc(split):
            ids, _, _, labels = D.generate("tnews", split, n=512)
            hit = 0
            for i in range(len(ids)):
                toks = set(ids[i].tolist())
                hit += int(np.argmax([len(toks & s) for s in kwsets])
                           == labels[i])
            return hit / len(ids)

        assert bayes_acc("train") > bayes_acc("dev") + 0.15

    def test_ner_bio_consistency(self):
        _, _, mask, tags = D.generate("cluener", "dev", n=32)
        # I-tag never follows O of a different type start-lessly at pos 0
        for row, m in zip(tags, mask):
            n = int(m.sum())
            for j in range(n):
                t = D.NER_LABELS[row[j]]
                if t.startswith("I-"):
                    prev = D.NER_LABELS[row[j - 1]] if j > 0 else "O"
                    assert prev.endswith(t[2:]), f"dangling {t} after {prev}"


class TestTextRendering:
    def test_roundtrip_tokens(self):
        """render_text must reproduce exactly the non-special tokens, so the
        Rust tokenizer can rebuild the id row."""
        ids, _, mask, _ = D.generate("tnews", "dev", n=8)
        vocab = D.build_vocab()
        for i in range(8):
            text = D.render_text(ids[i])
            words = text.split(" ")
            expect = [vocab[t] for t in ids[i] if t not in
                      (D.PAD, D.CLS, D.SEP)]
            assert words == expect

    def test_matching_tab_separator(self):
        ids, _, _, _ = D.generate("afqmc", "dev", n=4)
        for i in range(4):
            text = D.render_text(ids[i])
            assert "\t" in text

    def test_vocab_shape(self):
        v = D.build_vocab()
        assert len(v) == D.VOCAB_SIZE
        assert v[D.CLS] == "[CLS]"
        assert v[D.CJK_BASE] == chr(0x4E00)
        assert len(set(v)) == len(v), "vocab must be collision-free"
