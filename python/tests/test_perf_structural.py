"""L1 structural performance invariants (the perf-pass guardrails):
every kernel's VMEM working set must fit the TPU budget at serving
geometries, and the INT8 GEMM tiles must keep the MXU well fed."""

import importlib

attn_k = importlib.import_module("compile.kernels.attention")
emb_k = importlib.import_module("compile.kernels.fused_embedding")
ln_k = importlib.import_module("compile.kernels.fused_ln_quant")
mm_k = importlib.import_module("compile.kernels.int8_matmul")
sm_k = importlib.import_module("compile.kernels.softmax_quant")
from compile.perf_report import MXU, VMEM_BUDGET, mxu_utilization

# serving geometries from the manifest: (batch, seq, hidden, ffn, vocab)
GEOMS = [
    (8, 32, 64, 256, 2048),    # tnews
    (8, 64, 64, 256, 2048),    # afqmc
    (8, 128, 64, 256, 2048),   # iflytek
]
BERT_BASE = (8, 64, 768, 3072, 30522)


class TestVmemBudget:
    def test_all_kernels_fit_at_serving_geometries(self):
        for batch, seq, hidden, ffn, vocab in GEOMS:
            rows = batch * seq
            assert mm_k.vmem_estimate(rows, hidden, hidden) <= VMEM_BUDGET
            assert mm_k.vmem_estimate(rows, hidden, ffn) <= VMEM_BUDGET
            assert mm_k.vmem_estimate(rows, ffn, hidden) <= VMEM_BUDGET
            assert emb_k.vmem_estimate(seq, vocab, hidden) <= VMEM_BUDGET
            assert ln_k.vmem_estimate(hidden) <= VMEM_BUDGET
            assert sm_k.vmem_estimate(seq) <= VMEM_BUDGET
            assert attn_k.vmem_estimate(seq, hidden // 4) <= VMEM_BUDGET

    def test_gemm_fits_even_at_bert_base(self):
        batch, seq, hidden, ffn, _ = BERT_BASE
        rows = batch * seq
        assert mm_k.vmem_estimate(rows, hidden, ffn) <= VMEM_BUDGET
        assert mm_k.vmem_estimate(rows, ffn, hidden) <= VMEM_BUDGET

    def test_embedding_table_strategy_documented_limit(self):
        """The whole-table-in-VMEM strategy is only valid for small vocabs;
        BERT-base vocab must exceed the budget (documented in the kernel
        docstring as requiring HBM gathers on real hardware)."""
        _, seq, hidden, _, vocab = BERT_BASE
        assert emb_k.vmem_estimate(seq, vocab, hidden) > VMEM_BUDGET


class TestMxuFeeding:
    def test_default_tiles_fill_mxu_when_dims_allow(self):
        # 128x128 tiles at BERT-base rows/cols -> 100% MXU tile fill
        assert mxu_utilization(128, 128, 768) == 1.0

    def test_small_hidden_underfills_and_is_known(self):
        # H=64 underfills one MXU edge: utilization 0.5^1; this is a model-
        # geometry property, not a kernel bug (tracked in EXPERIMENTS §Perf)
        u = mxu_utilization(128, 64, 64)
        assert abs(u - 0.5) < 1e-9

    def test_pick_block_prefers_mxu_edges(self):
        assert mm_k.pick_block(512, 128) == 128
        assert mm_k.pick_block(256, 128) == 128
        # degrades to divisors for odd sizes
        assert mm_k.pick_block(100, 128) == 100
