"""Calibrator tests: the four PTQ calibrators' invariants + parity vectors
that the Rust ports (rust/src/quant/calibrators.rs) mirror."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.calib import (HistogramCollector, compute_scales, scale_entropy,
                           scale_minmax, scale_mse, scale_percentile,
                           CALIBRATORS)
from compile.kernels.common import QMAX

COMMON = dict(deadline=None, max_examples=20, derandomize=True)


def collect(data, name="x", bins=2048):
    c = HistogramCollector(bins)
    c.add(name, data)
    c.start_histogram_pass()
    c.add(name, data)
    return c


class TestCollector:
    def test_two_pass_amax_then_hist(self):
        r = np.random.default_rng(0)
        data = r.normal(0, 1, 10_000).astype(np.float32)
        c = collect(data)
        assert c.amax["x"] == pytest.approx(np.abs(data).max())
        assert c.hist["x"].sum() == data.size

    def test_multiple_batches_accumulate(self):
        c = HistogramCollector(64)
        a = np.ones(10, np.float32)
        b = np.full(10, 2.0, np.float32)
        c.add("x", a)
        c.add("x", b)
        assert c.amax["x"] == 2.0
        c.start_histogram_pass()
        c.add("x", a)
        c.add("x", b)
        assert c.hist["x"].sum() == 20


class TestCalibrators:
    @given(st.integers(0, 2**32 - 1))
    @settings(**COMMON)
    def test_all_calibrators_positive_and_bounded(self, seed):
        r = np.random.default_rng(seed)
        data = (r.normal(0, 1, 20_000) * r.uniform(0.1, 10)).astype(np.float32)
        c = collect(data)
        amax = c.amax["x"]
        for method in CALIBRATORS:
            s = compute_scales(c, method)["x"]
            assert s > 0
            # no calibrator may exceed the minmax scale
            assert s <= amax / QMAX + 1e-9, method

    def test_percentile_clips_gaussian_tail(self):
        r = np.random.default_rng(1)
        data = r.normal(0, 1, 100_000).astype(np.float32)
        c = collect(data)
        s999 = scale_percentile(c.amax["x"], c.hist["x"], c.bin_width("x"), 99.9)
        clip = s999 * QMAX
        assert 2.5 < clip < 4.5  # |N(0,1)| 99.9th pct ~ 3.29

    def test_mse_keeps_uniform_range(self):
        data = np.linspace(0, 1, 10_000).astype(np.float32)
        c = collect(data, bins=512)
        s = scale_mse(c.amax["x"], c.hist["x"], c.bin_width("x"))
        assert s * QMAX > 0.9

    def test_entropy_clips_long_tail(self):
        r = np.random.default_rng(2)
        # mass at small values + rare huge outliers
        data = np.concatenate([
            r.normal(0, 0.1, 100_000),
            r.normal(0, 5.0, 100),
        ]).astype(np.float32)
        c = collect(data)
        s_ent = scale_entropy(c.amax["x"], c.hist["x"], c.bin_width("x"))
        s_mm = scale_minmax(c.amax["x"])
        assert s_ent < s_mm * 0.5  # entropy must clip hard here

    def test_degenerate_zero_tensor(self):
        c = collect(np.zeros(100, np.float32))
        for method in CALIBRATORS:
            assert compute_scales(c, method)["x"] == 1.0

    def test_unknown_method_rejected(self):
        c = collect(np.ones(10, np.float32))
        with pytest.raises(AssertionError):
            compute_scales(c, "magic")


class TestRustParityVectors:
    """Fixed vectors double-checked by rust/src/quant tests — keep in sync."""

    def test_quantize_vector(self):
        from compile.kernels.common import quantize
        import jax.numpy as jnp
        xs = jnp.asarray([0.0, 0.024, -0.024, 1.0, -5.0, 0.05, 0.074, 0.076],
                         jnp.float32)
        got = np.asarray(quantize(xs, 0.05)).tolist()
        assert got == [0, 0, 0, 20, -100, 1, 1, 2]
