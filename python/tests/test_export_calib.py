"""Calibration-set exporter tests: JSONL contract with the Rust planner
(`{"text": ..., "label": ...}` rows), determinism, calib/dev split
separation."""

import json

import pytest

from compile import data as D
from compile import export_calib


class TestExportCalib:
    @pytest.mark.parametrize("task", ["tnews", "afqmc", "cluener"])
    def test_writes_parseable_jsonl(self, task, tmp_path):
        out = tmp_path / f"{task}.jsonl"
        rows = export_calib.export(task, str(out), n=16)
        assert rows == 16
        lines = out.read_text(encoding="utf-8").splitlines()
        assert len(lines) == 16
        for line in lines:
            row = json.loads(line)
            assert row["text"], "empty calibration text"
            assert "label" in row
            # the planner re-tokenizes: texts must be plain surface words
            for w in row["text"].replace("\t", " ").split():
                assert w not in ("[CLS]", "[SEP]", "[PAD]"), w

    def test_matching_task_renders_tab_separated_pairs(self, tmp_path):
        out = tmp_path / "afqmc.jsonl"
        export_calib.export("afqmc", str(out), n=8)
        rows = [json.loads(l) for l in out.read_text().splitlines()]
        assert all("\t" in r["text"] for r in rows)

    def test_deterministic_and_split_from_dev(self, tmp_path):
        a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
        export_calib.export("tnews", str(a), n=8)
        export_calib.export("tnews", str(b), n=8)
        assert a.read_text() == b.read_text()
        # the calib split must not be the dev split (no leakage)
        dev_ids, *_ = D.generate("tnews", "dev", n=8)
        calib_ids, *_ = D.generate("tnews", "calib", n=8)
        assert (dev_ids != calib_ids).any()

    def test_unknown_task_raises(self, tmp_path):
        with pytest.raises(ValueError):
            export_calib.export("nope", str(tmp_path / "x.jsonl"), n=4)
