"""Training-loop tests (tiny geometry so they run in seconds on 1 CPU)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.model import ModelConfig, init_params
from compile.train import (TrainSettings, adam_init, adam_update, accuracy,
                           config_for_task, load_params, save_params,
                           train_task, _loss_fn)
from compile.model import PrecisionPlan, FP32


class TestAdam:
    def test_descends_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        opt = adam_init(params)
        import jax
        for _ in range(300):
            grads = {"w": 2 * params["w"]}
            params, opt = adam_update(params, grads, opt, 0.05)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_state_shapes_match(self):
        cfg = ModelConfig(vocab_size=32, hidden=16, layers=1, heads=2, ffn=32,
                          max_len=8)
        params = {k: jnp.asarray(v) for k, v in init_params(cfg).items()}
        opt = adam_init(params)
        assert set(opt["m"]) == set(params)
        for k in params:
            assert opt["m"][k].shape == params[k].shape


class TestTrainTask:
    @pytest.fixture(scope="class")
    def trained(self):
        cfg = config_for_task("tnews", layers=2, hidden=32)
        return train_task("tnews", cfg,
                          TrainSettings(steps=220, batch_size=16,
                                        log_every=1000),
                          verbose=False)

    def test_loss_decreases(self, trained):
        _, _, rep = trained
        # (2-layer, 220-step smoke: demand measurable descent)
        assert rep["final_loss"] < rep["first_loss"] * 0.97, rep

    def test_beats_chance(self, trained):
        params, cfg, rep = trained
        assert rep["dev_accuracy_fp32"] > 2.0 / 15

    def test_save_load_roundtrip(self, trained, tmp_path):
        params, _, _ = trained
        p = str(tmp_path / "w.npz")
        save_params(p, params)
        loaded = load_params(p)
        assert set(loaded) == set(params)
        for k in params:
            np.testing.assert_array_equal(loaded[k], params[k])

    def test_config_for_task_geometry(self):
        cfg = config_for_task("afqmc")
        assert cfg.head_type == "matching"
        assert cfg.layers == 12
        cfg = config_for_task("cluener")
        assert cfg.head_type == "ner"
        assert cfg.num_labels == 9
