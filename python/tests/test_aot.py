"""AOT pipeline tests: HLO-text lowering, variant grid, dataset export
formats — at tiny geometry so they complete in seconds."""

import json
import os
import struct

import numpy as np
import jax.numpy as jnp
import pytest

from compile import data as D
from compile.aot import (lower_encoder, lower_head, to_hlo_text,
                         variant_plans, write_dataset_bin, SWEEP_KS)
from compile.model import (FP16, INT8_FFN, INT8_FULL, ModelConfig,
                           PrecisionPlan, ScaleSet, init_params)

CFG = ModelConfig(vocab_size=64, hidden=16, layers=2, heads=2, ffn=32,
                  max_len=8, num_labels=3)


class TestVariantGrid:
    def test_grid_contents(self):
        plans = variant_plans(12)
        assert set(plans) == {"fp32", "fp16"} | {
            f"{m}_{k}" for m in ("full_quant", "ffn_only") for k in SWEEP_KS}
        assert plans["full_quant_4"].layer_modes[:4] == (INT8_FULL,) * 4
        assert plans["full_quant_4"].layer_modes[4:] == (FP16,) * 8
        assert plans["ffn_only_12"].layer_modes == (INT8_FFN,) * 12

    def test_grid_respects_layer_count(self):
        plans = variant_plans(4)
        assert "full_quant_6" not in plans
        assert "full_quant_4" in plans


class TestLowering:
    @pytest.fixture(scope="class")
    def params_scales(self):
        params = init_params(CFG, seed=3)
        # synthetic-but-plausible scales (no calibration needed for lowering)
        sc = ScaleSet({})
        for l in range(CFG.layers):
            for t in ("attn_in", "q_out", "k_out", "v_out", "ctx", "ffn_in",
                      "act", "layer_out"):
                sc[f"l{l}/{t}"] = 0.05
            sc[f"l{l}/p_out"] = 1 / 127
            for w in ("wq", "wk", "wv", "wo", "w1", "w2"):
                amax = float(np.abs(params[f"l{l}/{w}"]).max())
                sc[f"l{l}/{w}"] = amax / 127 if amax > 0 else 1.0
        sc["emb_out"] = 0.1
        return params, sc

    def test_encoder_hlo_text_valid(self, params_scales):
        params, sc = params_scales
        plan = PrecisionPlan.prefix(INT8_FULL, 1, CFG.layers)
        hlo = lower_encoder(params, CFG, plan, sc, batch=2)
        assert hlo.startswith("HloModule"), hlo[:60]
        assert "ENTRY" in hlo
        # int8 arithmetic must actually appear in the quantized variant
        assert "s8[" in hlo, "expected int8 tensors in Fully-Quant HLO"
        assert "s32[" in hlo, "expected int32 accumulators"

    def test_fp_variant_has_no_int8(self, params_scales):
        params, sc = params_scales
        plan = PrecisionPlan.uniform(FP16, CFG.layers)
        hlo = lower_encoder(params, CFG, plan, sc, batch=2)
        assert "s8[" not in hlo
        assert "f16[" in hlo

    def test_head_hlo(self, params_scales):
        params, _ = params_scales
        hlo = lower_head(params, CFG, batch=2)
        assert hlo.startswith("HloModule")
        # classification head output shape [batch, labels]
        assert f"f32[2,{CFG.num_labels}]" in hlo

    def test_lowering_deterministic(self, params_scales):
        params, sc = params_scales
        plan = PrecisionPlan.uniform(FP16, CFG.layers)
        a = lower_encoder(params, CFG, plan, sc, batch=2)
        b = lower_encoder(params, CFG, plan, sc, batch=2)
        assert a == b


class TestDatasetExport:
    def test_bin_format_roundtrip(self, tmp_path):
        ids, segs, mask, labels = D.generate("tnews", "dev", n=16)
        p = str(tmp_path / "d.bin")
        write_dataset_bin(p, ids, segs, mask, labels, per_token=False)
        raw = open(p, "rb").read()
        assert raw[:8] == b"SAMPDAT1"
        n, seq = struct.unpack("<II", raw[8:16])
        assert (n, seq) == ids.shape
        body = np.frombuffer(raw[20:], dtype="<i4")
        assert body.size == 3 * n * seq + n
        np.testing.assert_array_equal(body[: n * seq].reshape(n, seq), ids)
        np.testing.assert_array_equal(body[3 * n * seq:], labels)

    def test_bin_per_token(self, tmp_path):
        ids, segs, mask, tags = D.generate("cluener", "dev", n=8)
        p = str(tmp_path / "d.bin")
        write_dataset_bin(p, ids, segs, mask, tags, per_token=True)
        raw = open(p, "rb").read()
        n, seq = struct.unpack("<II", raw[8:16])
        assert raw[16] == 1
        body = np.frombuffer(raw[20:], dtype="<i4")
        assert body.size == 4 * n * seq
