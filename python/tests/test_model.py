"""L2 model tests: precision-plan dispatch, shape/dtype contracts, parity of
the Pallas inference path with the pure-jnp training path, and the
quantization-accuracy ordering the paper's Table 2 rests on."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data as D
from compile.calib import calibrate_model
from compile.model import (FP16, FP32, INT8_FFN, INT8_FULL, ModelConfig,
                           PrecisionPlan, ScaleSet, encoder_forward,
                           encoder_forward_ref, encoder_forward_with_taps,
                           head_forward, init_params, LAYER_TAPS)

CFG = ModelConfig(vocab_size=128, hidden=32, layers=3, heads=2, ffn=64,
                  max_len=16, num_labels=4)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, seed=1)
    rng = np.random.default_rng(0)
    b, s = 4, CFG.max_len
    ids = jnp.asarray(rng.integers(5, CFG.vocab_size, (b, s)).astype(np.int32))
    segs = jnp.asarray(rng.integers(0, 2, (b, s)).astype(np.int32))
    mask_np = np.ones((b, s), np.float32)
    mask_np[:, 12:] = 0.0
    mask = jnp.asarray(mask_np)
    cal = [(ids, segs, mask)]
    scales = ScaleSet(calibrate_model(params, CFG, cal, "minmax"))
    return params, ids, segs, mask, scales


class TestPrecisionPlan:
    def test_uniform_and_prefix(self):
        p = PrecisionPlan.uniform(FP16, 4)
        assert p.layer_modes == (FP16,) * 4
        p = PrecisionPlan.prefix(INT8_FULL, 2, 4)
        assert p.layer_modes == (INT8_FULL, INT8_FULL, FP16, FP16)
        assert p.embedding_quant
        p = PrecisionPlan.prefix(INT8_FFN, 2, 4)
        assert not p.embedding_quant

    def test_rejects_bad_mode(self):
        with pytest.raises(AssertionError):
            PrecisionPlan(("nope",))

    def test_names_stable(self):
        assert PrecisionPlan.uniform(FP16, 4).name() == "float16"
        assert "full_quant_2of4" in PrecisionPlan.prefix(INT8_FULL, 2, 4).name()
        assert "ffn_only_3of4" in PrecisionPlan.prefix(INT8_FFN, 3, 4).name()


class TestForward:
    def test_output_shape_all_plans(self, setup):
        params, ids, segs, mask, scales = setup
        for plan in [
            PrecisionPlan.uniform(FP32, 3, fp_dtype=jnp.float32),
            PrecisionPlan.uniform(FP16, 3),
            PrecisionPlan.prefix(INT8_FFN, 2, 3),
            PrecisionPlan.prefix(INT8_FULL, 2, 3),
            PrecisionPlan.uniform(INT8_FULL, 3),
            # arbitrary interleaving must also work
            PrecisionPlan((INT8_FULL, FP16, INT8_FFN)),
        ]:
            h = encoder_forward(params, CFG, plan, ids, segs, mask, scales)
            assert h.shape == (4, CFG.max_len, CFG.hidden), plan.name()
            assert h.dtype == jnp.float32
            assert bool(jnp.isfinite(h).all()), plan.name()

    def test_pallas_path_matches_ref_path_fp32(self, setup):
        """The inference graph (Pallas kernels) must agree with the pure-jnp
        training graph in FP32 — this ties L1 to L2."""
        params, ids, segs, mask, _ = setup
        plan = PrecisionPlan.uniform(FP32, 3, fp_dtype=jnp.float32)
        h1 = np.asarray(encoder_forward(params, CFG, plan, ids, segs, mask))
        h2 = np.asarray(encoder_forward_ref(params, CFG, ids, segs, mask))
        np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)

    def test_fp16_close_to_fp32(self, setup):
        params, ids, segs, mask, _ = setup
        h32 = np.asarray(encoder_forward(
            params, CFG, PrecisionPlan.uniform(FP32, 3, fp_dtype=jnp.float32),
            ids, segs, mask))
        h16 = np.asarray(encoder_forward(
            params, CFG, PrecisionPlan.uniform(FP16, 3), ids, segs, mask))
        # half precision: absolute agreement at lenient tolerance
        assert np.abs(h32 - h16).mean() < 0.05

    def test_int8_noise_small_but_nonzero(self, setup):
        params, ids, segs, mask, scales = setup
        h16 = np.asarray(encoder_forward(
            params, CFG, PrecisionPlan.uniform(FP16, 3), ids, segs, mask))
        hq = np.asarray(encoder_forward(
            params, CFG, PrecisionPlan.prefix(INT8_FFN, 3, 3), ids, segs, mask,
            scales))
        d = np.abs(h16 - hq).mean()
        assert 0.0 < d < 0.5, d

    def test_full_quant_noisier_than_ffn_only(self, setup):
        """Appendix B: quantizing MHA (softmax P!) hurts more than FFN."""
        params, ids, segs, mask, scales = setup
        h32 = np.asarray(encoder_forward(
            params, CFG, PrecisionPlan.uniform(FP32, 3, fp_dtype=jnp.float32),
            ids, segs, mask))
        hffn = np.asarray(encoder_forward(
            params, CFG, PrecisionPlan.uniform(INT8_FFN, 3), ids, segs, mask,
            scales))
        hfull = np.asarray(encoder_forward(
            params, CFG, PrecisionPlan.uniform(INT8_FULL, 3), ids, segs, mask,
            scales))
        err_ffn = np.abs(h32 - hffn).mean()
        err_full = np.abs(h32 - hfull).mean()
        assert err_full > err_ffn, (err_full, err_ffn)

    def test_padding_rows_do_not_change_real_rows(self, setup):
        """Batch padding (the serving batcher's zero rows) must not leak."""
        params, ids, segs, mask, scales = setup
        plan = PrecisionPlan.uniform(FP16, 3)
        h_full = np.asarray(encoder_forward(params, CFG, plan, ids, segs,
                                            mask, scales))
        ids2 = np.array(ids).copy()
        mask2 = np.array(mask).copy()
        ids2[2:] = 0
        mask2[2:] = 0.0
        h_pad = np.asarray(encoder_forward(params, CFG, plan,
                                           jnp.asarray(ids2), segs,
                                           jnp.asarray(mask2), scales))
        np.testing.assert_allclose(h_full[:2], h_pad[:2], rtol=2e-2, atol=2e-2)


class TestHeads:
    def test_classification_and_matching(self, setup):
        params, ids, segs, mask, _ = setup
        h = encoder_forward(params, CFG,
                            PrecisionPlan.uniform(FP32, 3, fp_dtype=jnp.float32),
                            ids, segs, mask)
        logits = head_forward(params, CFG, h)
        assert logits.shape == (4, CFG.num_labels)

    def test_ner_head(self, setup):
        params, ids, segs, mask, _ = setup
        cfg = ModelConfig(**{**CFG.__dict__, "head_type": "ner",
                             "num_labels": 9})
        p = init_params(cfg, seed=2)
        h = encoder_forward(p, cfg,
                            PrecisionPlan.uniform(FP32, 3, fp_dtype=jnp.float32),
                            ids, segs, mask)
        logits = head_forward(p, cfg, h)
        assert logits.shape == (4, cfg.max_len, 9)


class TestTaps:
    def test_all_taps_present_and_shaped(self, setup):
        params, ids, segs, mask, _ = setup
        _, taps = encoder_forward_with_taps(params, CFG, ids, segs, mask)
        assert "emb_out" in taps
        for l in range(CFG.layers):
            for t in LAYER_TAPS:
                assert f"l{l}/{t}" in taps, f"missing l{l}/{t}"
        # softmax tap rows sum to 1
        p = np.asarray(taps["l0/p_out"])
        np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-4)
        assert p.min() >= 0.0
