//! Offline stub of the `xla` crate (xla-rs, PJRT C API bindings).
//!
//! The real crate links `xla_extension`, which cannot be downloaded in this
//! build environment.  This stub keeps the exact type-level surface that
//! `samp::runtime` consumes so the workspace builds and the unit/integration
//! tests (which skip when no AOT artifacts are present) stay green:
//!
//! * construction-side calls (`PjRtClient::cpu`, `Literal::vec1`, `reshape`,
//!   `HloModuleProto::from_text_file`, `compile`) succeed — artifact parsing
//!   validates that the file exists and looks like HLO text;
//! * execution-side calls (`execute`, `to_literal_sync`, …) return a clear
//!   "offline stub" error, so anyone running with real artifacts but without
//!   the real PJRT backend gets an actionable message instead of garbage.
//!
//! Swapping in real PJRT is a Cargo.toml-only change.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla (offline stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn exec_unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT execution is unavailable offline; link the real `xla` crate \
         (xla_extension) to run compiled artifacts"
            .to_string(),
    ))
}

/// Host literal handle. The stub carries no data — literals only flow into
/// `execute`, which is the call that errors.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        exec_unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        exec_unavailable()
    }
}

/// Parsed HLO module (stub: existence/shape check of the artifact file only).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path) {
            Ok(text) if text.contains("HloModule") || text.contains("ENTRY") => {
                Ok(HloModuleProto)
            }
            Ok(_) => Err(Error(format!("{path}: does not look like HLO text"))),
            Err(e) => Err(Error(format!("{path}: {e}"))),
        }
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (offline stub)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable)
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        exec_unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        exec_unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_side_is_ok() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[3]).is_ok());
    }

    #[test]
    fn execution_side_errors_clearly() {
        let exe = PjRtClient::cpu().unwrap().compile(&XlaComputation);
        let err = exe.unwrap().execute::<Literal>(&[]).unwrap_err();
        assert!(err.to_string().contains("offline"));
    }

    #[test]
    fn hlo_parse_requires_file() {
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
    }
}
