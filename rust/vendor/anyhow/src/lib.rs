//! Offline shim for the `anyhow` crate.
//!
//! Implements the subset this workspace uses — [`Error`], [`Result`],
//! [`Context`] on `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!`
//! macros — with the same observable formatting conventions:
//!
//! * `{}` displays the outermost message only;
//! * `{:#}` displays the whole chain joined with `": "`;
//! * `{:?}` (e.g. from `.unwrap()`) displays the message plus a
//!   `Caused by:` list, like real anyhow.
//!
//! Swap back to the real crate by pointing the `anyhow` dependency at
//! crates.io; no call sites change.

use std::fmt;

/// Error type: an ordered context chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (the `anyhow!` macro entry point).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn push_context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// Iterate the chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Mirrors real anyhow: any std error converts, capturing its source chain.
// (Error itself does not implement std::error::Error, so this blanket impl
// does not collide with the reflexive `From<Error> for Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or turn `None` into an error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("opening config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: file missing");
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let inner: Result<()> = Err(anyhow!("root {}", 42));
        let e = inner.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(format!("{:#}", v.context("empty").unwrap_err()), "empty");
        assert_eq!(Some(7u8).context("never").unwrap(), 7);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero");
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
    }
}
