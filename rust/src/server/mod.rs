//! HTTP/1.1 JSON serving front-end over std::net (tokio unavailable offline).
//!
//! Endpoints:
//!   POST /v1/infer    {"task": "...", "text": "...", "model": id?}   -> result
//!   POST /v1/batch    {"task": "...", "texts": [...], "model": id?}  -> results
//!   POST /v1/models/{id}/reload   {"variant": name?}   -> hot reload
//!   GET  /v1/models   model registry: generations, replicas, per-model stats
//!   GET  /v1/plan     active precision plan per model/task (read-only)
//!   GET  /v1/stats    counters + per-lane shard/replica breakdown
//!   GET  /health      ok
//!
//! Architecture: acceptor thread + a fixed worker [`ThreadPool`] in front of
//! a model [`Registry`].  Every loaded model is an immutable **deployment
//! generation** ([`crate::registry::Deployment`]): manifest + router + one
//! admission-controlled lane per task, each lane drained by a shard set of N
//! dispatcher workers (`--workers-per-lane`) running batches on the
//! least-loaded engine of an N-way **replica set** (`--replicas-per-lane`,
//! duplicated packed native weights).  Native-backend lanes form
//! **continuous** token-budget batches and every row completes individually
//! ([`crate::coordinator::Pipeline::decode_row`]).
//!
//! # Zero-downtime reload
//!
//! `POST /v1/models/{id}/reload` (or `--watch-manifest` mtime polling)
//! builds the next generation off-path, warms it, atomically swaps it in,
//! then drains the old generation — in-flight rows finish on their original
//! engines, and the generation retires when nothing references it.  The
//! request path cooperates: the swap happens *before* the old lanes close,
//! so a row that races the swap and gets a typed `Closed` rejection simply
//! re-resolves the current generation and retries.  A reload therefore
//! produces zero request failures; graceful shutdown (SIGTERM / ctrl-c)
//! drains through the same path instead of aborting mid-batch.
//!
//! # Serving hot path
//!
//! A steady-state request crosses exactly these synchronization points:
//!
//! 1. **Model + lane resolve** — registry map read lock -> generation
//!    pointer read lock -> lane map read lock (each an `Arc` clone; lane
//!    creation double-checks under the write lock).
//! 2. **Enqueue-all / collect-all** — [`Server::infer_many`] tokenizes and
//!    enqueues *every* row of a multi-text request into the lane's batcher
//!    before blocking on the first reply.  Row failures are per-row.
//! 3. **Sharded dispatch** — N workers pull from the shared queue; each
//!    batch runs on the least-loaded engine replica, so batches of one lane
//!    proceed concurrently on independent weight copies.
//! 4. **Pooled blocks** — formed batches borrow
//!    [`BlockPool`](crate::coordinator::BlockPool) blocks; steady state
//!    allocates no tensors.
//! 5. **Lock-free metrics** — atomic [`Histogram`]s server-wide and per
//!    lane; aggregate shed/pool counters live on the registry-wide
//!    [`Counters`], so totals stay monotonic across lane rebuilds *and*
//!    generation reloads.
//! 6. **Admission control** — queue-depth cap per lane; excess pushes shed
//!    with [`ServeError::Overloaded`] -> HTTP 429.
//!
//! [`Histogram`]: crate::metrics::Histogram

pub mod http;
pub mod threadpool;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::ServerConfig;
use crate::coordinator::batcher::PushError;
use crate::coordinator::{Pipeline, Router, TaskOutput};
use crate::fault;
use crate::metrics::Counters;
use crate::registry::{Deployment, LaneConfig, Registry, RowError, RowOutput,
                      TaskLane};
use crate::telemetry;
use crate::util::json::Json;

use http::{read_request, write_response, write_response_typed,
           write_response_with, HttpRequest};
use threadpool::ThreadPool;

/// Why a request (or one row of a batch request) failed, with its HTTP
/// status.  Typed so `/v1/*` can answer 429 on admission-control shedding
/// instead of a generic 500.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Shed by the batcher's queue-depth cap — retry later (HTTP 429).
    Overloaded,
    /// The lane is shutting down (HTTP 503).
    ShuttingDown,
    /// The row's end-to-end deadline (`X-SAMP-Deadline-Ms` /
    /// `--default-deadline-ms`) passed before its forward pass ran; the row
    /// was dropped at form time, never costing engine work (HTTP 504).
    DeadlineExceeded,
    /// Pipeline/engine failure (HTTP 500).
    Failed(String),
}

impl ServeError {
    pub fn status(&self) -> u16 {
        match self {
            ServeError::Overloaded => 429,
            ServeError::ShuttingDown => 503,
            ServeError::DeadlineExceeded => 504,
            ServeError::Failed(_) => 500,
        }
    }

    /// Machine-readable failure class, reported per row in `/v1/batch`
    /// error objects so clients can separate back-off-and-retry
    /// (`overloaded`, `shutting_down`) from give-up (`deadline_exceeded`).
    pub fn reason(&self) -> &'static str {
        match self {
            ServeError::Overloaded => "overloaded",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::Failed(_) => "failed",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => {
                write!(f, "server overloaded: batch queue is full, retry later")
            }
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::DeadlineExceeded => {
                write!(f, "deadline exceeded before inference")
            }
            ServeError::Failed(msg) => write!(f, "{msg}"),
        }
    }
}

impl From<RowError> for ServeError {
    fn from(e: RowError) -> ServeError {
        match e {
            RowError::Failed(msg) => ServeError::Failed(msg),
            RowError::DeadlineExceeded => ServeError::DeadlineExceeded,
        }
    }
}

/// A resolved (generation, lane, pipeline) triple for one request.  Holding
/// the deployment `Arc` for the request's lifetime is what keeps a draining
/// generation alive until its last in-flight row replies.
struct LaneRef {
    _deployment: Arc<Deployment>,
    lane: Arc<TaskLane>,
    pipe: Arc<Pipeline>,
}

/// The serving coordinator: HTTP front-end over the model [`Registry`].
pub struct Server {
    pub config: ServerConfig,
    registry: Arc<Registry>,
    counters: Arc<Counters>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bounded retries for rows that race a generation swap (each retry
    /// re-resolves the freshly-swapped generation; the bound only engages
    /// when the server is actually shutting down).
    const SWAP_RETRIES: usize = 8;

    /// Bounded exponential backoff with jitter between swap-race retries:
    /// attempt `n` sleeps ~`500us << n` (capped at 50ms) ± 25%, so a herd
    /// of rows racing one reload swap doesn't spin a hot resolve loop in
    /// lockstep.  Attempt 0 is free — the first retry after a `Closed`
    /// rejection almost always lands on the freshly-swapped generation.
    fn swap_backoff(attempt: usize) {
        if attempt == 0 {
            std::thread::yield_now();
            return;
        }
        // xorshift over a process-wide seed: cheap jitter without pulling
        // clocks or a PRNG crate into the hot path
        static SEED: AtomicU64 = AtomicU64::new(0x9e37_79b9_7f4a_7c15);
        let mut x = SEED.load(Ordering::Relaxed);
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        SEED.store(x, Ordering::Relaxed);
        let base_us = (500u64 << attempt.min(7)).min(50_000);
        // jitter in [-25%, +25%]
        let jitter = (x % (base_us / 2 + 1)) as i64 - (base_us / 4) as i64;
        let us = (base_us as i64 + jitter).max(100) as u64;
        std::thread::sleep(Duration::from_micros(us));
    }

    /// Single-model compatibility constructor: wrap an existing router as
    /// the `default` model's generation 1.  Reload works against the
    /// router's manifest root.
    pub fn new(config: ServerConfig, router: Arc<Router>) -> Server {
        let counters = Arc::new(Counters::default());
        let registry = Arc::new(Registry::new(LaneConfig::from_server(&config),
                                              counters.clone()));
        spawn_healer(&registry);
        telemetry::spawn_signal_collector(&registry);
        registry
            .install_router("default", router)
            .expect("a fresh registry has no model id collisions");
        Server {
            config,
            registry,
            counters,
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Build the full registry from the config's model list (`--artifacts
    /// id=dir`, or the single `artifacts_dir` as `default`) and warm every
    /// generation.  A warm failure (e.g. PJRT artifacts without a runnable
    /// PJRT) is logged, not fatal — lanes stay lazy, exactly as before.
    pub fn from_config(config: ServerConfig) -> Result<Arc<Server>> {
        let counters = Arc::new(Counters::default());
        let registry = Arc::new(Registry::new(LaneConfig::from_server(&config),
                                              counters.clone()));
        spawn_healer(&registry);
        telemetry::spawn_signal_collector(&registry);
        let models: Vec<(String, PathBuf)> = if config.models.is_empty() {
            vec![("default".to_string(), config.artifacts_dir.clone())]
        } else {
            config.models.clone()
        };
        for (id, dir) in &models {
            let dep = registry.load_model(id, dir)?;
            match dep.warm() {
                Ok(()) => eprintln!(
                    "[serve] model `{id}`: generation 1 warm ({} task(s), \
                     {} replica(s) per lane)",
                    dep.tasks().len(),
                    registry.lane_config().replicas_per_lane),
                Err(e) => eprintln!(
                    "[serve] warning: warming model `{id}` failed: {e:#} \
                     (lanes stay lazy)"),
            }
        }
        Ok(Arc::new(Server {
            config,
            registry,
            counters,
            stop: Arc::new(AtomicBool::new(false)),
        }))
    }

    /// The model registry (lifecycle owner: load / reload / drain).
    pub fn registry(&self) -> Arc<Registry> {
        self.registry.clone()
    }

    pub fn counters(&self) -> Arc<Counters> {
        self.counters.clone()
    }

    /// Aggregate (hits, misses) of every lane's block pool, ever — read
    /// from the registry-wide [`Counters`] sink, so the totals are monotonic
    /// across lane rebuilds and generation reloads.
    pub fn pool_stats(&self) -> (u64, u64) {
        (self.counters.pool_hits.load(Ordering::Relaxed),
         self.counters.pool_misses.load(Ordering::Relaxed))
    }

    /// Total pushes shed by admission control across every lane, ever
    /// (monotonic — same [`Counters`] sink as [`Server::pool_stats`]).
    pub fn shed_count(&self) -> u64 {
        self.counters.shed.load(Ordering::Relaxed)
    }

    /// Dispatcher workers currently running across every live generation.
    pub fn worker_count(&self) -> usize {
        self.registry
            .entries()
            .iter()
            .map(|e| {
                e.current()
                    .lanes_snapshot()
                    .iter()
                    .map(|l| l.stats.workers())
                    .sum::<usize>()
            })
            .sum()
    }

    /// Resolve the current generation + lane + pipeline for a request row.
    /// A draining generation is retried — the reload swap publishes the new
    /// generation before closing the old lanes, so the retry lands on the
    /// fresh one; persistent draining means the whole server is stopping.
    fn resolve_lane(&self, model: Option<&str>, task: &str)
                    -> Result<LaneRef, ServeError> {
        for attempt in 0..Self::SWAP_RETRIES {
            let dep = self
                .registry
                .resolve(model)
                .map_err(|e| ServeError::Failed(format!("{e:#}")))?;
            let lane = match dep.lane(task) {
                Ok(Some(l)) => l,
                Ok(None) => {
                    if self.registry.is_closed() {
                        return Err(ServeError::ShuttingDown);
                    }
                    Self::swap_backoff(attempt);
                    continue;
                }
                Err(e) => return Err(ServeError::Failed(format!("{e:#}"))),
            };
            let pipe = dep
                .router
                .pipeline(task)
                .map_err(|e| ServeError::Failed(format!("{e:#}")))?;
            return Ok(LaneRef { _deployment: dep, lane, pipe });
        }
        self.counters.inc_swap_retry_exhausted();
        Err(ServeError::ShuttingDown)
    }

    /// Enqueue one text request and wait for its result.
    pub fn infer(&self, task: &str, text: &str) -> Result<TaskOutput, ServeError> {
        self.infer_many(task, &[text])
            .pop()
            .expect("infer_many returns one result per text")
    }

    /// Enqueue-all / collect-all against the default model (see
    /// [`Server::infer_many_on`]).
    pub fn infer_many<S: AsRef<str>>(&self, task: &str, texts: &[S])
                      -> Vec<Result<TaskOutput, ServeError>> {
        self.infer_many_on(None, task, texts)
    }

    /// Enqueue-all / collect-all returning bare task outputs (the
    /// compatibility surface; deadline = `--default-deadline-ms`).  See
    /// [`Server::infer_rows_on`] for the full row results with
    /// `served_precision`.
    pub fn infer_many_on<S: AsRef<str>>(&self, model: Option<&str>,
                                        task: &str, texts: &[S])
                                        -> Vec<Result<TaskOutput, ServeError>> {
        self.infer_rows_on(model, task, texts, self.default_deadline())
            .into_iter()
            .map(|r| r.map(|row| row.output))
            .collect()
    }

    /// The process-wide default deadline (`--default-deadline-ms`; 0 = none)
    /// as an absolute instant from now.
    fn default_deadline(&self) -> Option<Instant> {
        (self.config.default_deadline_ms > 0).then(|| {
            Instant::now()
                + Duration::from_millis(self.config.default_deadline_ms)
        })
    }

    /// Enqueue-all / collect-all: tokenize and submit every text into the
    /// addressed model's task lane *before* waiting on any reply.  Returns
    /// one result per input text, in order; failures are per-row.  A row
    /// that races a generation swap (typed `Closed` push rejection) retries
    /// against the freshly-swapped generation, so reloads lose nothing.
    ///
    /// `deadline` is the absolute end-to-end deadline every row carries
    /// through admission and batch forming: a row still queued past it is
    /// dropped *before* the forward pass and answered
    /// [`ServeError::DeadlineExceeded`] (HTTP 504) — late answers cost
    /// engine time twice (the wasted pass plus the retry the client already
    /// sent), so expired work is shed, not served.
    pub fn infer_rows_on<S: AsRef<str>>(&self, model: Option<&str>,
                                        task: &str, texts: &[S],
                                        deadline: Option<Instant>)
                                        -> Vec<Result<RowOutput, ServeError>> {
        self.counters.inc_requests(texts.len() as u64);
        let flight = self.registry.flight_recorder();
        let t0 = Instant::now();
        let mut ctx = match self.resolve_lane(model, task) {
            Ok(c) => c,
            Err(e) => {
                // every row fails: error accounting stays per-row so
                // errors/requests remains a meaningful failure rate
                self.counters.inc_errors_n(texts.len() as u64);
                self.counters.latency.record_us(
                    t0.elapsed().as_secs_f64() * 1e6);
                return texts.iter().map(|_| Err(e.clone())).collect();
            }
        };
        // the model id the flight recorder and rung windows file under
        // (resolve_lane already disambiguated None to the default model)
        let model_id = ctx._deployment.model_id.clone();
        flight.instant(&model_id, task, "admit", texts.len() as u64, "");
        // phase 1: submit all rows (each carries its tokenize time so the
        // stage trace can report it once the row completes)
        type Pending = Result<mpsc::Receiver<Result<RowOutput, RowError>>,
                              ServeError>;
        let mut pending: Vec<(u64, Pending)> = Vec::with_capacity(texts.len());
        'rows: for text in texts {
            if deadline.is_some_and(|d| Instant::now() >= d) {
                // already late at admission: don't even tokenize
                self.counters.inc_deadline_expired(1);
                self.counters.inc_errors();
                pending.push((0, Err(ServeError::DeadlineExceeded)));
                continue 'rows;
            }
            let mut swaps = 0usize;
            let mut tok_us = 0u64;
            loop {
                let tok_start = Instant::now();
                let enc = ctx.pipe.encode_text(text.as_ref());
                tok_us += tok_start.elapsed().as_micros() as u64;
                let (tx, rx) = mpsc::channel();
                match ctx.lane.batcher.push_with_deadline(enc, tx, deadline) {
                    Ok(()) => {
                        pending.push((tok_us, Ok(rx)));
                        continue 'rows;
                    }
                    Err(PushError::Overloaded(_reply)) => {
                        // shed: the row never entered the queue — answer 429
                        self.counters.inc_errors();
                        pending.push((tok_us, Err(ServeError::Overloaded)));
                        continue 'rows;
                    }
                    Err(PushError::Closed(_reply)) => {
                        // generation swapped (or shutdown): re-resolve and
                        // retry this row on the current generation
                        swaps += 1;
                        if swaps >= Self::SWAP_RETRIES {
                            self.counters.inc_swap_retry_exhausted();
                            self.counters.inc_errors();
                            pending
                                .push((tok_us, Err(ServeError::ShuttingDown)));
                            continue 'rows;
                        }
                        Self::swap_backoff(swaps - 1);
                        match self.resolve_lane(model, task) {
                            Ok(c) => ctx = c,
                            Err(e) => {
                                self.counters.inc_errors();
                                pending.push((tok_us, Err(e)));
                                continue 'rows;
                            }
                        }
                    }
                }
            }
        }
        // phase 2: collect in submission order
        let results: Vec<Result<RowOutput, ServeError>> = pending
            .into_iter()
            .map(|(tok_us, p)| match p {
                Ok(rx) => rx
                    .recv()
                    .map_err(|_| ServeError::Failed("dispatcher gone".into()))
                    .and_then(|r| r.map_err(ServeError::from))
                    .map(|mut row| {
                        if let Some(t) = row.timings.as_mut() {
                            t.tokenize_us = tok_us;
                        }
                        row
                    }),
                Err(e) => Err(e),
            })
            .collect();
        let us = t0.elapsed().as_secs_f64() * 1e6;
        self.counters.latency.record_us(us);
        ctx.lane.stats.latency.record_us(us);
        // the rolling windows drive the SLO ladder: record *served* rows
        // only, because sheds and deadline drops answer in microseconds and
        // would drag the recent p99 down exactly when the lane is drowning
        if results.iter().any(|r| r.is_ok()) {
            self.counters.recent_latency.record_us(us);
            ctx.lane.stats.recent.record_us(us);
        }
        // per-rung latency attribution: the same end-to-end latency, filed
        // under the precision rung that actually served each row — the
        // observed cost of every ladder level (samp_rung_latency_us)
        for row in results.iter().flatten() {
            ctx.lane.stats.rung_latency.record_us(&row.served_variant, us);
        }
        // automatic slow-row capture: any row past the lane SLO lands in
        // the flight recorder with its full stage breakdown
        let slo_us = self.config.slo_p99_ms.saturating_mul(1000);
        if slo_us > 0 && us > slo_us as f64 {
            if let Some(row) = results.iter().flatten().next() {
                let detail = match &row.timings {
                    Some(t) => format!(
                        "rung `{}` tokenize {}us queue {}us form {}us \
                         forward {}us (gemm {}us) decode {}us",
                        row.served_variant, t.tokenize_us, t.queue_us,
                        t.form_us, t.forward_us, t.gemm_us, t.decode_us),
                    None => format!("rung `{}`", row.served_variant),
                };
                flight.span(&model_id, task, "slow_row", us as u64,
                            texts.len() as u64, detail);
            }
        }
        results
    }

    /// Serve until `stop` is flagged, then drain every generation through
    /// the registry's retire path (in-flight rows finish; workers join).
    /// Binds `config.addr`.
    pub fn run(self: &Arc<Self>) -> Result<()> {
        let listener = TcpListener::bind(&self.config.addr)
            .with_context(|| format!("binding {}", self.config.addr))?;
        listener.set_nonblocking(true)?;
        let pool = ThreadPool::new(self.config.workers.max(1));
        eprintln!("[server] listening on {} ({} http workers, {} dispatcher \
                   shards per lane, {} engine replica(s) per lane, {} \
                   model(s))",
                  self.config.addr, self.config.workers,
                  self.config.resolved_workers_per_lane().max(1),
                  self.registry.lane_config().replicas_per_lane,
                  self.registry.model_count());
        if self.config.watch_manifest {
            let me = self.clone();
            std::thread::spawn(move || me.watch_manifests());
        }
        while !self.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    let me = self.clone();
                    pool.execute(move || me.handle(stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => {
                    eprintln!("[server] accept error: {e}");
                }
            }
        }
        eprintln!("[server] draining {} model(s)", self.registry.model_count());
        self.registry.drain_all();
        Ok(())
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// Graceful drain without the accept loop (programmatic servers /
    /// tests): every generation closes its lanes, in-flight rows finish,
    /// dispatcher workers join.
    pub fn drain(&self) {
        self.registry.drain_all();
    }

    /// `--watch-manifest`: poll each model's `manifest.json` mtime and
    /// hot-reload the model when it changes — `samp plan` into a served
    /// artifacts directory goes live without a restart.
    fn watch_manifests(self: Arc<Self>) {
        let interval =
            Duration::from_millis(self.config.watch_interval_ms.max(50));
        let mut seen: std::collections::HashMap<String, ManifestStamp> =
            Default::default();
        // record the state at startup so only *changes* trigger reloads
        for entry in self.registry.entries() {
            if let Some(t) = manifest_stamp(&entry.artifacts_dir) {
                seen.insert(entry.id.clone(), t);
            }
        }
        while !self.stop.load(Ordering::Relaxed) {
            std::thread::sleep(interval);
            for entry in self.registry.entries() {
                let Some(t) = manifest_stamp(&entry.artifacts_dir) else {
                    continue;
                };
                let changed = match seen.get(&entry.id) {
                    Some(prev) => *prev != t,
                    None => true,
                };
                if !changed {
                    continue;
                }
                seen.insert(entry.id.clone(), t);
                eprintln!("[serve] {}: manifest changed on disk — reloading",
                          entry.id);
                match self.registry.reload(&entry.id, None) {
                    Ok(dep) => eprintln!("[serve] {}: generation {} live",
                                         entry.id, dep.generation),
                    Err(e) => eprintln!(
                        "[serve] {}: reload failed ({e:#}); the previous \
                         generation keeps serving", entry.id),
                }
            }
        }
    }

    fn handle(&self, mut stream: TcpStream) {
        let req = match read_request(&mut stream) {
            Ok(r) => r,
            Err(e) => {
                let _ = write_response(&mut stream, 400, &Json::obj(vec![
                    ("error", Json::str(format!("bad request: {e}"))),
                ]).to_string());
                return;
            }
        };
        if req.method == "GET" && req.path == "/metrics" {
            // Prometheus text exposition, not JSON — rendered and written
            // outside the JSON dispatch path
            let body = telemetry::render_prometheus(&self.registry);
            let _ = write_response_typed(&mut stream, 200,
                                         "text/plain; version=0.0.4", &body,
                                         &[]);
            let _ = stream.flush();
            return;
        }
        let (status, body) = self.dispatch(&req);
        // shed responses carry Retry-After so well-behaved clients back off
        // instead of hammering an overloaded or draining server
        let extra: &[(&str, String)] = if status == 429 || status == 503 {
            &[("Retry-After", String::from("1"))]
        } else {
            &[]
        };
        let _ = write_response_with(&mut stream, status, &body.to_string(),
                                    extra);
        let _ = stream.flush();
    }

    fn dispatch(&self, req: &HttpRequest) -> (u16, Json) {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => (200, Json::obj(vec![("ok", Json::Bool(true))])),
            ("GET", "/v1/models") => self.models_endpoint(),
            ("GET", "/v1/plan") => self.plan_endpoint(),
            ("GET", "/v1/stats") => self.stats_endpoint(),
            ("GET", "/v1/debug/fault") => (200, Json::obj(vec![
                ("spec", Json::str(fault::current_spec())),
                ("injected", Json::num(fault::injected_total() as f64)),
            ])),
            ("GET", path) if path == "/v1/debug/trace"
                || path.starts_with("/v1/debug/trace?") =>
            {
                self.trace_endpoint(path)
            }
            ("POST", "/v1/debug/fault") => self.fault_endpoint(req),
            ("POST", "/v1/infer") => self.infer_endpoint(req, false),
            ("POST", "/v1/batch") => self.infer_endpoint(req, true),
            ("POST", path) if path.starts_with("/v1/models/") => {
                let inner = &path["/v1/models/".len()..];
                match inner.strip_suffix("/reload") {
                    Some(id) if !id.is_empty() => self.reload_endpoint(id, req),
                    _ => (404, Json::obj(vec![
                        ("error", Json::str("not found"))])),
                }
            }
            _ => (404, Json::obj(vec![("error", Json::str("not found"))])),
        }
    }

    /// `POST /v1/models/{id}/reload` — rebuild the model's deployment from
    /// its artifacts directory (optionally activating `{"variant": name}` on
    /// every task), warm it, swap it in, drain the old generation.
    fn reload_endpoint(&self, id: &str, req: &HttpRequest) -> (u16, Json) {
        let variant = if req.body.trim().is_empty() {
            None
        } else {
            match Json::parse(&req.body) {
                Ok(b) => b.get("variant").as_str().map(String::from),
                Err(e) => {
                    return (400, Json::obj(vec![
                        ("error", Json::str(format!("bad json: {e}")))]));
                }
            }
        };
        if self.registry.entry(id).is_none() {
            return (404, Json::obj(vec![
                ("error", Json::str(format!("unknown model `{id}`")))]));
        }
        match self.registry.reload(id, variant.as_deref()) {
            Ok(dep) => (200, Json::obj(vec![
                ("model", Json::str(id)),
                ("generation", Json::num(dep.generation as f64)),
                ("tasks", Json::arr(dep.tasks().into_iter().map(Json::str))),
                ("warmed", Json::Bool(true)),
            ])),
            Err(e) => (500, Json::obj(vec![
                ("error", Json::str(format!("reload failed: {e:#}")))])),
        }
    }

    /// `GET /v1/debug/trace[?secs=N]` — dump the flight recorder's last N
    /// seconds (default 60) as Chrome trace-event JSON: one track per lane,
    /// admit/form/steal/dispatch/rung-shift/heal/reply lifecycle events
    /// plus automatic `slow_row` captures.  Loads directly in
    /// `chrome://tracing` / Perfetto.
    fn trace_endpoint(&self, path: &str) -> (u16, Json) {
        let secs = path
            .split_once('?')
            .map(|(_, q)| q)
            .and_then(|q| {
                q.split('&')
                    .find_map(|kv| kv.strip_prefix("secs="))
                    .and_then(|v| v.parse::<u64>().ok())
            })
            .unwrap_or(60)
            .clamp(1, 3600);
        let flight = self.registry.flight_recorder();
        if !flight.enabled() {
            return (404, Json::obj(vec![
                ("error", Json::str("flight recorder is disabled \
                                     (--no-flight-recorder)"))]));
        }
        (200, flight.trace_json(Duration::from_secs(secs)))
    }

    /// `POST /v1/debug/fault` — install a fault-injection spec at runtime
    /// (`{"spec": "gemm_panic:1:3,slow_forward:50ms"}`; empty spec clears).
    /// The same grammar as the `SAMP_FAULT` env var; chaos tests drive the
    /// self-healing machinery through this without restarting the server.
    fn fault_endpoint(&self, req: &HttpRequest) -> (u16, Json) {
        let spec = if req.body.trim().is_empty() {
            String::new()
        } else {
            match Json::parse(&req.body) {
                Ok(b) => b.get("spec").as_str().unwrap_or("").to_string(),
                Err(e) => {
                    return (400, Json::obj(vec![
                        ("error", Json::str(format!("bad json: {e}")))]));
                }
            }
        };
        match fault::set_spec(&spec) {
            Ok(()) => (200, Json::obj(vec![
                ("spec", Json::str(fault::current_spec())),
                ("injected", Json::num(fault::injected_total() as f64)),
            ])),
            Err(e) => (400, Json::obj(vec![
                ("error", Json::str(format!("bad fault spec: {e:#}")))])),
        }
    }

    /// `GET /v1/models` — the registry: per model, its current generation,
    /// replica configuration, task specs and live-lane stats.
    fn models_endpoint(&self) -> (u16, Json) {
        let models: Vec<Json> = self
            .registry
            .entries()
            .iter()
            .map(|entry| {
                let dep = entry.current();
                let budget = self.registry.lane_config().budget(&entry.id);
                let tasks: Vec<Json> = dep
                    .router
                    .manifest
                    .models
                    .iter()
                    .map(|m| {
                        Json::obj(vec![
                            ("task", Json::str(m.task.clone())),
                            ("kind", Json::str(m.kind.clone())),
                            ("seq_len", Json::num(m.seq_len as f64)),
                            ("num_labels", Json::num(m.num_labels as f64)),
                            ("variants", Json::arr(
                                m.variants.keys().map(|k| Json::str(k.clone())))),
                        ])
                    })
                    .collect();
                let lanes: Vec<Json> = dep
                    .lanes_snapshot()
                    .iter()
                    .map(|lane| {
                        // per-replica native kernel identity: ISA rung, GEMM
                        // thread count, observed pool pinning (null replicas
                        // run on PJRT)
                        let kernels: Vec<Json> = lane
                            .replicas
                            .kernel_snapshot()
                            .into_iter()
                            .map(|k| match k {
                                Some(k) => Json::obj(vec![
                                    ("isa", Json::str(k.isa)),
                                    ("gemm_threads", Json::num(
                                        k.threads as f64)),
                                    ("pinned_cores", Json::arr(
                                        k.pinned.iter().map(|p| match p {
                                            Some(c) => Json::num(*c as f64),
                                            None => Json::Null,
                                        }))),
                                ]),
                                None => Json::Null,
                            })
                            .collect();
                        // the SLO precision ladder's live state: rung list
                        // (default first), current level, served variant
                        let ladder = match &lane.ladder {
                            Some(l) => Json::obj(vec![
                                ("rungs", Json::arr(
                                    l.rungs().iter().map(|r| Json::str(
                                        r.clone())))),
                                ("level", Json::num(l.level() as f64)),
                                ("served_variant", Json::str(l.served())),
                            ]),
                            None => Json::Null,
                        };
                        // observed per-rung cost: rolling latency windows
                        // keyed by the served_precision that ran the rows
                        let mut rungs = std::collections::BTreeMap::new();
                        for (rung, w) in lane.stats.rung_latency.snapshot() {
                            let (Some(p50), Some(p99)) =
                                (w.percentile_opt_us(50.0),
                                 w.percentile_opt_us(99.0))
                            else {
                                continue;
                            };
                            rungs.insert(rung, Json::obj(vec![
                                ("p50_us", Json::num(p50)),
                                ("p99_us", Json::num(p99)),
                                ("rows", Json::num(w.total() as f64)),
                            ]));
                        }
                        let rung_latency = Json::Obj(rungs);
                        Json::obj(vec![
                            ("task", Json::str(lane.stats.task())),
                            ("workers", Json::num(
                                lane.stats.workers() as f64)),
                            ("replicas", Json::num(
                                lane.replicas.len() as f64)),
                            ("batches", Json::num(lane.stats.batches() as f64)),
                            ("rows", Json::num(lane.stats.rows() as f64)),
                            ("queue_depth", Json::num(
                                lane.batcher.len() as f64)),
                            ("ladder", ladder),
                            ("rung_latency", rung_latency),
                            ("replica_kernels", Json::Arr(kernels)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("id", Json::str(entry.id.clone())),
                    ("generation", Json::num(entry.generation() as f64)),
                    ("artifacts", Json::str(
                        entry.artifacts_dir.display().to_string())),
                    ("replicas_per_lane", Json::num(
                        self.registry.lane_config().replicas_per_lane as f64)),
                    // the model's slice of the global dispatcher/queue pool
                    // (--lane-weight; share 0 = outside the startup budget,
                    // serving the flat per-lane split)
                    ("lane_weight", Json::num(budget.weight)),
                    ("budget_share", Json::num(budget.share)),
                    ("worker_budget", Json::num(budget.workers as f64)),
                    ("queue_budget", Json::num(budget.queue_depth as f64)),
                    ("stolen_inflight", Json::num(
                        dep.stolen_inflight() as f64)),
                    ("draining", Json::Bool(dep.is_draining())),
                    ("tasks", Json::Arr(tasks)),
                    ("lanes", Json::Arr(lanes)),
                ])
            })
            .collect();
        (200, Json::obj(vec![
            ("models", Json::Arr(models)),
            ("reloads", Json::num(self.registry.reload_count() as f64)),
            ("generations_retired", Json::num(
                self.registry.retired_count() as f64)),
        ]))
    }

    /// `GET /v1/plan` — the plan each ACTIVE pipeline serves with (written
    /// by `samp plan` / `Router::activate` / reload), without forcing cold
    /// tasks to load.
    fn plan_endpoint(&self) -> (u16, Json) {
        let mut tasks: Vec<Json> = Vec::new();
        for entry in self.registry.entries() {
            let dep = entry.current();
            for m in &dep.router.manifest.models {
                tasks.push(match dep.router.active(&m.task) {
                    Some(pipe) => Json::obj(vec![
                        ("model", Json::str(entry.id.clone())),
                        ("task", Json::str(m.task.clone())),
                        ("active_variant", Json::str(pipe.variant.clone())),
                        ("backend", Json::str(pipe.backend_name())),
                        ("int8_layers", Json::num(
                            pipe.plan()
                                .iter()
                                .filter(|x| x.is_int8())
                                .count() as f64)),
                        ("layer_modes", Json::arr(
                            pipe.plan()
                                .iter()
                                .map(|x| Json::str(x.as_str())))),
                        ("act_quant", Json::arr(
                            pipe.act_quant()
                                .iter()
                                .map(|s| Json::str(s.clone())))),
                    ]),
                    None => Json::obj(vec![
                        ("model", Json::str(entry.id.clone())),
                        ("task", Json::str(m.task.clone())),
                        ("active_variant", Json::Null),
                    ]),
                });
            }
        }
        (200, Json::obj(vec![("tasks", Json::Arr(tasks))]))
    }

    /// `GET /v1/stats` — registry-wide counters plus the per-lane
    /// shard-set / replica-set breakdown across every model.
    fn stats_endpoint(&self) -> (u16, Json) {
        let (reqs, batches, rows, errors) = self.counters.snapshot();
        let (pool_hits, pool_misses) = self.pool_stats();
        let lat = self.counters.latency.summary();
        let mut lanes: Vec<Json> = Vec::new();
        for entry in self.registry.entries() {
            let dep = entry.current();
            for lane in dep.lanes_snapshot() {
                let s = &lane.stats;
                let llat = s.latency.summary();
                let replicas = lane.replicas.snapshot();
                lanes.push(Json::obj(vec![
                    ("model", Json::str(entry.id.clone())),
                    ("generation", Json::num(dep.generation as f64)),
                    ("task", Json::str(s.task())),
                    ("workers", Json::num(s.workers() as f64)),
                    ("replicas", Json::num(lane.replicas.len() as f64)),
                    ("continuous", Json::Bool(s.continuous())),
                    ("batches", Json::num(s.batches() as f64)),
                    ("batch_fill", Json::num(s.batch_fill())),
                    ("queue_depth", Json::num(lane.batcher.len() as f64)),
                    ("shed", Json::num(lane.batcher.shed_count() as f64)),
                    ("worker_batches", Json::arr(
                        s.worker_batches.iter().map(|b| Json::num(
                            b.load(Ordering::Relaxed) as f64)))),
                    // core each dispatcher worker landed on (null = unpinned:
                    // no --pin-cores, or sched_setaffinity unavailable)
                    ("worker_pinned", Json::arr(
                        s.worker_pinned.iter().map(|p| {
                            let c = p.load(Ordering::Relaxed);
                            if c < 0 { Json::Null } else { Json::num(c as f64) }
                        }))),
                    ("replica_batches", Json::arr(
                        replicas.iter().map(|(_, b)| Json::num(*b as f64)))),
                    ("replicas_healed", Json::num(
                        lane.replicas.healed_count() as f64)),
                    ("served_variant", match &lane.ladder {
                        Some(l) => Json::str(l.served()),
                        None => Json::Null,
                    }),
                    // cross-lane work stealing: batches this lane's workers
                    // ran for siblings (in) / siblings ran for it (out)
                    ("steals_in", Json::num(
                        s.steals_in.load(Ordering::Relaxed) as f64)),
                    ("steals_out", Json::num(
                        s.steals_out.load(Ordering::Relaxed) as f64)),
                    ("latency_p50_us", Json::num(llat.p50_us)),
                    ("latency_p99_us", Json::num(llat.p99_us)),
                    // the rolling-window p99 the ladder controller actually
                    // compares against --slo-p99-ms (served rows only);
                    // null when the window is empty -- 0 would read as
                    // "infinitely fast" to dashboards and alert rules
                    ("recent_p99_ms", match s.recent.percentile_opt_us(99.0) {
                        Some(p99) => Json::num(p99 / 1000.0),
                        None => Json::Null,
                    }),
                ]));
            }
        }
        (200, Json::obj(vec![
            ("requests", Json::num(reqs as f64)),
            ("batches", Json::num(batches as f64)),
            ("batch_rows", Json::num(rows as f64)),
            ("errors", Json::num(errors as f64)),
            ("deadline_expired", Json::num(
                self.counters.deadline_expired.load(Ordering::Relaxed) as f64)),
            ("swap_retry_exhausted", Json::num(
                self.counters.swap_retry_exhausted.load(Ordering::Relaxed)
                    as f64)),
            ("replicas_healed", Json::num(
                self.counters.replicas_healed.load(Ordering::Relaxed) as f64)),
            ("ladder_shifts", Json::num(
                self.counters.ladder_shifts.load(Ordering::Relaxed) as f64)),
            ("steals", Json::num(
                self.counters.lane_steals.load(Ordering::Relaxed) as f64)),
            // per (victim, thief) steal counts, monotone across reloads
            ("steal_pairs", Json::Arr(
                self.registry
                    .steal_router()
                    .pairs()
                    .into_iter()
                    .map(|(from, to, n)| Json::obj(vec![
                        ("from", Json::str(from)),
                        ("to", Json::str(to)),
                        ("steals", Json::num(n as f64)),
                    ]))
                    .collect())),
            ("faults_injected", Json::num(fault::injected_total() as f64)),
            ("shed", Json::num(self.shed_count() as f64)),
            ("workers", Json::num(self.worker_count() as f64)),
            ("batch_fill", Json::num(self.counters.mean_batch_fill())),
            ("pool_hits", Json::num(pool_hits as f64)),
            ("pool_misses", Json::num(pool_misses as f64)),
            ("pool_hit_rate", Json::num(
                if pool_hits + pool_misses == 0 { 0.0 } else {
                    pool_hits as f64 / (pool_hits + pool_misses) as f64
                })),
            ("models", Json::num(self.registry.model_count() as f64)),
            ("reloads", Json::num(self.registry.reload_count() as f64)),
            ("generations_retired", Json::num(
                self.registry.retired_count() as f64)),
            ("latency_p50_us", Json::num(lat.p50_us)),
            ("latency_p95_us", Json::num(lat.p95_us)),
            ("latency_p99_us", Json::num(lat.p99_us)),
            ("lanes", Json::Arr(lanes)),
        ]))
    }

    fn infer_endpoint(&self, req: &HttpRequest, multi: bool) -> (u16, Json) {
        let body = match Json::parse(&req.body) {
            Ok(b) => b,
            Err(e) => {
                return (400, Json::obj(vec![
                    ("error", Json::str(format!("bad json: {e}")))]));
            }
        };
        let task = match body.get("task").as_str() {
            Some(t) => t.to_string(),
            None => return (400, Json::obj(vec![
                ("error", Json::str("missing `task`"))])),
        };
        // multi-model: requests address {"model": id, ...}; absent = the
        // single/default model.  An unknown id is the client's addressing
        // mistake — answer 404 like the reload endpoint, not a 500
        let model = body.get("model").as_str().map(String::from);
        if let Some(id) = &model {
            if self.registry.entry(id).is_none() {
                return (404, Json::obj(vec![
                    ("error", Json::str(format!("unknown model `{id}`")))]));
            }
        }
        let texts: Vec<String> = if multi {
            // every entry must be a string: dropping bad rows would shift
            // results[] against the caller's texts[] indices
            let rows = body.get("texts").as_arr().unwrap_or(&[]);
            let strings: Vec<String> = rows
                .iter()
                .filter_map(|x| x.as_str().map(String::from))
                .collect();
            if strings.len() != rows.len() {
                return (400, Json::obj(vec![
                    ("error", Json::str("`texts` must be an array of strings"))]));
            }
            strings
        } else {
            body.get("text").as_str().map(|t| vec![t.to_string()])
                .unwrap_or_default()
        };
        if texts.is_empty() {
            return (400, Json::obj(vec![
                ("error", Json::str("missing `text`/`texts`"))]));
        }
        // end-to-end deadline: X-SAMP-Deadline-Ms wins, --default-deadline-ms
        // otherwise, 0/absent = none.  Absolute from request admission.
        let deadline_ms = match req.header("X-SAMP-Deadline-Ms") {
            Some(v) => match v.trim().parse::<u64>() {
                Ok(ms) => ms,
                Err(_) => {
                    return (400, Json::obj(vec![
                        ("error", Json::str(
                            "X-SAMP-Deadline-Ms must be a non-negative \
                             integer"))]));
                }
            },
            None => self.config.default_deadline_ms,
        };
        let deadline = (deadline_ms > 0)
            .then(|| Instant::now() + Duration::from_millis(deadline_ms));
        // per-request stage-timing echo: the server flag turns it on
        // globally, the header per request (any value but "0")
        let trace = match req.header("X-SAMP-Trace") {
            Some(v) => v.trim() != "0",
            None => self.config.trace_responses,
        };
        let outs = self.infer_rows_on(model.as_deref(), &task, &texts,
                                      deadline);
        if multi {
            // per-row results: one failed row yields one error object (with
            // a machine-readable `reason`), not a request-wide 500 — the
            // other rows' answers still come back.  The exceptions are
            // uniform failures: every row shed by admission control answers
            // the whole request 429, every row past its deadline 504.
            let status = if outs
                .iter()
                .all(|r| matches!(r, Err(ServeError::Overloaded)))
            {
                429
            } else if outs
                .iter()
                .all(|r| matches!(r, Err(ServeError::DeadlineExceeded)))
            {
                504
            } else {
                200
            };
            let results: Vec<Json> = outs
                .into_iter()
                .map(|r| match r {
                    Ok(row) => row_json_traced(&row, trace),
                    Err(e) => Json::obj(vec![
                        ("error", Json::str(e.to_string())),
                        ("reason", Json::str(e.reason())),
                    ]),
                })
                .collect();
            (status, Json::obj(vec![("results", Json::Arr(results))]))
        } else {
            match outs.into_iter().next().unwrap() {
                Ok(row) => (200, row_json_traced(&row, trace)),
                Err(e) => (e.status(), Json::obj(vec![
                    ("error", Json::str(e.to_string())),
                    ("reason", Json::str(e.reason())),
                ])),
            }
        }
    }
}

/// Spawn the self-healing thread: whenever a dispatcher worker heals a
/// poisoned GEMM pool in place ([`crate::registry::ReplicaSet::heal`]), it
/// sends the model id here and this thread answers with a full
/// [`Registry::reload`] — the wounded generation retires through the normal
/// swap-before-drain machinery and a cleanly rebuilt one takes over, with
/// zero dropped in-flight rows.  Exits when the registry closes.  Idempotent
/// per registry (the receiver can only be taken once).
fn spawn_healer(registry: &Arc<Registry>) {
    let Some(rx) = registry.heal_requests() else {
        return;
    };
    let registry = registry.clone();
    std::thread::spawn(move || {
        while !registry.is_closed() {
            let id = match rx.recv_timeout(Duration::from_millis(100)) {
                Ok(id) => id,
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            };
            // collapse the burst: every worker that saw the poisoned pool
            // sent a request, one rebuild answers them all
            while rx.try_recv().is_ok() {}
            eprintln!("[heal] model `{id}`: replica healed in place — \
                       rebuilding the generation behind it");
            match registry.reload(&id, None) {
                Ok(dep) => eprintln!(
                    "[heal] model `{id}`: generation {} live", dep.generation),
                Err(e) => eprintln!(
                    "[heal] model `{id}`: generation rebuild failed: {e:#} \
                     (the healed-in-place generation keeps serving)"),
            }
        }
    });
}

/// Change stamp of a watched manifest: (mtime, size).  Size is included
/// because two rewrites can land within the filesystem's mtime granularity
/// (e.g. back-to-back `samp plan` runs on a 1s-resolution filesystem) —
/// plan output virtually always changes the byte count too.
type ManifestStamp = (std::time::SystemTime, u64);

/// Stamp of `dir/manifest.json`, if readable (`--watch-manifest` polling).
fn manifest_stamp(dir: &Path) -> Option<ManifestStamp> {
    let meta = std::fs::metadata(dir.join("manifest.json")).ok()?;
    Some((meta.modified().ok()?, meta.len()))
}

/// Serialize one completed row for the wire: the task output plus the
/// `served_precision` variant that actually ran it — under ladder pressure
/// this may be a deeper-INT8 rung than the lane's default, and callers see
/// exactly which precision answered them.
pub fn row_json(row: &RowOutput) -> Json {
    row_json_traced(row, false)
}

/// [`row_json`] with an optional `"timings"` object (microseconds per
/// stage) when the request opted into tracing (`--trace-responses` or
/// `X-SAMP-Trace: 1`).
pub fn row_json_traced(row: &RowOutput, trace: bool) -> Json {
    let mut j = output_json(&row.output);
    if let Json::Obj(m) = &mut j {
        m.insert("served_precision".into(),
                 Json::str(row.served_variant.clone()));
        if trace {
            if let Some(t) = &row.timings {
                m.insert("timings".into(), Json::obj(vec![
                    ("tokenize_us", Json::num(t.tokenize_us as f64)),
                    ("queue_us", Json::num(t.queue_us as f64)),
                    ("form_us", Json::num(t.form_us as f64)),
                    ("forward_us", Json::num(t.forward_us as f64)),
                    ("gemm_us", Json::num(t.gemm_us as f64)),
                    ("decode_us", Json::num(t.decode_us as f64)),
                ]));
            }
        }
    }
    j
}

/// Serialize a task output for the wire.
pub fn output_json(out: &TaskOutput) -> Json {
    match out {
        TaskOutput::Classification(c) => Json::obj(vec![
            ("label", Json::num(c.label as f64)),
            ("confidence", Json::num(c.confidence as f64)),
            ("top_k", Json::arr(c.top_k.iter().map(|(l, p)| {
                Json::obj(vec![("label", Json::num(*l as f64)),
                               ("prob", Json::num(*p as f64))])
            }))),
        ]),
        TaskOutput::Matching(m) => Json::obj(vec![
            ("is_match", Json::Bool(m.is_match)),
            ("probability", Json::num(m.probability as f64)),
        ]),
        TaskOutput::Ner(ents) => Json::obj(vec![
            ("entities", Json::arr(ents.iter().map(|e| {
                Json::obj(vec![
                    ("start", Json::num(e.start as f64)),
                    ("end", Json::num(e.end as f64)),
                    ("type", Json::str(e.entity_type.clone())),
                ])
            }))),
        ]),
    }
}

/// Minimal blocking HTTP client for examples/tests.
pub fn http_post(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len());
    stream.write_all(req.as_bytes())?;
    http::read_response(&mut stream)
}

pub fn http_get(addr: &str, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    http::read_response(&mut stream)
}
