//! HTTP/1.1 JSON serving front-end over std::net (tokio unavailable offline).
//!
//! Endpoints:
//!   POST /v1/infer    {"task": "tnews", "text": "..."}            -> result
//!   POST /v1/batch    {"task": "...", "texts": ["...", ...]}      -> results
//!   GET  /v1/models                                               -> registry
//!   GET  /v1/plan     active precision plan per task (read-only)
//!   GET  /v1/stats                                                -> counters
//!   GET  /health                                                  -> ok
//!
//! Architecture: acceptor thread + a fixed worker [`ThreadPool`].  Each task
//! has one admission-controlled [`Batcher`] queue drained by a **shard set**
//! of N dispatcher workers (`--workers-per-lane`, default `min(4, cores)`).
//! Native-backend lanes form **continuous** batches — variable-shape
//! `[rows, bucket_seq]` blocks packed by token budget — and every row
//! **completes individually**: its reply channel fires as soon as its own
//! logits are decoded ([`crate::coordinator::Pipeline::decode_row`]), so a
//! short row's tail latency is decoupled from its batch mates' decode work
//! and, bucketing aside, from other buckets' long sequences.  For the
//! CPU-bound single-device runtime this mirrors the vLLM/TurboTransformers
//! queue->batch->execute loop without an async reactor.
//!
//! # Serving hot path
//!
//! A steady-state request crosses exactly these synchronization points:
//!
//! 1. **Lane lookup** — `lanes` is an `RwLock` map; existing lanes resolve
//!    under a read lock (the write lock is taken once per task lifetime, to
//!    start the lane's shard set).  The `Runtime` engine cache and the
//!    `Router` pipeline table follow the same read-mostly pattern.
//! 2. **Enqueue-all / collect-all** — [`Server::infer_many`] tokenizes and
//!    enqueues *every* row of a multi-text request into the lane's batcher
//!    (each with its own oneshot reply channel) before blocking on the first
//!    reply.  An N-text `/v1/batch` request therefore fills real batches;
//!    the previous submit-one/wait-one loop could never form a batch > 1
//!    from a single connection.  Row failures are per-row: one bad row
//!    yields one `{"error": ...}` entry, not a request-wide 500.
//! 3. **Sharded dispatch** — N workers pull from the shared queue; forming
//!    happens under the queue mutex, so each batch goes to exactly one
//!    worker and workers run batches (and different seq-length buckets)
//!    concurrently.  The pipeline's `Arc<dyn Backend>` halves are reentrant
//!    (`Backend: Send + Sync`, `&self` calls — statically asserted in
//!    `runtime`); the native encoder pools per-worker scratch.
//! 4. **Pooled blocks** — the batcher forms batches into [`BlockPool`]
//!    blocks; each dispatcher worker recycles its block after `run_block`,
//!    so no tensor allocation happens per batch in steady state — continuous
//!    lanes reuse the same storage across `[rows, bucket_seq]` geometries.
//!    Pool hit/miss counts are exported via `/v1/stats`
//!    (`pool_hits`/`pool_misses`).
//! 5. **Lock-free metrics** — request latency lands in atomic
//!    [`Histogram`](crate::metrics::Histogram)s (server-wide + per lane);
//!    `/v1/stats` serves p50/p95/p99 (and per-lane p99) without stopping
//!    traffic.  Aggregate shed/pool counters live on the server's
//!    [`Counters`], so totals stay monotonic even across lane rebuilds.
//! 6. **Admission control** — each lane's batcher queue is capped
//!    (`ServerConfig::max_queue_depth`); pushes beyond the cap are shed
//!    with [`ServeError::Overloaded`] → HTTP 429 and counted in the
//!    `/v1/stats` `shed` field, so overload turns into fast, retryable
//!    rejections instead of unbounded queue growth — with N workers exactly
//!    as with one.
//!
//! Lifecycle of a pooled block: `checkout_shaped` (stale) → `set_row` ×
//! rows → `reset_rows(rows)` (scrub dirty tail) → engine → per-row decode +
//! reply → `recycle` → next batch.
//!
//! The engines behind a lane may be PJRT executables or the native backend
//! (`backend::native`) — the dispatcher neither knows nor cares; see
//! `coordinator::pipeline` for the selection rule.  PJRT lanes keep fixed
//! `[batch, seq]` forming (their HLO shape is static); native lanes opt into
//! continuous forming automatically.

pub mod http;
pub mod threadpool;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::ServerConfig;
use crate::coordinator::batcher::{Batcher, PushError};
use crate::coordinator::{Router, TaskOutput};
use crate::metrics::{Counters, Histogram};
use crate::util::json::Json;

use http::{read_request, write_response, HttpRequest};
use threadpool::ThreadPool;

/// Reply handle: the worker blocks on the receiver.
type Reply = mpsc::Sender<Result<TaskOutput, String>>;

/// Why a request (or one row of a batch request) failed, with its HTTP
/// status.  Typed so `/v1/*` can answer 429 on admission-control shedding
/// instead of a generic 500.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Shed by the batcher's queue-depth cap — retry later (HTTP 429).
    Overloaded,
    /// The lane is shutting down (HTTP 503).
    ShuttingDown,
    /// Pipeline/engine failure (HTTP 500).
    Failed(String),
}

impl ServeError {
    pub fn status(&self) -> u16 {
        match self {
            ServeError::Overloaded => 429,
            ServeError::ShuttingDown => 503,
            ServeError::Failed(_) => 500,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => {
                write!(f, "server overloaded: batch queue is full, retry later")
            }
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::Failed(msg) => write!(f, "{msg}"),
        }
    }
}

/// Per-lane observability: what each dispatcher worker of the shard set
/// did, plus the lane's own request-latency histogram (`/v1/stats` reports
/// the per-lane p99 the tentpole decouples from other lanes).
struct LaneStats {
    task: String,
    continuous: bool,
    worker_batches: Vec<AtomicU64>,
    worker_rows: Vec<AtomicU64>,
    latency: Histogram,
}

impl LaneStats {
    fn new(task: &str, continuous: bool, workers: usize) -> LaneStats {
        LaneStats {
            task: task.to_string(),
            continuous,
            worker_batches: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            worker_rows: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            latency: Histogram::new(),
        }
    }

    fn workers(&self) -> usize {
        self.worker_batches.len()
    }

    fn batches(&self) -> u64 {
        self.worker_batches
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum()
    }

    fn rows(&self) -> u64 {
        self.worker_rows.iter().map(|r| r.load(Ordering::Relaxed)).sum()
    }

    fn batch_fill(&self) -> f64 {
        let b = self.batches();
        if b == 0 {
            return 0.0;
        }
        self.rows() as f64 / b as f64
    }
}

struct TaskLane {
    batcher: Arc<Batcher<Reply>>,
    stats: Arc<LaneStats>,
    _workers: Vec<std::thread::JoinHandle<()>>,
}

/// The serving coordinator.
pub struct Server {
    pub config: ServerConfig,
    router: Arc<Router>,
    counters: Arc<Counters>,
    lanes: RwLock<std::collections::HashMap<String, Arc<TaskLane>>>,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(config: ServerConfig, router: Arc<Router>) -> Server {
        Server {
            config,
            router,
            counters: Arc::new(Counters::default()),
            lanes: RwLock::new(Default::default()),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn counters(&self) -> Arc<Counters> {
        self.counters.clone()
    }

    /// Aggregate (hits, misses) of every lane's block pool, ever — read
    /// from the server-wide [`Counters`] sink, so the totals are monotonic
    /// even if a lane is torn down and rebuilt.
    pub fn pool_stats(&self) -> (u64, u64) {
        (self.counters.pool_hits.load(Ordering::Relaxed),
         self.counters.pool_misses.load(Ordering::Relaxed))
    }

    /// Total pushes shed by admission control across every lane, ever
    /// (monotonic — same [`Counters`] sink as [`Server::pool_stats`]).
    pub fn shed_count(&self) -> u64 {
        self.counters.shed.load(Ordering::Relaxed)
    }

    /// Dispatcher workers currently running across every live lane.
    pub fn worker_count(&self) -> usize {
        let lanes = self.lanes.read().unwrap();
        lanes.values().map(|l| l.stats.workers()).sum()
    }

    /// Get or start the batching lane for a task.  Steady state takes a read
    /// lock only; lane creation double-checks under the write lock so a
    /// racing pair of cold requests starts exactly one shard set.
    fn lane(&self, task: &str) -> Result<Arc<TaskLane>> {
        if let Some(l) = self.lanes.read().unwrap().get(task) {
            return Ok(l.clone());
        }
        let pipe = self.router.pipeline(task)?; // may compile; outside locks
        let mut lanes = self.lanes.write().unwrap();
        if let Some(l) = lanes.get(task) {
            return Ok(l.clone());
        }
        // Continuous (token-budget, variable-shape) forming needs a backend
        // without a static-shape constraint; PJRT artifacts are lowered at
        // a fixed [batch, seq], so those lanes keep fixed forming.
        let continuous = pipe.backend_name() == "native";
        let timeout = Duration::from_millis(self.config.batch_timeout_ms);
        // .max(1): a zero depth would trip the batcher's assert inside a
        // request thread; the CLI rejects 0 at startup, this guards
        // programmatic configs
        let depth = self.config.max_queue_depth.max(1);
        let batcher = if continuous {
            Batcher::<Reply>::continuous(
                pipe.spec.batch,
                pipe.spec.seq_len,
                timeout,
                depth,
                Batcher::<Reply>::default_granularity(pipe.spec.seq_len),
            )
        } else {
            Batcher::<Reply>::with_queue_depth(
                pipe.spec.batch, pipe.spec.seq_len, timeout, depth)
        };
        let batcher = Arc::new(batcher.with_counters(self.counters.clone()));
        let n_workers = self.config.resolved_workers_per_lane().max(1);
        let stats = Arc::new(LaneStats::new(task, continuous, n_workers));
        let workers = (0..n_workers)
            .map(|w| {
                let counters = self.counters.clone();
                let b2 = batcher.clone();
                let stats = stats.clone();
                let router = self.router.clone();
                let task_name = task.to_string();
                std::thread::spawn(move || {
                    Self::dispatch_loop(&router, &task_name, &b2, &counters,
                                        &stats, w)
                })
            })
            .collect();
        let lane = Arc::new(TaskLane { batcher, stats, _workers: workers });
        lanes.insert(task.to_string(), lane.clone());
        Ok(lane)
    }

    /// One dispatcher worker of a lane's shard set: drain batches from the
    /// shared queue, run the engine, then **complete rows individually** —
    /// each reply fires the moment its own row is decoded, so a row never
    /// waits on its batch mates' decode (NER BIO walks included).
    fn dispatch_loop(router: &Router, task: &str, batcher: &Batcher<Reply>,
                     counters: &Counters, stats: &LaneStats, worker: usize) {
        while let Some(fb) = batcher.next_batch() {
            counters.inc_batches(fb.rows as u64);
            stats.worker_batches[worker].fetch_add(1, Ordering::Relaxed);
            stats.worker_rows[worker].fetch_add(fb.rows as u64,
                                                Ordering::Relaxed);
            let crate::coordinator::FormedBatch { block, replies, .. } = fb;
            // re-resolve per batch (one read lock) so Router::activate
            // switches a live lane to the new variant; every variant of a
            // task shares the lane's [batch, seq] budget
            let result = router
                .pipeline(task)
                .and_then(|pipe| {
                    let logits = pipe.run_block(&block)?;
                    Ok((pipe, logits))
                });
            match result {
                Ok((pipe, logits)) => {
                    for (row, reply) in replies.into_iter().enumerate() {
                        let out = pipe.decode_row(&logits, &block, row);
                        let _ = reply.send(Ok(out));
                    }
                }
                Err(e) => {
                    counters.inc_errors();
                    let msg = format!("inference failed: {e:#}");
                    for reply in replies {
                        let _ = reply.send(Err(msg.clone()));
                    }
                }
            }
            // hand the tensor block back for the next form()
            batcher.recycle(block);
        }
    }

    /// Enqueue one text request and wait for its result.
    pub fn infer(&self, task: &str, text: &str) -> Result<TaskOutput, ServeError> {
        self.infer_many(task, &[text])
            .pop()
            .expect("infer_many returns one result per text")
    }

    /// Enqueue-all / collect-all: tokenize and submit every text into the
    /// task's batcher *before* waiting on any reply, so an N-text request
    /// fills real batches instead of N sequential 1-row dispatches.  Returns
    /// one result per input text, in order; failures are per-row.
    pub fn infer_many<S: AsRef<str>>(&self, task: &str, texts: &[S])
                      -> Vec<Result<TaskOutput, ServeError>> {
        self.counters.inc_requests(texts.len() as u64);
        let t0 = Instant::now();
        let resolved = self
            .router
            .pipeline(task)
            .and_then(|pipe| Ok((pipe, self.lane(task)?)));
        let (pipe, lane) = match resolved {
            Ok(r) => r,
            Err(e) => {
                // every row fails: error accounting stays per-row so
                // errors/requests remains a meaningful failure rate
                self.counters.inc_errors_n(texts.len() as u64);
                self.counters.latency.record_us(
                    t0.elapsed().as_secs_f64() * 1e6);
                let err = ServeError::Failed(format!("{e:#}"));
                return texts.iter().map(|_| Err(err.clone())).collect();
            }
        };
        // phase 1: submit all rows
        let mut pending = Vec::with_capacity(texts.len());
        for text in texts {
            let enc = pipe.encode_text(text.as_ref());
            let (tx, rx) = mpsc::channel();
            match lane.batcher.push(enc, tx) {
                Ok(()) => pending.push(Ok(rx)),
                Err(PushError::Overloaded(_reply)) => {
                    // shed: the row never entered the queue — answer 429
                    self.counters.inc_errors();
                    pending.push(Err(ServeError::Overloaded))
                }
                Err(PushError::Closed(_reply)) => {
                    self.counters.inc_errors();
                    pending.push(Err(ServeError::ShuttingDown))
                }
            }
        }
        // phase 2: collect in submission order
        let results: Vec<Result<TaskOutput, ServeError>> = pending
            .into_iter()
            .map(|p| match p {
                Ok(rx) => rx
                    .recv()
                    .map_err(|_| ServeError::Failed("dispatcher gone".into()))
                    .and_then(|r| r.map_err(ServeError::Failed)),
                Err(e) => Err(e),
            })
            .collect();
        let us = t0.elapsed().as_secs_f64() * 1e6;
        self.counters.latency.record_us(us);
        lane.stats.latency.record_us(us);
        results
    }

    /// Serve until `stop` is flagged. Binds `config.addr`.
    pub fn run(self: &Arc<Self>) -> Result<()> {
        let listener = TcpListener::bind(&self.config.addr)
            .with_context(|| format!("binding {}", self.config.addr))?;
        listener.set_nonblocking(true)?;
        let pool = ThreadPool::new(self.config.workers.max(1));
        eprintln!("[server] listening on {} ({} http workers, {} dispatcher \
                   shards per lane)",
                  self.config.addr, self.config.workers,
                  self.config.resolved_workers_per_lane().max(1));
        while !self.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    let me = self.clone();
                    pool.execute(move || me.handle(stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => {
                    eprintln!("[server] accept error: {e}");
                }
            }
        }
        for lane in self.lanes.read().unwrap().values() {
            lane.batcher.close();
        }
        Ok(())
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    fn handle(&self, mut stream: TcpStream) {
        let req = match read_request(&mut stream) {
            Ok(r) => r,
            Err(e) => {
                let _ = write_response(&mut stream, 400, &Json::obj(vec![
                    ("error", Json::str(format!("bad request: {e}"))),
                ]).to_string());
                return;
            }
        };
        let (status, body) = self.dispatch(&req);
        let _ = write_response(&mut stream, status, &body.to_string());
        let _ = stream.flush();
    }

    fn dispatch(&self, req: &HttpRequest) -> (u16, Json) {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => (200, Json::obj(vec![("ok", Json::Bool(true))])),
            ("GET", "/v1/models") => {
                let tasks: Vec<Json> = self
                    .router
                    .manifest
                    .models
                    .iter()
                    .map(|m| {
                        Json::obj(vec![
                            ("task", Json::str(m.task.clone())),
                            ("kind", Json::str(m.kind.clone())),
                            ("seq_len", Json::num(m.seq_len as f64)),
                            ("num_labels", Json::num(m.num_labels as f64)),
                            ("variants", Json::arr(
                                m.variants.keys().map(|k| Json::str(k.clone())))),
                        ])
                    })
                    .collect();
                (200, Json::obj(vec![("models", Json::Arr(tasks))]))
            }
            ("GET", "/v1/plan") => {
                // read-only: reports the plan each ACTIVE pipeline serves
                // with (written by `samp plan` / Router::activate) without
                // forcing cold tasks to load
                let tasks: Vec<Json> = self
                    .router
                    .manifest
                    .models
                    .iter()
                    .map(|m| match self.router.active(&m.task) {
                        Some(pipe) => Json::obj(vec![
                            ("task", Json::str(m.task.clone())),
                            ("active_variant", Json::str(pipe.variant.clone())),
                            ("backend", Json::str(pipe.backend_name())),
                            ("int8_layers", Json::num(
                                pipe.plan()
                                    .iter()
                                    .filter(|x| x.is_int8())
                                    .count() as f64)),
                            ("layer_modes", Json::arr(
                                pipe.plan()
                                    .iter()
                                    .map(|x| Json::str(x.as_str())))),
                            ("act_quant", Json::arr(
                                pipe.act_quant()
                                    .iter()
                                    .map(|s| Json::str(s.clone())))),
                        ]),
                        None => Json::obj(vec![
                            ("task", Json::str(m.task.clone())),
                            ("active_variant", Json::Null),
                        ]),
                    })
                    .collect();
                (200, Json::obj(vec![("tasks", Json::Arr(tasks))]))
            }
            ("GET", "/v1/stats") => {
                let (reqs, batches, rows, errors) = self.counters.snapshot();
                let (pool_hits, pool_misses) = self.pool_stats();
                let lat = self.counters.latency.summary();
                // per-lane shard-set breakdown: workers, fill, queue, p99
                let lanes: Vec<Json> = {
                    let lanes = self.lanes.read().unwrap();
                    let mut sorted: Vec<&Arc<TaskLane>> = lanes.values()
                        .collect();
                    sorted.sort_by(|a, b| a.stats.task.cmp(&b.stats.task));
                    sorted
                        .into_iter()
                        .map(|lane| {
                            let s = &lane.stats;
                            let llat = s.latency.summary();
                            Json::obj(vec![
                                ("task", Json::str(s.task.clone())),
                                ("workers", Json::num(s.workers() as f64)),
                                ("continuous", Json::Bool(s.continuous)),
                                ("batches", Json::num(s.batches() as f64)),
                                ("batch_fill", Json::num(s.batch_fill())),
                                ("queue_depth", Json::num(
                                    lane.batcher.len() as f64)),
                                ("shed", Json::num(
                                    lane.batcher.shed_count() as f64)),
                                ("worker_batches", Json::arr(
                                    s.worker_batches.iter().map(|b| Json::num(
                                        b.load(Ordering::Relaxed) as f64)))),
                                ("latency_p50_us", Json::num(llat.p50_us)),
                                ("latency_p99_us", Json::num(llat.p99_us)),
                            ])
                        })
                        .collect()
                };
                (200, Json::obj(vec![
                    ("requests", Json::num(reqs as f64)),
                    ("batches", Json::num(batches as f64)),
                    ("batch_rows", Json::num(rows as f64)),
                    ("errors", Json::num(errors as f64)),
                    ("shed", Json::num(self.shed_count() as f64)),
                    ("workers", Json::num(self.worker_count() as f64)),
                    ("batch_fill", Json::num(self.counters.mean_batch_fill())),
                    ("pool_hits", Json::num(pool_hits as f64)),
                    ("pool_misses", Json::num(pool_misses as f64)),
                    ("pool_hit_rate", Json::num(
                        if pool_hits + pool_misses == 0 { 0.0 } else {
                            pool_hits as f64 / (pool_hits + pool_misses) as f64
                        })),
                    ("latency_p50_us", Json::num(lat.p50_us)),
                    ("latency_p95_us", Json::num(lat.p95_us)),
                    ("latency_p99_us", Json::num(lat.p99_us)),
                    ("lanes", Json::Arr(lanes)),
                ]))
            }
            ("POST", "/v1/infer") => self.infer_endpoint(req, false),
            ("POST", "/v1/batch") => self.infer_endpoint(req, true),
            _ => (404, Json::obj(vec![("error", Json::str("not found"))])),
        }
    }

    fn infer_endpoint(&self, req: &HttpRequest, multi: bool) -> (u16, Json) {
        let body = match Json::parse(&req.body) {
            Ok(b) => b,
            Err(e) => {
                return (400, Json::obj(vec![
                    ("error", Json::str(format!("bad json: {e}")))]));
            }
        };
        let task = match body.get("task").as_str() {
            Some(t) => t.to_string(),
            None => return (400, Json::obj(vec![
                ("error", Json::str("missing `task`"))])),
        };
        let texts: Vec<String> = if multi {
            // every entry must be a string: dropping bad rows would shift
            // results[] against the caller's texts[] indices
            let rows = body.get("texts").as_arr().unwrap_or(&[]);
            let strings: Vec<String> = rows
                .iter()
                .filter_map(|x| x.as_str().map(String::from))
                .collect();
            if strings.len() != rows.len() {
                return (400, Json::obj(vec![
                    ("error", Json::str("`texts` must be an array of strings"))]));
            }
            strings
        } else {
            body.get("text").as_str().map(|t| vec![t.to_string()])
                .unwrap_or_default()
        };
        if texts.is_empty() {
            return (400, Json::obj(vec![
                ("error", Json::str("missing `text`/`texts`"))]));
        }
        let outs = self.infer_many(&task, &texts);
        if multi {
            // per-row results: one failed row yields one error object, not a
            // request-wide 500 (the other rows' answers still come back).
            // The exception is a fully-shed request: every row rejected by
            // admission control means the whole request gets the 429.
            let all_shed = outs
                .iter()
                .all(|r| matches!(r, Err(ServeError::Overloaded)));
            let status = if all_shed { 429 } else { 200 };
            let results: Vec<Json> = outs
                .into_iter()
                .map(|r| match r {
                    Ok(out) => output_json(&out),
                    Err(e) => Json::obj(vec![
                        ("error", Json::str(e.to_string()))]),
                })
                .collect();
            (status, Json::obj(vec![("results", Json::Arr(results))]))
        } else {
            match outs.into_iter().next().unwrap() {
                Ok(out) => (200, output_json(&out)),
                Err(e) => (e.status(),
                           Json::obj(vec![("error", Json::str(e.to_string()))])),
            }
        }
    }
}

/// Serialize a task output for the wire.
pub fn output_json(out: &TaskOutput) -> Json {
    match out {
        TaskOutput::Classification(c) => Json::obj(vec![
            ("label", Json::num(c.label as f64)),
            ("confidence", Json::num(c.confidence as f64)),
            ("top_k", Json::arr(c.top_k.iter().map(|(l, p)| {
                Json::obj(vec![("label", Json::num(*l as f64)),
                               ("prob", Json::num(*p as f64))])
            }))),
        ]),
        TaskOutput::Matching(m) => Json::obj(vec![
            ("is_match", Json::Bool(m.is_match)),
            ("probability", Json::num(m.probability as f64)),
        ]),
        TaskOutput::Ner(ents) => Json::obj(vec![
            ("entities", Json::arr(ents.iter().map(|e| {
                Json::obj(vec![
                    ("start", Json::num(e.start as f64)),
                    ("end", Json::num(e.end as f64)),
                    ("type", Json::str(e.entity_type.clone())),
                ])
            }))),
        ]),
    }
}

/// Minimal blocking HTTP client for examples/tests.
pub fn http_post(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len());
    stream.write_all(req.as_bytes())?;
    http::read_response(&mut stream)
}

pub fn http_get(addr: &str, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    http::read_response(&mut stream)
}
