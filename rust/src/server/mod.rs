//! HTTP/1.1 JSON serving front-end over std::net (tokio unavailable offline).
//!
//! Endpoints:
//!   POST /v1/infer    {"task": "tnews", "text": "..."}            -> result
//!   POST /v1/batch    {"task": "...", "texts": ["...", ...]}      -> results
//!   GET  /v1/models                                               -> registry
//!   GET  /v1/stats                                                -> counters
//!   GET  /health                                                  -> ok
//!
//! Architecture: acceptor thread + a fixed worker [`ThreadPool`].  Each task
//! has a dynamic [`Batcher`]; worker handlers enqueue encodings and a
//! dedicated dispatcher thread per task drains batches through the pipeline.
//! For the CPU-bound single-device runtime this mirrors the vLLM router's
//! queue->batch->execute loop without an async reactor.

pub mod http;
pub mod threadpool;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::ServerConfig;
use crate::coordinator::batcher::Batcher;
use crate::coordinator::{Router, TaskOutput};
use crate::metrics::Counters;
use crate::util::json::Json;

use http::{read_request, write_response, HttpRequest};
use threadpool::ThreadPool;

/// Reply handle: the worker blocks on the receiver.
type Reply = mpsc::Sender<Result<TaskOutput, String>>;

struct TaskLane {
    batcher: Arc<Batcher<Reply>>,
    _dispatcher: std::thread::JoinHandle<()>,
}

/// The serving coordinator.
pub struct Server {
    pub config: ServerConfig,
    router: Arc<Router>,
    counters: Arc<Counters>,
    lanes: Mutex<std::collections::HashMap<String, Arc<TaskLane>>>,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(config: ServerConfig, router: Arc<Router>) -> Server {
        Server {
            config,
            router,
            counters: Arc::new(Counters::default()),
            lanes: Mutex::new(Default::default()),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn counters(&self) -> Arc<Counters> {
        self.counters.clone()
    }

    /// Get or start the batching lane for a task.
    fn lane(&self, task: &str) -> Result<Arc<TaskLane>> {
        if let Some(l) = self.lanes.lock().unwrap().get(task) {
            return Ok(l.clone());
        }
        let pipe = self.router.pipeline(task)?;
        let batcher = Arc::new(Batcher::<Reply>::new(
            pipe.spec.batch,
            pipe.spec.seq_len,
            Duration::from_millis(self.config.batch_timeout_ms),
        ));
        let counters = self.counters.clone();
        let b2 = batcher.clone();
        let dispatcher = std::thread::spawn(move || {
            while let Some(fb) = b2.next_batch() {
                counters.inc_batches(fb.rows as u64);
                match pipe.run_block(&fb.block) {
                    Ok(logits) => {
                        let outs = pipe.decode(&logits, &fb.block, fb.rows);
                        for (reply, out) in fb.replies.into_iter().zip(outs) {
                            let _ = reply.send(Ok(out));
                        }
                    }
                    Err(e) => {
                        counters.inc_errors();
                        let msg = format!("inference failed: {e:#}");
                        for reply in fb.replies {
                            let _ = reply.send(Err(msg.clone()));
                        }
                    }
                }
            }
        });
        let lane = Arc::new(TaskLane { batcher, _dispatcher: dispatcher });
        self.lanes.lock().unwrap().insert(task.to_string(), lane.clone());
        Ok(lane)
    }

    /// Enqueue one text request and wait for its result.
    pub fn infer(&self, task: &str, text: &str) -> Result<TaskOutput, String> {
        self.counters.inc_requests(1);
        let pipe = self.router.pipeline(task).map_err(|e| format!("{e:#}"))?;
        let lane = self.lane(task).map_err(|e| format!("{e:#}"))?;
        let enc = pipe.encode_text(text);
        let (tx, rx) = mpsc::channel();
        lane.batcher.push(enc, tx);
        rx.recv().map_err(|_| "dispatcher gone".to_string())?
    }

    /// Serve until `stop` is flagged. Binds `config.addr`.
    pub fn run(self: &Arc<Self>) -> Result<()> {
        let listener = TcpListener::bind(&self.config.addr)
            .with_context(|| format!("binding {}", self.config.addr))?;
        listener.set_nonblocking(true)?;
        let pool = ThreadPool::new(self.config.workers.max(1));
        eprintln!("[server] listening on {} ({} workers)",
                  self.config.addr, self.config.workers);
        while !self.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    let me = self.clone();
                    pool.execute(move || me.handle(stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => {
                    eprintln!("[server] accept error: {e}");
                }
            }
        }
        for lane in self.lanes.lock().unwrap().values() {
            lane.batcher.close();
        }
        Ok(())
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    fn handle(&self, mut stream: TcpStream) {
        let req = match read_request(&mut stream) {
            Ok(r) => r,
            Err(e) => {
                let _ = write_response(&mut stream, 400, &Json::obj(vec![
                    ("error", Json::str(format!("bad request: {e}"))),
                ]).to_string());
                return;
            }
        };
        let (status, body) = self.dispatch(&req);
        let _ = write_response(&mut stream, status, &body.to_string());
        let _ = stream.flush();
    }

    fn dispatch(&self, req: &HttpRequest) -> (u16, Json) {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => (200, Json::obj(vec![("ok", Json::Bool(true))])),
            ("GET", "/v1/models") => {
                let tasks: Vec<Json> = self
                    .router
                    .manifest
                    .models
                    .iter()
                    .map(|m| {
                        Json::obj(vec![
                            ("task", Json::str(m.task.clone())),
                            ("kind", Json::str(m.kind.clone())),
                            ("seq_len", Json::num(m.seq_len as f64)),
                            ("num_labels", Json::num(m.num_labels as f64)),
                            ("variants", Json::arr(
                                m.variants.keys().map(|k| Json::str(k.clone())))),
                        ])
                    })
                    .collect();
                (200, Json::obj(vec![("models", Json::Arr(tasks))]))
            }
            ("GET", "/v1/stats") => {
                let (reqs, batches, rows, errors) = self.counters.snapshot();
                (200, Json::obj(vec![
                    ("requests", Json::num(reqs as f64)),
                    ("batches", Json::num(batches as f64)),
                    ("batch_rows", Json::num(rows as f64)),
                    ("errors", Json::num(errors as f64)),
                    ("mean_batch_fill", Json::num(self.counters.mean_batch_fill())),
                ]))
            }
            ("POST", "/v1/infer") => self.infer_endpoint(req, false),
            ("POST", "/v1/batch") => self.infer_endpoint(req, true),
            _ => (404, Json::obj(vec![("error", Json::str("not found"))])),
        }
    }

    fn infer_endpoint(&self, req: &HttpRequest, multi: bool) -> (u16, Json) {
        let body = match Json::parse(&req.body) {
            Ok(b) => b,
            Err(e) => {
                return (400, Json::obj(vec![
                    ("error", Json::str(format!("bad json: {e}")))]));
            }
        };
        let task = match body.get("task").as_str() {
            Some(t) => t.to_string(),
            None => return (400, Json::obj(vec![
                ("error", Json::str("missing `task`"))])),
        };
        let texts: Vec<String> = if multi {
            body.get("texts")
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from))
                     .collect())
                .unwrap_or_default()
        } else {
            body.get("text").as_str().map(|t| vec![t.to_string()])
                .unwrap_or_default()
        };
        if texts.is_empty() {
            return (400, Json::obj(vec![
                ("error", Json::str("missing `text`/`texts`"))]));
        }
        let mut results = Vec::with_capacity(texts.len());
        for t in &texts {
            match self.infer(&task, t) {
                Ok(out) => results.push(output_json(&out)),
                Err(e) => return (500, Json::obj(vec![("error", Json::str(e))])),
            }
        }
        if multi {
            (200, Json::obj(vec![("results", Json::Arr(results))]))
        } else {
            (200, results.into_iter().next().unwrap())
        }
    }
}

/// Serialize a task output for the wire.
pub fn output_json(out: &TaskOutput) -> Json {
    match out {
        TaskOutput::Classification(c) => Json::obj(vec![
            ("label", Json::num(c.label as f64)),
            ("confidence", Json::num(c.confidence as f64)),
            ("top_k", Json::arr(c.top_k.iter().map(|(l, p)| {
                Json::obj(vec![("label", Json::num(*l as f64)),
                               ("prob", Json::num(*p as f64))])
            }))),
        ]),
        TaskOutput::Matching(m) => Json::obj(vec![
            ("is_match", Json::Bool(m.is_match)),
            ("probability", Json::num(m.probability as f64)),
        ]),
        TaskOutput::Ner(ents) => Json::obj(vec![
            ("entities", Json::arr(ents.iter().map(|e| {
                Json::obj(vec![
                    ("start", Json::num(e.start as f64)),
                    ("end", Json::num(e.end as f64)),
                    ("type", Json::str(e.entity_type.clone())),
                ])
            }))),
        ]),
    }
}

/// Minimal blocking HTTP client for examples/tests.
pub fn http_post(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len());
    stream.write_all(req.as_bytes())?;
    http::read_response(&mut stream)
}

pub fn http_get(addr: &str, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    http::read_response(&mut stream)
}
