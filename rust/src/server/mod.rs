//! HTTP/1.1 JSON serving front-end over std::net (tokio unavailable offline).
//!
//! Endpoints:
//!   POST /v1/infer    {"task": "tnews", "text": "..."}            -> result
//!   POST /v1/batch    {"task": "...", "texts": ["...", ...]}      -> results
//!   GET  /v1/models                                               -> registry
//!   GET  /v1/plan     active precision plan per task (read-only)
//!   GET  /v1/stats                                                -> counters
//!   GET  /health                                                  -> ok
//!
//! Architecture: acceptor thread + a fixed worker [`ThreadPool`].  Each task
//! has a dynamic [`Batcher`]; worker handlers enqueue encodings and a
//! dedicated dispatcher thread per task drains batches through the pipeline.
//! For the CPU-bound single-device runtime this mirrors the vLLM router's
//! queue->batch->execute loop without an async reactor.
//!
//! # Serving hot path
//!
//! A steady-state request crosses exactly these synchronization points:
//!
//! 1. **Lane lookup** — `lanes` is an `RwLock` map; existing lanes resolve
//!    under a read lock (the write lock is taken once per task lifetime, to
//!    start the lane).  The `Runtime` engine cache and the `Router` pipeline
//!    table follow the same read-mostly pattern.
//! 2. **Enqueue-all / collect-all** — [`Server::infer_many`] tokenizes and
//!    enqueues *every* row of a multi-text request into the lane's batcher
//!    (each with its own oneshot reply channel) before blocking on the first
//!    reply.  An N-text `/v1/batch` request therefore fills real batches;
//!    the previous submit-one/wait-one loop could never form a batch > 1
//!    from a single connection.  Row failures are per-row: one bad row
//!    yields one `{"error": ...}` entry, not a request-wide 500.
//! 3. **Pooled blocks** — the batcher forms batches into [`BlockPool`]
//!    blocks; the dispatcher recycles each block after `run_block`, so no
//!    tensor allocation happens per batch in steady state.  Pool hit/miss
//!    counts are exported via `/v1/stats` (`pool_hits`/`pool_misses`).
//! 4. **Lock-free metrics** — request latency lands in an atomic
//!    [`Histogram`](crate::metrics::Histogram); `/v1/stats` serves
//!    p50/p95/p99 without stopping traffic.
//! 5. **Admission control** — each lane's batcher queue is capped
//!    (`ServerConfig::max_queue_depth`); pushes beyond the cap are shed
//!    with [`ServeError::Overloaded`] → HTTP 429 and counted in the
//!    `/v1/stats` `shed` field, so overload turns into fast, retryable
//!    rejections instead of unbounded queue growth.
//!
//! Lifecycle of a pooled block: `checkout` (stale) → `set_row` × rows →
//! `reset_rows(rows)` (scrub dirty tail) → engine → `recycle` → next batch.
//!
//! The engines behind a lane may be PJRT executables or the native backend
//! (`backend::native`) — the dispatcher neither knows nor cares; see
//! `coordinator::pipeline` for the selection rule.

pub mod http;
pub mod threadpool;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::ServerConfig;
use crate::coordinator::batcher::{Batcher, PushError};
use crate::coordinator::{Router, TaskOutput};
use crate::metrics::Counters;
use crate::util::json::Json;

use http::{read_request, write_response, HttpRequest};
use threadpool::ThreadPool;

/// Reply handle: the worker blocks on the receiver.
type Reply = mpsc::Sender<Result<TaskOutput, String>>;

/// Why a request (or one row of a batch request) failed, with its HTTP
/// status.  Typed so `/v1/*` can answer 429 on admission-control shedding
/// instead of a generic 500.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Shed by the batcher's queue-depth cap — retry later (HTTP 429).
    Overloaded,
    /// The lane is shutting down (HTTP 503).
    ShuttingDown,
    /// Pipeline/engine failure (HTTP 500).
    Failed(String),
}

impl ServeError {
    pub fn status(&self) -> u16 {
        match self {
            ServeError::Overloaded => 429,
            ServeError::ShuttingDown => 503,
            ServeError::Failed(_) => 500,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => {
                write!(f, "server overloaded: batch queue is full, retry later")
            }
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::Failed(msg) => write!(f, "{msg}"),
        }
    }
}

struct TaskLane {
    batcher: Arc<Batcher<Reply>>,
    _dispatcher: std::thread::JoinHandle<()>,
}

/// The serving coordinator.
pub struct Server {
    pub config: ServerConfig,
    router: Arc<Router>,
    counters: Arc<Counters>,
    lanes: RwLock<std::collections::HashMap<String, Arc<TaskLane>>>,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(config: ServerConfig, router: Arc<Router>) -> Server {
        Server {
            config,
            router,
            counters: Arc::new(Counters::default()),
            lanes: RwLock::new(Default::default()),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn counters(&self) -> Arc<Counters> {
        self.counters.clone()
    }

    /// Aggregate (hits, misses) of every lane's block pool.
    pub fn pool_stats(&self) -> (u64, u64) {
        let lanes = self.lanes.read().unwrap();
        lanes.values().fold((0, 0), |(h, m), lane| {
            let (lh, lm) = lane.batcher.pool().stats();
            (h + lh, m + lm)
        })
    }

    /// Total pushes shed by admission control across every lane.
    pub fn shed_count(&self) -> u64 {
        let lanes = self.lanes.read().unwrap();
        lanes.values().map(|lane| lane.batcher.shed_count()).sum()
    }

    /// Get or start the batching lane for a task.  Steady state takes a read
    /// lock only; lane creation double-checks under the write lock so a
    /// racing pair of cold requests starts exactly one dispatcher.
    fn lane(&self, task: &str) -> Result<Arc<TaskLane>> {
        if let Some(l) = self.lanes.read().unwrap().get(task) {
            return Ok(l.clone());
        }
        let pipe = self.router.pipeline(task)?; // may compile; outside locks
        let mut lanes = self.lanes.write().unwrap();
        if let Some(l) = lanes.get(task) {
            return Ok(l.clone());
        }
        // .max(1): a zero depth would trip the batcher's assert inside a
        // request thread; the CLI rejects 0 at startup, this guards
        // programmatic configs
        let batcher = Arc::new(Batcher::<Reply>::with_queue_depth(
            pipe.spec.batch,
            pipe.spec.seq_len,
            Duration::from_millis(self.config.batch_timeout_ms),
            self.config.max_queue_depth.max(1),
        ));
        let counters = self.counters.clone();
        let b2 = batcher.clone();
        let router = self.router.clone();
        let task_name = task.to_string();
        let dispatcher = std::thread::spawn(move || {
            while let Some(fb) = b2.next_batch() {
                counters.inc_batches(fb.rows as u64);
                let crate::coordinator::FormedBatch { block, replies, rows, .. } = fb;
                // re-resolve per batch (one read lock) so Router::activate
                // switches a live lane to the new variant; every variant of a
                // task shares the lane's static [batch, seq] shape
                let result = router
                    .pipeline(&task_name)
                    .and_then(|pipe| {
                        let logits = pipe.run_block(&block)?;
                        Ok(pipe.decode(&logits, &block, rows))
                    });
                match result {
                    Ok(outs) => {
                        for (reply, out) in replies.into_iter().zip(outs) {
                            let _ = reply.send(Ok(out));
                        }
                    }
                    Err(e) => {
                        counters.inc_errors();
                        let msg = format!("inference failed: {e:#}");
                        for reply in replies {
                            let _ = reply.send(Err(msg.clone()));
                        }
                    }
                }
                // hand the tensor block back for the next form()
                b2.recycle(block);
            }
        });
        let lane = Arc::new(TaskLane { batcher, _dispatcher: dispatcher });
        lanes.insert(task.to_string(), lane.clone());
        Ok(lane)
    }

    /// Enqueue one text request and wait for its result.
    pub fn infer(&self, task: &str, text: &str) -> Result<TaskOutput, ServeError> {
        self.infer_many(task, &[text])
            .pop()
            .expect("infer_many returns one result per text")
    }

    /// Enqueue-all / collect-all: tokenize and submit every text into the
    /// task's batcher *before* waiting on any reply, so an N-text request
    /// fills real batches instead of N sequential 1-row dispatches.  Returns
    /// one result per input text, in order; failures are per-row.
    pub fn infer_many<S: AsRef<str>>(&self, task: &str, texts: &[S])
                      -> Vec<Result<TaskOutput, ServeError>> {
        self.counters.inc_requests(texts.len() as u64);
        let t0 = Instant::now();
        let resolved = self
            .router
            .pipeline(task)
            .and_then(|pipe| Ok((pipe, self.lane(task)?)));
        let (pipe, lane) = match resolved {
            Ok(r) => r,
            Err(e) => {
                // every row fails: error accounting stays per-row so
                // errors/requests remains a meaningful failure rate
                self.counters.inc_errors_n(texts.len() as u64);
                self.counters.latency.record_us(
                    t0.elapsed().as_secs_f64() * 1e6);
                let err = ServeError::Failed(format!("{e:#}"));
                return texts.iter().map(|_| Err(err.clone())).collect();
            }
        };
        // phase 1: submit all rows
        let mut pending = Vec::with_capacity(texts.len());
        for text in texts {
            let enc = pipe.encode_text(text.as_ref());
            let (tx, rx) = mpsc::channel();
            match lane.batcher.push(enc, tx) {
                Ok(()) => pending.push(Ok(rx)),
                Err(PushError::Overloaded(_reply)) => {
                    // shed: the row never entered the queue — answer 429
                    self.counters.inc_errors();
                    pending.push(Err(ServeError::Overloaded))
                }
                Err(PushError::Closed(_reply)) => {
                    self.counters.inc_errors();
                    pending.push(Err(ServeError::ShuttingDown))
                }
            }
        }
        // phase 2: collect in submission order
        let results: Vec<Result<TaskOutput, ServeError>> = pending
            .into_iter()
            .map(|p| match p {
                Ok(rx) => rx
                    .recv()
                    .map_err(|_| ServeError::Failed("dispatcher gone".into()))
                    .and_then(|r| r.map_err(ServeError::Failed)),
                Err(e) => Err(e),
            })
            .collect();
        self.counters.latency.record_us(t0.elapsed().as_secs_f64() * 1e6);
        results
    }

    /// Serve until `stop` is flagged. Binds `config.addr`.
    pub fn run(self: &Arc<Self>) -> Result<()> {
        let listener = TcpListener::bind(&self.config.addr)
            .with_context(|| format!("binding {}", self.config.addr))?;
        listener.set_nonblocking(true)?;
        let pool = ThreadPool::new(self.config.workers.max(1));
        eprintln!("[server] listening on {} ({} workers)",
                  self.config.addr, self.config.workers);
        while !self.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    let me = self.clone();
                    pool.execute(move || me.handle(stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => {
                    eprintln!("[server] accept error: {e}");
                }
            }
        }
        for lane in self.lanes.read().unwrap().values() {
            lane.batcher.close();
        }
        Ok(())
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    fn handle(&self, mut stream: TcpStream) {
        let req = match read_request(&mut stream) {
            Ok(r) => r,
            Err(e) => {
                let _ = write_response(&mut stream, 400, &Json::obj(vec![
                    ("error", Json::str(format!("bad request: {e}"))),
                ]).to_string());
                return;
            }
        };
        let (status, body) = self.dispatch(&req);
        let _ = write_response(&mut stream, status, &body.to_string());
        let _ = stream.flush();
    }

    fn dispatch(&self, req: &HttpRequest) -> (u16, Json) {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => (200, Json::obj(vec![("ok", Json::Bool(true))])),
            ("GET", "/v1/models") => {
                let tasks: Vec<Json> = self
                    .router
                    .manifest
                    .models
                    .iter()
                    .map(|m| {
                        Json::obj(vec![
                            ("task", Json::str(m.task.clone())),
                            ("kind", Json::str(m.kind.clone())),
                            ("seq_len", Json::num(m.seq_len as f64)),
                            ("num_labels", Json::num(m.num_labels as f64)),
                            ("variants", Json::arr(
                                m.variants.keys().map(|k| Json::str(k.clone())))),
                        ])
                    })
                    .collect();
                (200, Json::obj(vec![("models", Json::Arr(tasks))]))
            }
            ("GET", "/v1/plan") => {
                // read-only: reports the plan each ACTIVE pipeline serves
                // with (written by `samp plan` / Router::activate) without
                // forcing cold tasks to load
                let tasks: Vec<Json> = self
                    .router
                    .manifest
                    .models
                    .iter()
                    .map(|m| match self.router.active(&m.task) {
                        Some(pipe) => Json::obj(vec![
                            ("task", Json::str(m.task.clone())),
                            ("active_variant", Json::str(pipe.variant.clone())),
                            ("backend", Json::str(pipe.backend_name())),
                            ("int8_layers", Json::num(
                                pipe.plan()
                                    .iter()
                                    .filter(|x| x.is_int8())
                                    .count() as f64)),
                            ("layer_modes", Json::arr(
                                pipe.plan()
                                    .iter()
                                    .map(|x| Json::str(x.as_str())))),
                            ("act_quant", Json::arr(
                                pipe.act_quant()
                                    .iter()
                                    .map(|s| Json::str(s.clone())))),
                        ]),
                        None => Json::obj(vec![
                            ("task", Json::str(m.task.clone())),
                            ("active_variant", Json::Null),
                        ]),
                    })
                    .collect();
                (200, Json::obj(vec![("tasks", Json::Arr(tasks))]))
            }
            ("GET", "/v1/stats") => {
                let (reqs, batches, rows, errors) = self.counters.snapshot();
                let (pool_hits, pool_misses) = self.pool_stats();
                let lat = self.counters.latency.summary();
                (200, Json::obj(vec![
                    ("requests", Json::num(reqs as f64)),
                    ("batches", Json::num(batches as f64)),
                    ("batch_rows", Json::num(rows as f64)),
                    ("errors", Json::num(errors as f64)),
                    ("shed", Json::num(self.shed_count() as f64)),
                    ("mean_batch_fill", Json::num(self.counters.mean_batch_fill())),
                    ("pool_hits", Json::num(pool_hits as f64)),
                    ("pool_misses", Json::num(pool_misses as f64)),
                    ("pool_hit_rate", Json::num(
                        if pool_hits + pool_misses == 0 { 0.0 } else {
                            pool_hits as f64 / (pool_hits + pool_misses) as f64
                        })),
                    ("latency_p50_us", Json::num(lat.p50_us)),
                    ("latency_p95_us", Json::num(lat.p95_us)),
                    ("latency_p99_us", Json::num(lat.p99_us)),
                ]))
            }
            ("POST", "/v1/infer") => self.infer_endpoint(req, false),
            ("POST", "/v1/batch") => self.infer_endpoint(req, true),
            _ => (404, Json::obj(vec![("error", Json::str("not found"))])),
        }
    }

    fn infer_endpoint(&self, req: &HttpRequest, multi: bool) -> (u16, Json) {
        let body = match Json::parse(&req.body) {
            Ok(b) => b,
            Err(e) => {
                return (400, Json::obj(vec![
                    ("error", Json::str(format!("bad json: {e}")))]));
            }
        };
        let task = match body.get("task").as_str() {
            Some(t) => t.to_string(),
            None => return (400, Json::obj(vec![
                ("error", Json::str("missing `task`"))])),
        };
        let texts: Vec<String> = if multi {
            // every entry must be a string: dropping bad rows would shift
            // results[] against the caller's texts[] indices
            let rows = body.get("texts").as_arr().unwrap_or(&[]);
            let strings: Vec<String> = rows
                .iter()
                .filter_map(|x| x.as_str().map(String::from))
                .collect();
            if strings.len() != rows.len() {
                return (400, Json::obj(vec![
                    ("error", Json::str("`texts` must be an array of strings"))]));
            }
            strings
        } else {
            body.get("text").as_str().map(|t| vec![t.to_string()])
                .unwrap_or_default()
        };
        if texts.is_empty() {
            return (400, Json::obj(vec![
                ("error", Json::str("missing `text`/`texts`"))]));
        }
        let outs = self.infer_many(&task, &texts);
        if multi {
            // per-row results: one failed row yields one error object, not a
            // request-wide 500 (the other rows' answers still come back).
            // The exception is a fully-shed request: every row rejected by
            // admission control means the whole request gets the 429.
            let all_shed = outs
                .iter()
                .all(|r| matches!(r, Err(ServeError::Overloaded)));
            let status = if all_shed { 429 } else { 200 };
            let results: Vec<Json> = outs
                .into_iter()
                .map(|r| match r {
                    Ok(out) => output_json(&out),
                    Err(e) => Json::obj(vec![
                        ("error", Json::str(e.to_string()))]),
                })
                .collect();
            (status, Json::obj(vec![("results", Json::Arr(results))]))
        } else {
            match outs.into_iter().next().unwrap() {
                Ok(out) => (200, output_json(&out)),
                Err(e) => (e.status(),
                           Json::obj(vec![("error", Json::str(e.to_string()))])),
            }
        }
    }
}

/// Serialize a task output for the wire.
pub fn output_json(out: &TaskOutput) -> Json {
    match out {
        TaskOutput::Classification(c) => Json::obj(vec![
            ("label", Json::num(c.label as f64)),
            ("confidence", Json::num(c.confidence as f64)),
            ("top_k", Json::arr(c.top_k.iter().map(|(l, p)| {
                Json::obj(vec![("label", Json::num(*l as f64)),
                               ("prob", Json::num(*p as f64))])
            }))),
        ]),
        TaskOutput::Matching(m) => Json::obj(vec![
            ("is_match", Json::Bool(m.is_match)),
            ("probability", Json::num(m.probability as f64)),
        ]),
        TaskOutput::Ner(ents) => Json::obj(vec![
            ("entities", Json::arr(ents.iter().map(|e| {
                Json::obj(vec![
                    ("start", Json::num(e.start as f64)),
                    ("end", Json::num(e.end as f64)),
                    ("type", Json::str(e.entity_type.clone())),
                ])
            }))),
        ]),
    }
}

/// Minimal blocking HTTP client for examples/tests.
pub fn http_post(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len());
    stream.write_all(req.as_bytes())?;
    http::read_response(&mut stream)
}

pub fn http_get(addr: &str, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    http::read_response(&mut stream)
}
