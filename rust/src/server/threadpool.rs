//! Fixed-size worker thread pool (tokio substitute for the request path).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // sender dropped: shut down
                    }
                })
            })
            .collect();
        ThreadPool { workers, sender: Some(tx) }
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        if let Some(tx) = &self.sender {
            let _ = tx.send(Box::new(job));
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }
}
