//! Minimal HTTP/1.1 parsing/writing (request path needs no full framework).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

const MAX_BODY: usize = 4 << 20; // 4 MiB
const MAX_HEADERS: usize = 64;

#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
    /// Request headers as `(name, value)` pairs, in wire order.
    pub headers: Vec<(String, String)>,
}

impl HttpRequest {
    /// First header with the given name (case-insensitive), trimmed.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Parse one request from the stream.
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).context("reading request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();

    let mut content_length = 0usize;
    let mut headers = Vec::new();
    for _ in 0..MAX_HEADERS {
        let mut h = String::new();
        reader.read_line(&mut h).context("reading header")?;
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    if content_length > MAX_BODY {
        bail!("body too large: {content_length}");
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body).context("reading body")?;
    }
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8(body).context("non-utf8 body")?,
        headers,
    })
}

/// Write a JSON response.
pub fn write_response(stream: &mut TcpStream, status: u16, body: &str) -> Result<()> {
    write_response_with(stream, status, body, &[])
}

/// Write a JSON response with extra headers (e.g. `Retry-After` on 429/503).
/// Each entry is a pre-formatted `Name: value` pair.
pub fn write_response_with(stream: &mut TcpStream, status: u16, body: &str,
                           extra_headers: &[(&str, String)]) -> Result<()> {
    write_response_typed(stream, status, "application/json", body,
                         extra_headers)
}

/// Write a response with an explicit Content-Type (`/metrics` serves the
/// Prometheus text exposition format, everything else JSON).
pub fn write_response_typed(stream: &mut TcpStream, status: u16,
                            content_type: &str, body: &str,
                            extra_headers: &[(&str, String)]) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    };
    let mut extras = String::new();
    for (k, v) in extra_headers {
        extras.push_str(k);
        extras.push_str(": ");
        extras.push_str(v);
        extras.push_str("\r\n");
    }
    let resp = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\n{extras}Connection: close\r\n\r\n{body}",
        body.len());
    stream.write_all(resp.as_bytes())?;
    Ok(())
}

/// Read a response (client side): returns (status, body).
pub fn read_response(stream: &mut TcpStream) -> Result<(u16, String)> {
    let (status, _headers, body) = read_response_headers(stream)?;
    Ok((status, body))
}

/// Read a response keeping its headers: returns (status, headers, body).
pub fn read_response_headers(stream: &mut TcpStream)
                             -> Result<(u16, Vec<(String, String)>, String)> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).context("reading status line")?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .context("missing status")?
        .parse()
        .context("bad status")?;
    let mut content_length = 0usize;
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            break;
        }
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok((status, headers, String::from_utf8_lossy(&body).into_owned()))
}
