//! Minimal JSON parser/writer (serde_json is unavailable offline).
//!
//! Supports the full JSON grammar; numbers are kept as f64 (adequate for the
//! manifest, configs and the HTTP API).  The parser is recursive-descent with
//! a depth limit; the writer escapes control characters and non-BMP chars via
//! surrogate pairs.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

const MAX_DEPTH: usize = 128;

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns Json::Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        write!(f, "{}", *n as i64)
                    } else {
                        write!(f, "{n}")
                    }
                } else {
                    write!(f, "null") // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8, what: &str) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(what))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, msg: format!("bad number `{text}`") })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"', "expected string")?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.eat(b'\\', "expected low surrogate")?;
                                self.eat(b'u', "expected low surrogate")?;
                                let lo = self.hex4()?;
                                let code = 0x10000
                                    + ((hi - 0xD800) << 10)
                                    + (lo.wrapping_sub(0xDC00) & 0x3FF);
                                char::from_u32(code)
                            } else {
                                char::from_u32(hi)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // decode one UTF-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.eat(b'[', "expected [")?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.eat(b'{', "expected {")?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':', "expected :")?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").as_str(), Some("x"));
        assert!(j.get("a").as_arr().unwrap()[2].get("b").is_null());
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(Json::parse(r#""中文""#).unwrap(), Json::Str("中文".into()));
        // surrogate pair: U+1F600
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"x":1,"y":[true,false,null],"z":"中文 ok"}"#,
            r#"[1.5,-2,0.25]"#,
            r#""tab\tnewline\n""#,
        ];
        for c in cases {
            let j = Json::parse(c).unwrap();
            let again = Json::parse(&j.to_string()).unwrap();
            assert_eq!(j, again, "case {c}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "\"\\q\""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn rejects_deep_nesting() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn integer_display_is_integral() {
        assert_eq!(Json::Num(8.0).to_string(), "8");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
