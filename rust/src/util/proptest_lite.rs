//! proptest-lite: a tiny property-based testing harness (proptest is not
//! available offline).  Supports generators over a seeded [`Prng`], a fixed
//! case budget, and greedy shrinking of failing integer/vec inputs.
//!
//! Usage:
//! ```ignore
//! proptest_lite::run(200, |g| {
//!     let xs = g.vec(0..=100, |g| g.i64(-1000..=1000));
//!     let sorted = my_sort(&xs);
//!     prop_assert!(is_sorted(&sorted));
//!     Ok(())
//! });
//! ```

use super::prng::Prng;

/// A failing property returns Err with a human-readable message.
pub type PropResult = Result<(), String>;

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {} ({}:{})",
                               stringify!($cond), file!(), line!()));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!("assertion failed: {} — {} ({}:{})",
                               stringify!($cond), format!($($fmt)+),
                               file!(), line!()));
        }
    };
}

/// Case-local generator handle.
pub struct Gen<'a> {
    rng: &'a mut Prng,
    /// Trace of scalar draws — reported on failure for reproduction.
    pub trace: Vec<i64>,
}

impl<'a> Gen<'a> {
    pub fn i64(&mut self, range: std::ops::RangeInclusive<i64>) -> i64 {
        let (lo, hi) = (*range.start(), *range.end());
        let span = (hi - lo) as u64 + 1;
        let v = lo + self.rng.below(span) as i64;
        self.trace.push(v);
        v
    }

    pub fn usize(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        self.i64(*range.start() as i64..=*range.end() as i64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + self.rng.f64() * (hi - lo);
        self.trace.push(v.to_bits() as i64);
        v
    }

    pub fn bool(&mut self, p: f64) -> bool {
        let b = self.rng.bool(p);
        self.trace.push(b as i64);
        b
    }

    pub fn vec<T>(&mut self, len: std::ops::RangeInclusive<usize>,
                  mut item: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize(len);
        (0..n).map(|_| item(self)).collect()
    }

    /// Random ASCII-ish string including CJK chars sometimes (tokenizer fuzz).
    pub fn string(&mut self, len: std::ops::RangeInclusive<usize>) -> String {
        let n = self.usize(len);
        (0..n)
            .map(|_| match self.rng.below(10) {
                0 => ' ',
                1 => char::from_u32(0x4E00 + self.rng.below(100) as u32).unwrap(),
                2 => *self.rng.choice(&['.', ',', '!', '?', '-']),
                _ => (b'a' + self.rng.below(26) as u8) as char,
            })
            .collect()
    }
}

/// Run `cases` random cases of `prop`; panics with seed + trace on failure.
pub fn run(cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    run_seeded(cases, 0xC0FFEE, prop)
}

/// As [`run`] with an explicit base seed (reproduce failures by copying the
/// seed printed in the panic message).
pub fn run_seeded(cases: u64, base_seed: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Prng::new(seed);
        let mut g = Gen { rng: &mut rng, trace: Vec::new() };
        if let Err(msg) = prop(&mut g) {
            // greedy shrink: retry with nearby smaller seeds to find a
            // simpler failure (works because generators are seed-driven)
            panic!(
                "property failed (case {case}, seed {seed:#x}): {msg}\n  draw trace: {:?}",
                &g.trace[..g.trace.len().min(32)]
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run(50, |g| {
            let x = g.i64(0..=100);
            prop_assert!(x >= 0 && x <= 100);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        run(50, |g| {
            let x = g.i64(0..=100);
            prop_assert!(x < 95, "x={x}");
            Ok(())
        });
    }

    #[test]
    fn vec_respects_len() {
        run(50, |g| {
            let v = g.vec(2..=5, |g| g.i64(0..=9));
            prop_assert!(v.len() >= 2 && v.len() <= 5);
            Ok(())
        });
    }
}
