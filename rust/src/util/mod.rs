//! In-tree utility substrates (offline environment: no serde/rand/proptest).

pub mod affinity;
pub mod json;
pub mod prng;
pub mod proptest_lite;

/// Simple monotonic stopwatch for metrics and benches.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    pub fn elapsed_us(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e6
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}
