//! Thread-to-core pinning via Linux `sched_setaffinity(2)`, degrading
//! gracefully (warn once, keep running unpinned) everywhere the call is
//! unavailable: non-Linux hosts, restricted sandboxes, or a core id the
//! machine does not have.
//!
//! No libc crate in this offline environment, so the symbol is bound
//! directly (same pattern as the `signal(2)` binding in `main.rs`).

use std::io;
use std::sync::atomic::{AtomicBool, Ordering};

/// Bytes in the affinity mask handed to the kernel: glibc's `cpu_set_t`
/// size, covering cpus 0..1023.
const CPU_SET_BYTES: usize = 128;

/// Pin the *calling thread* to `core`.  Errors (instead of silently doing
/// nothing) when the core id is out of mask range, the kernel rejects the
/// mask (e.g. the machine has fewer cores), or the platform has no
/// `sched_setaffinity` at all.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(core: usize) -> io::Result<()> {
    extern "C" {
        // glibc: pid 0 targets the calling thread (the raw syscall is
        // per-thread, which is exactly what a worker pinning itself wants)
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u8)
                             -> i32;
    }
    if core >= CPU_SET_BYTES * 8 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("core {core} exceeds the {}-cpu affinity mask",
                    CPU_SET_BYTES * 8)));
    }
    let mut mask = [0u8; CPU_SET_BYTES];
    mask[core / 8] |= 1 << (core % 8);
    // SAFETY: the mask buffer outlives the call and cpusetsize matches its
    // length; sched_setaffinity only reads the mask.
    let rc = unsafe { sched_setaffinity(0, CPU_SET_BYTES, mask.as_ptr()) };
    if rc == 0 {
        Ok(())
    } else {
        Err(io::Error::last_os_error())
    }
}

/// Non-Linux stub: pinning is a perf hint, not a correctness requirement,
/// so the caller is expected to go through [`try_pin`] and shrug this off.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(core: usize) -> io::Result<()> {
    let _ = core;
    Err(io::Error::new(io::ErrorKind::Unsupported,
                       "thread pinning needs Linux sched_setaffinity"))
}

static WARNED: AtomicBool = AtomicBool::new(false);

/// Best-effort pin of the calling thread: `Some(core)` on success, `None`
/// (after warning once per process) on any failure.  This is the entry
/// point the serving path uses — a replica on a laptop or in a sandbox
/// must run, just unpinned.
pub fn try_pin(core: usize) -> Option<usize> {
    match pin_current_thread(core) {
        Ok(()) => Some(core),
        Err(e) => {
            if !WARNED.swap(true, Ordering::Relaxed) {
                eprintln!("[affinity] pinning to core {core} failed ({e}); \
                           continuing unpinned");
            }
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_of_range_core_is_an_error_not_a_crash() {
        let err = pin_current_thread(usize::MAX).unwrap_err();
        // linux: our own range check; elsewhere: the unsupported stub
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn try_pin_never_panics() {
        // core 0 exists on any machine, but sandboxes may still refuse the
        // syscall — both outcomes are valid, panicking is not
        if let Some(c) = try_pin(0) {
            assert_eq!(c, 0);
        }
        // a core the host certainly lacks must degrade to None
        assert_eq!(try_pin(100_000), None);
    }
}
