//! Deterministic PRNG (xoshiro256**) for benches, the workload generator and
//! the proptest-lite harness.  No external `rand` crate offline; this is the
//! standard xoshiro256** algorithm (Blackman & Vigna), good statistical
//! quality for everything short of cryptography.

/// xoshiro256** seeded deterministically.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via splitmix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, bound) without modulo bias (Lemire reduction).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut p = Prng::new(1);
        for _ in 0..1000 {
            assert!(p.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut p = Prng::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(3);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = p.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(4);
        let mut v: Vec<u32> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
