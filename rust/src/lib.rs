//! # SAMP — Self-Adaptive Mixed-Precision inference toolkit
//!
//! Reproduction of *SAMP: A Model Inference Toolkit of Post-Training
//! Quantization for Text Processing via Self-Adaptive Mixed-Precision*
//! (EMNLP 2023 Industry Track) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build-time)** — fused/quantized kernels
//!   (`python/compile/kernels/`): fused embedding, INT8 GEMM with fused
//!   requantization, AddBias+Residual+LayerNorm(+Quant) "big kernels",
//!   softmax(+quant), fused attention.
//! * **Layer 2 (JAX, build-time)** — the mixed-precision BERT encoder with a
//!   per-layer `PrecisionPlan` (`python/compile/model.py`), calibration and
//!   training; AOT-lowered to HLO text per precision variant.
//! * **Layer 3 (this crate, request path)** — pluggable execution backends
//!   behind the [`runtime::Backend`] trait (PJRT engines for compiled HLO,
//!   or the in-tree native mixed-precision backend with blocked INT8 GEMM
//!   kernels — [`backend::native`]), tokenizer, dynamic batcher with
//!   admission control, task router, accuracy-decay-aware allocator
//!   (Algorithm 1), T4 latency cost model, calibration-driven precision
//!   planner ([`planner`] — `samp plan`), downstream-task decoding, HTTP
//!   serving.  Python never runs here.
//!
//! Quickstart (after `make artifacts`):
//!
//! ```no_run
//! use std::sync::Arc;
//! use samp::config::Manifest;
//! use samp::coordinator::Router;
//! use samp::runtime::Runtime;
//!
//! let rt = Arc::new(Runtime::cpu().unwrap());
//! let manifest = Manifest::load("artifacts").unwrap();
//! let router = Router::new(rt, manifest).unwrap();
//! let pipe = router.pipeline("tnews").unwrap();
//! let out = pipe.infer_text("w00123 w00456").unwrap();
//! println!("{out:?}");
//! ```

pub mod allocator;
pub mod backend;
pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod latency;
pub mod metrics;
pub mod planner;
pub mod quant;
pub mod registry;
pub mod runtime;
pub mod server;
pub mod tasks;
pub mod telemetry;
pub mod tokenizer;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Feature matrix of this toolkit (Table 1 of the paper) — used by the
/// bench_table2 header and asserted by the integration tests.
pub fn feature_matrix() -> Vec<(&'static str, bool)> {
    vec![
        ("tokenizer", true),
        ("mixed_precision_layers", true),
        ("mixed_precision_mha_ffn", true),
        ("fully_quantized", true),
        ("task_classification", true),
        ("task_ner", true),
        ("task_text_matching", true),
    ]
}
