//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a plain `fn main()` with `harness = false`;
//! this module supplies warmup + repeated timing with mean/stddev/min and a
//! uniform report format so `cargo bench` output is comparable across
//! targets.

use std::time::Instant;

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub stddev_us: f64,
    pub min_us: f64,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:48} {:>12.2} us/iter (±{:>8.2}, min {:>10.2}, n={})",
               self.name, self.mean_us, self.stddev_us, self.min_us, self.iters)
    }
}

/// Run `f` with warmup then measure `iters` iterations.
pub fn bench(name: &str, warmup: usize, iters: usize,
             mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    summarize(name, &samples)
}

/// Summarize externally collected samples (already in microseconds).
pub fn summarize(name: &str, samples_us: &[f64]) -> BenchResult {
    let n = samples_us.len().max(1) as f64;
    let mean = samples_us.iter().sum::<f64>() / n;
    let var = samples_us.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        iters: samples_us.len(),
        mean_us: mean,
        stddev_us: var.sqrt(),
        min_us: samples_us.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Section header for bench reports.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Simple fixed-width table printer for paper-style outputs.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        for (i, c) in cells.iter().enumerate() {
            self.widths[i] = self.widths[i].max(c.len());
        }
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("| {:w$} ", c, w = w));
            }
            s.push('|');
            s
        };
        let header = line(&self.headers, &self.widths);
        println!("{header}");
        println!("{}", "-".repeat(header.len()));
        for r in &self.rows {
            println!("{}", line(r, &self.widths));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_us >= 0.0 && r.min_us <= r.mean_us);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "bee"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }
}
