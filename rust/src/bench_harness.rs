//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a plain `fn main()` with `harness = false`;
//! this module supplies warmup + repeated timing with mean/stddev/min and a
//! uniform report format so `cargo bench` output is comparable across
//! targets.

use std::time::Instant;

use crate::util::json::Json;

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub stddev_us: f64,
    pub min_us: f64,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:48} {:>12.2} us/iter (±{:>8.2}, min {:>10.2}, n={})",
               self.name, self.mean_us, self.stddev_us, self.min_us, self.iters)
    }
}

/// Run `f` with warmup then measure `iters` iterations.
pub fn bench(name: &str, warmup: usize, iters: usize,
             mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    summarize(name, &samples)
}

/// Summarize externally collected samples (already in microseconds).
pub fn summarize(name: &str, samples_us: &[f64]) -> BenchResult {
    let n = samples_us.len().max(1) as f64;
    let mean = samples_us.iter().sum::<f64>() / n;
    let var = samples_us.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        iters: samples_us.len(),
        mean_us: mean,
        stddev_us: var.sqrt(),
        min_us: samples_us.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Section header for bench reports.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Merge one section into a shared bench-report JSON file
/// (`BENCH_SERVING.json`: `{"serving": ..., "gemm": ...}`) with
/// read-modify-write semantics: every *other* top-level section is
/// preserved, so a partial run (only one bench executed) can never clobber
/// the rest of the report.  The replace is atomic (temp file + rename), so a
/// crash mid-write cannot corrupt the file and take the other sections down
/// on the next run either.
///
/// Legacy layout (a bench report at top level, recognizable by its own
/// `"bench"` name field) is rehomed under that name before merging.
pub fn merge_bench_section(path: &str, key: &str, value: Json)
                           -> std::io::Result<()> {
    let root = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .unwrap_or(Json::Null);
    let mut root = match root {
        Json::Obj(o) => {
            let legacy = o.get("bench").and_then(|b| b.as_str())
                .map(String::from);
            match legacy {
                Some(name) => {
                    let mut fresh = std::collections::BTreeMap::new();
                    fresh.insert(name, Json::Obj(o));
                    Json::Obj(fresh)
                }
                None => Json::Obj(o),
            }
        }
        _ => Json::Obj(Default::default()),
    };
    if let Json::Obj(o) = &mut root {
        o.insert(key.to_string(), value);
    }
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, root.to_string())?;
    std::fs::rename(&tmp, path)
}

/// Simple fixed-width table printer for paper-style outputs.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        for (i, c) in cells.iter().enumerate() {
            self.widths[i] = self.widths[i].max(c.len());
        }
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("| {:w$} ", c, w = w));
            }
            s.push('|');
            s
        };
        let header = line(&self.headers, &self.widths);
        println!("{header}");
        println!("{}", "-".repeat(header.len()));
        for r in &self.rows {
            println!("{}", line(r, &self.widths));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_us >= 0.0 && r.min_us <= r.mean_us);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "bee"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }

    fn tmp_report(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!(
            "samp_bench_merge_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("BENCH.json").to_str().unwrap().to_string()
    }

    #[test]
    fn merge_preserves_other_sections() {
        let path = tmp_report("preserve");
        std::fs::write(&path, r#"{"serving":{"requests":5}}"#).unwrap();
        merge_bench_section(&path, "gemm",
                            Json::obj(vec![("x", Json::num(1.0))])).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("serving").get("requests").as_usize(), Some(5));
        assert_eq!(j.get("gemm").get("x").as_usize(), Some(1));
        // overwriting one section leaves the other intact
        merge_bench_section(&path, "serving",
                            Json::obj(vec![("requests", Json::num(9.0))]))
            .unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("serving").get("requests").as_usize(), Some(9));
        assert_eq!(j.get("gemm").get("x").as_usize(), Some(1));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn merge_rehomes_legacy_toplevel_report() {
        // the pre-PR2 layout: the serving report itself at top level — it
        // must move under its "bench" name, not be mistaken for the root
        let path = tmp_report("legacy");
        std::fs::write(&path, r#"{"bench":"serving","requests":7}"#).unwrap();
        merge_bench_section(&path, "gemm", Json::num(2.0)).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("serving").get("requests").as_usize(), Some(7));
        assert_eq!(j.get("gemm").as_f64(), Some(2.0));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn merge_survives_missing_and_corrupt_files() {
        let path = tmp_report("corrupt");
        std::fs::remove_file(&path).ok();
        merge_bench_section(&path, "gemm", Json::num(1.0)).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("gemm").as_f64(), Some(1.0));
        // truncated/corrupt content degrades to a fresh report
        std::fs::write(&path, r#"{"serving": {"trunc"#).unwrap();
        merge_bench_section(&path, "gemm", Json::num(3.0)).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("gemm").as_f64(), Some(3.0));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn merge_keeps_sections_across_both_bench_orders() {
        // regression for the pre-fix bug: a gemm-only file got rehomed
        // wholesale under "serving" by the next gemm run
        let path = tmp_report("orders");
        std::fs::remove_file(&path).ok();
        merge_bench_section(&path, "gemm", Json::num(1.0)).unwrap();
        merge_bench_section(&path, "gemm", Json::num(2.0)).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(j.get("serving").is_null(), "gemm-only file grew a serving \
                                             section: {j}");
        assert_eq!(j.get("gemm").as_f64(), Some(2.0));
        merge_bench_section(&path, "serving", Json::num(5.0)).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("gemm").as_f64(), Some(2.0));
        assert_eq!(j.get("serving").as_f64(), Some(5.0));
        std::fs::remove_file(&path).ok();
    }
}
