//! Per-toolkit encoder kernel schedules.
//!
//! Each builder emits the kernel sequence the corresponding toolkit launches
//! for one encoder forward pass, following the systems' public fusion
//! behaviour:
//!
//! * **PyTorch** (eager): every op is its own kernel; no tensor cores for
//!   elementwise chains; LayerNorm = 2 kernels (stats + normalize); GEMMs hit
//!   cuBLAS.  No INT8 path.
//! * **FasterTransformer**: QKV fused into one GEMM (tensor fusion), fused
//!   add-bias-transpose, fused scale-mask-softmax, fused bias-residual-LN and
//!   bias-GELU (layer fusion).  INT8 mode is All-layers-Fully-Quant with
//!   *separate* quantize/dequantize kernels around GEMMs and FP16 dataflow
//!   between fused blocks.
//! * **TurboTransformers**: FP-only toolkit (Table 1); FT-like fusion minus
//!   the QKV tensor fusion.
//! * **SAMP**: FT fusions *plus* (a) the fused 3-in-1 embedding (Fig 1),
//!   (b) fused single-kernel attention core, (c) Quant/deQuant folded into
//!   the adjacent GEMM / big-kernel epilogues so INT8 layers keep an INT8
//!   dataflow (Fig 2a "all green arrows") — this is the §4.3 5~10% edge and
//!   the "reduces kernel calls by half" claim, and (d) per-layer mixed
//!   precision (the whole point of the paper).
//!
//! Every builder takes the per-layer plan; FT/Turbo/PyTorch only honour
//! uniform plans (they have no mixed-precision support — Table 1).

use super::{DType, Geometry, Kernel, LayerMode, Schedule, Workload};

/// Which toolkit's launch behaviour to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Toolkit {
    Samp,
    FasterTransformer,
    TurboTransformers,
    PyTorch,
}

impl Toolkit {
    pub fn parse(s: &str) -> Option<Toolkit> {
        Some(match s.to_ascii_lowercase().as_str() {
            "samp" => Toolkit::Samp,
            "fastertransformer" | "ft" => Toolkit::FasterTransformer,
            "turbotransformers" | "turbo" => Toolkit::TurboTransformers,
            "pytorch" | "torch" => Toolkit::PyTorch,
            _ => return None,
        })
    }
}

fn fp_dtype(mode: LayerMode) -> DType {
    match mode {
        LayerMode::Fp32 => DType::F32,
        _ => DType::F16,
    }
}

/// Activation tensor bytes for [rows, cols] in `d`.
fn act(rows: usize, cols: usize, d: DType) -> f64 {
    rows as f64 * cols as f64 * d.bytes()
}

/// Build the schedule for one toolkit / geometry / workload / per-layer plan.
pub fn encoder_schedule(tk: Toolkit, g: Geometry, w: Workload,
                        plan: &[LayerMode]) -> Schedule {
    assert_eq!(plan.len(), g.layers, "plan length != layers");
    let mut s = Schedule::default();
    let rows = w.batch * w.seq;
    let fp = fp_dtype(plan.iter().copied().find(|m| *m != LayerMode::Int8Full)
                          .unwrap_or(LayerMode::Fp16));

    embedding(&mut s, tk, g, w, plan[0] == LayerMode::Int8Full);

    for (l, &mode) in plan.iter().enumerate() {
        match mode {
            LayerMode::Int8Full => layer_int8_full(&mut s, tk, g, rows, w, l),
            LayerMode::Int8Ffn => layer_int8_ffn(&mut s, tk, g, rows, w, l),
            _ => layer_fp(&mut s, tk, g, rows, w, l, fp_dtype(mode)),
        }
    }
    let _ = fp;
    s
}

/// Embedding: token+segment+position gathers (+LN) (+quant for Fig 2a).
fn embedding(s: &mut Schedule, tk: Toolkit, g: Geometry, w: Workload,
             quant_out: bool) {
    let rows = w.batch * w.seq;
    let out = act(rows, g.hidden, DType::F16);
    match tk {
        Toolkit::Samp => {
            // one fused kernel: 3 gathers + add + LN (+quant): write int8 if
            // the encoder input is quantized
            let wr = if quant_out { act(rows, g.hidden, DType::I8) } else { out };
            s.push(Kernel::elementwise("emb_fused", 3.0 * out + wr, DType::F16));
        }
        _ => {
            // 3 gather kernels + add + LN(2 for PyTorch, 1 fused otherwise)
            for name in ["emb_tok", "emb_seg", "emb_pos"] {
                s.push(Kernel::elementwise(name, 2.0 * out, DType::F16));
            }
            if tk == Toolkit::PyTorch {
                s.push(Kernel::elementwise("emb_add", 3.0 * out, DType::F32));
                s.push(Kernel::elementwise("emb_ln_stats", out, DType::F32));
                s.push(Kernel::elementwise("emb_ln_norm", 2.0 * out, DType::F32));
            } else {
                s.push(Kernel::elementwise("emb_add_ln", 4.0 * out, DType::F16));
            }
            if quant_out {
                // FT quantizes encoder input with a separate kernel
                s.push(Kernel::elementwise(
                    "emb_quant",
                    out + act(rows, g.hidden, DType::I8),
                    DType::F16,
                ));
            }
        }
    }
}

/// Floating-point transformer layer (FP32 or FP16 pipelines).
fn layer_fp(s: &mut Schedule, tk: Toolkit, g: Geometry, rows: usize,
            w: Workload, l: usize, d: DType) {
    let h = g.hidden;
    let hd = h / g.heads;
    let bh = w.batch * g.heads;
    let a = |r, c| act(r, c, d);
    let pre = format!("l{l}");

    match tk {
        Toolkit::PyTorch => {
            for nm in ["wq", "wk", "wv"] {
                s.push(Kernel::gemm(format!("{pre}/{nm}"), rows, h, h, d,
                                    a(rows, h) + a(h, h), a(rows, h)));
                s.push(Kernel::elementwise(format!("{pre}/{nm}_bias"),
                                           2.0 * a(rows, h), d));
            }
            // transpose to heads (q,k,v)
            for nm in ["tq", "tk", "tv"] {
                s.push(Kernel::elementwise(format!("{pre}/{nm}"),
                                           2.0 * a(rows, h), d));
            }
            s.push(Kernel::gemm(format!("{pre}/qk"), bh * w.seq, w.seq, hd, d,
                                2.0 * a(rows, h), act(bh * w.seq, w.seq, d)));
            s.push(Kernel::elementwise(format!("{pre}/scale"),
                                       2.0 * act(bh * w.seq, w.seq, d), d));
            s.push(Kernel::elementwise(format!("{pre}/mask"),
                                       2.0 * act(bh * w.seq, w.seq, d), d));
            s.push(Kernel::elementwise(format!("{pre}/softmax"),
                                       2.0 * act(bh * w.seq, w.seq, d), d));
            s.push(Kernel::gemm(format!("{pre}/pv"), bh * w.seq, hd, w.seq, d,
                                act(bh * w.seq, w.seq, d) + a(rows, h), a(rows, h)));
            s.push(Kernel::elementwise(format!("{pre}/tctx"), 2.0 * a(rows, h), d));
            s.push(Kernel::gemm(format!("{pre}/wo"), rows, h, h, d,
                                a(rows, h) + a(h, h), a(rows, h)));
            s.push(Kernel::elementwise(format!("{pre}/wo_bias"), 2.0 * a(rows, h), d));
            s.push(Kernel::elementwise(format!("{pre}/res1"), 3.0 * a(rows, h), d));
            s.push(Kernel::elementwise(format!("{pre}/ln1_stats"), a(rows, h), d));
            s.push(Kernel::elementwise(format!("{pre}/ln1_norm"), 2.0 * a(rows, h), d));
            s.push(Kernel::gemm(format!("{pre}/fc1"), rows, g.ffn, h, d,
                                a(rows, h) + a(h, g.ffn), a(rows, g.ffn)));
            s.push(Kernel::elementwise(format!("{pre}/fc1_bias"),
                                       2.0 * a(rows, g.ffn), d));
            s.push(Kernel::elementwise(format!("{pre}/gelu"),
                                       2.0 * a(rows, g.ffn), d));
            s.push(Kernel::gemm(format!("{pre}/fc2"), rows, h, g.ffn, d,
                                a(rows, g.ffn) + a(g.ffn, h), a(rows, h)));
            s.push(Kernel::elementwise(format!("{pre}/fc2_bias"), 2.0 * a(rows, h), d));
            s.push(Kernel::elementwise(format!("{pre}/res2"), 3.0 * a(rows, h), d));
            s.push(Kernel::elementwise(format!("{pre}/ln2_stats"), a(rows, h), d));
            s.push(Kernel::elementwise(format!("{pre}/ln2_norm"), 2.0 * a(rows, h), d));
        }
        Toolkit::Samp | Toolkit::FasterTransformer | Toolkit::TurboTransformers => {
            if tk == Toolkit::TurboTransformers {
                // no QKV tensor fusion: three GEMMs
                for nm in ["wq", "wk", "wv"] {
                    s.push(Kernel::gemm(format!("{pre}/{nm}"), rows, h, h, d,
                                        a(rows, h) + a(h, h), a(rows, h)));
                }
            } else {
                // QKV fused as one [H, 3H] GEMM (FT tensor fusion)
                s.push(Kernel::gemm(format!("{pre}/qkv"), rows, 3 * h, h, d,
                                    a(rows, h) + a(h, 3 * h), 3.0 * a(rows, h)));
            }
            s.push(Kernel::elementwise(format!("{pre}/bias_transpose"),
                                       6.0 * a(rows, h), d));
            if tk == Toolkit::Samp {
                // fused attention core: QK^T + scale+mask+softmax + PV in one
                // kernel (our L1 attention kernel); score panel stays in VMEM
                let k = Kernel {
                    name: format!("{pre}/fused_attention"),
                    flops: 2.0 * (bh * w.seq) as f64 * w.seq as f64 * hd as f64 * 2.0,
                    bytes: 3.0 * a(rows, h) + a(rows, h),
                    dtype: d,
                };
                s.push(k);
            } else {
                s.push(Kernel::gemm(format!("{pre}/qk"), bh * w.seq, w.seq, hd, d,
                                    2.0 * a(rows, h), act(bh * w.seq, w.seq, d)));
                s.push(Kernel::elementwise(format!("{pre}/scale_mask_softmax"),
                                           2.0 * act(bh * w.seq, w.seq, d), d));
                s.push(Kernel::gemm(format!("{pre}/pv"), bh * w.seq, hd, w.seq, d,
                                    act(bh * w.seq, w.seq, d) + a(rows, h),
                                    a(rows, h)));
                s.push(Kernel::elementwise(format!("{pre}/transpose_ctx"),
                                           2.0 * a(rows, h), d));
            }
            s.push(Kernel::gemm(format!("{pre}/wo"), rows, h, h, d,
                                a(rows, h) + a(h, h), a(rows, h)));
            s.push(Kernel::elementwise(format!("{pre}/bias_res_ln1"),
                                       4.0 * a(rows, h), d));
            s.push(Kernel::gemm(format!("{pre}/fc1"), rows, g.ffn, h, d,
                                a(rows, h) + a(h, g.ffn), a(rows, g.ffn)));
            s.push(Kernel::elementwise(format!("{pre}/bias_gelu"),
                                       2.0 * a(rows, g.ffn), d));
            s.push(Kernel::gemm(format!("{pre}/fc2"), rows, h, g.ffn, d,
                                a(rows, g.ffn) + a(g.ffn, h), a(rows, h)));
            s.push(Kernel::elementwise(format!("{pre}/bias_res_ln2"),
                                       4.0 * a(rows, h), d));
        }
    }
}

/// Quant-FFN-Only layer (Fig 2b). Only SAMP supports this (Table 1).
fn layer_int8_ffn(s: &mut Schedule, tk: Toolkit, g: Geometry, rows: usize,
                  w: Workload, l: usize) {
    assert_eq!(tk, Toolkit::Samp, "only SAMP supports Quant-FFN-Only");
    let h = g.hidden;
    let d = DType::F16;
    let a = |r: usize, c: usize, dt: DType| act(r, c, dt);
    let pre = format!("l{l}");
    let hd = h / g.heads;
    let bh = w.batch * g.heads;

    // MHA identical to the SAMP FP16 path
    s.push(Kernel::gemm(format!("{pre}/qkv"), rows, 3 * h, h, d,
                        a(rows, h, d) + a(h, 3 * h, d), 3.0 * a(rows, h, d)));
    s.push(Kernel::elementwise(format!("{pre}/bias_transpose"),
                               6.0 * a(rows, h, d), d));
    s.push(Kernel {
        name: format!("{pre}/fused_attention"),
        flops: 2.0 * (bh * w.seq) as f64 * w.seq as f64 * hd as f64 * 2.0,
        bytes: 4.0 * a(rows, h, d),
        dtype: d,
    });
    s.push(Kernel::gemm(format!("{pre}/wo"), rows, h, h, d,
                        a(rows, h, d) + a(h, h, d), a(rows, h, d)));
    // big kernel: bias+residual+LN fused WITH the output quantization
    s.push(Kernel::elementwise(format!("{pre}/bias_res_ln1_quant"),
                               3.0 * a(rows, h, d) + a(rows, h, DType::I8), d));
    // INT8 FFN: GEMM reads int8, requant epilogue fused into GEMM
    s.push(Kernel::gemm(format!("{pre}/fc1_i8"), rows, g.ffn, h, DType::I8,
                        a(rows, h, DType::I8) + a(h, g.ffn, DType::I8),
                        a(rows, g.ffn, DType::I8)));
    s.push(Kernel::elementwise(format!("{pre}/bias_gelu_quant"),
                               2.0 * a(rows, g.ffn, DType::I8), d));
    s.push(Kernel::gemm(format!("{pre}/fc2_i8"), rows, h, g.ffn, DType::I8,
                        a(rows, g.ffn, DType::I8) + a(g.ffn, h, DType::I8),
                        a(rows, h, DType::I8)));
    // last big kernel: floating output (Fig 2b)
    s.push(Kernel::elementwise(format!("{pre}/bias_res_ln2"),
                               a(rows, h, DType::I8) + 3.0 * a(rows, h, d), d));
}

/// Fully-Quant layer (Fig 2a). SAMP keeps INT8 dataflow; FT inserts separate
/// quant/dequant kernels and moves FP16 between fused blocks.
fn layer_int8_full(s: &mut Schedule, tk: Toolkit, g: Geometry, rows: usize,
                   w: Workload, l: usize) {
    let h = g.hidden;
    let hd = h / g.heads;
    let bh = w.batch * g.heads;
    let i8 = DType::I8;
    let f16 = DType::F16;
    let a = act;
    let pre = format!("l{l}");
    let score_i8 = a(bh * w.seq, w.seq, i8);

    match tk {
        Toolkit::Samp => {
            // INT8 dataflow end to end ("all green arrows"):
            s.push(Kernel::gemm(format!("{pre}/qkv_i8"), rows, 3 * h, h, i8,
                                a(rows, h, i8) + a(h, 3 * h, i8),
                                3.0 * a(rows, h, i8)));
            s.push(Kernel::elementwise(format!("{pre}/bias_transpose_i8"),
                                       6.0 * a(rows, h, i8), f16));
            // QK^T accumulates INT32, writes the score panel FP16 (softmax
            // needs float math either way)...
            s.push(Kernel::gemm(format!("{pre}/qk_i8"), bh * w.seq, w.seq, hd,
                                i8, 2.0 * a(rows, h, i8),
                                act(bh * w.seq, w.seq, f16)));
            // ...but SAMP's softmax kernel *writes INT8 directly* (fused
            // scale+mask+softmax+quant, our L1 softmax_quant) where FT needs
            // a second standalone quantize pass over the panel.
            s.push(Kernel::elementwise(format!("{pre}/softmax_quant"),
                                       act(bh * w.seq, w.seq, f16) + score_i8,
                                       f16));
            s.push(Kernel::gemm(format!("{pre}/pv_i8"), bh * w.seq, hd, w.seq,
                                i8, score_i8 + a(rows, h, i8), a(rows, h, i8)));
            s.push(Kernel::gemm(format!("{pre}/wo_i8"), rows, h, h, i8,
                                a(rows, h, i8) + a(h, h, i8), a(rows, h, i8)));
            s.push(Kernel::elementwise(format!("{pre}/bias_res_ln1_quant"),
                                       3.0 * a(rows, h, i8), f16));
            s.push(Kernel::gemm(format!("{pre}/fc1_i8"), rows, g.ffn, h, i8,
                                a(rows, h, i8) + a(h, g.ffn, i8),
                                a(rows, g.ffn, i8)));
            s.push(Kernel::elementwise(format!("{pre}/bias_gelu_quant"),
                                       2.0 * a(rows, g.ffn, i8), f16));
            s.push(Kernel::gemm(format!("{pre}/fc2_i8"), rows, h, g.ffn, i8,
                                a(rows, g.ffn, i8) + a(g.ffn, h, i8),
                                a(rows, h, i8)));
            s.push(Kernel::elementwise(format!("{pre}/bias_res_ln2_quant"),
                                       3.0 * a(rows, h, i8), f16));
        }
        Toolkit::FasterTransformer => {
            // FT INT8 (paper-era): GEMMs use cuBLASLt INT8 with fused
            // dequant/requant epilogues (so GEMM outputs are INT8 like
            // SAMP's), but the *non-GEMM* boundaries are not quant-fused:
            // softmax, the LN epilogues and GELU run in FP16 and need
            // standalone quantize kernels before the next INT8 GEMM.  That
            // is exactly the gap SAMP's big-kernel fusion closes (§3.2), and
            // it costs FT 3 extra launches + FP16-width traffic per layer —
            // the §4.3 5~10%.
            s.push(Kernel::gemm(format!("{pre}/qkv_i8"), rows, 3 * h, h, i8,
                                a(rows, h, i8) + a(h, 3 * h, i8),
                                3.0 * a(rows, h, i8)));
            s.push(Kernel::elementwise(format!("{pre}/bias_transpose_i8"),
                                       6.0 * a(rows, h, i8), f16));
            s.push(Kernel::gemm(format!("{pre}/qk_i8"), bh * w.seq, w.seq, hd,
                                i8, 2.0 * a(rows, h, i8),
                                act(bh * w.seq, w.seq, f16)));
            // softmax in FP16, then a standalone quantize kernel for P
            s.push(Kernel::elementwise(format!("{pre}/scale_mask_softmax"),
                                       2.0 * act(bh * w.seq, w.seq, f16), f16));
            s.push(Kernel::elementwise(format!("{pre}/quant_p"),
                                       act(bh * w.seq, w.seq, f16) + score_i8,
                                       f16));
            s.push(Kernel::gemm(format!("{pre}/pv_i8"), bh * w.seq, hd, w.seq,
                                i8, score_i8 + a(rows, h, i8),
                                a(rows, h, i8)));
            s.push(Kernel::gemm(format!("{pre}/wo_i8"), rows, h, h, i8,
                                a(rows, h, i8) + a(h, h, i8), a(rows, h, i8)));
            // LN epilogue reads int8 GEMM out but writes FP16...
            s.push(Kernel::elementwise(format!("{pre}/bias_res_ln1"),
                                       2.0 * a(rows, h, i8) + a(rows, h, f16),
                                       f16));
            // ...so the FFN input needs a standalone quantize kernel
            s.push(Kernel::elementwise(format!("{pre}/quant_ffn"),
                                       a(rows, h, f16) + a(rows, h, i8), f16));
            s.push(Kernel::gemm(format!("{pre}/fc1_i8"), rows, g.ffn, h, i8,
                                a(rows, h, i8) + a(h, g.ffn, i8),
                                a(rows, g.ffn, f16)));
            s.push(Kernel::elementwise(format!("{pre}/bias_gelu_quant"),
                                       a(rows, g.ffn, f16) + a(rows, g.ffn, i8),
                                       f16));
            s.push(Kernel::gemm(format!("{pre}/fc2_i8"), rows, h, g.ffn, i8,
                                a(rows, g.ffn, i8) + a(g.ffn, h, i8),
                                a(rows, h, i8)));
            s.push(Kernel::elementwise(format!("{pre}/bias_res_ln2"),
                                       2.0 * a(rows, h, i8) + a(rows, h, f16),
                                       f16));
        }
        _ => panic!("{tk:?} has no INT8 path (Table 1)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::{BERT_BASE, TESLA_T4};

    fn uniform(mode: LayerMode) -> Vec<LayerMode> {
        vec![mode; BERT_BASE.layers]
    }

    fn lat(tk: Toolkit, mode: LayerMode, batch: usize, seq: usize) -> f64 {
        encoder_schedule(tk, BERT_BASE, Workload { batch, seq }, &uniform(mode))
            .total_us(&TESLA_T4)
    }

    #[test]
    fn samp_beats_ft_beats_pytorch_fp16() {
        for (b, s) in [(1, 32), (8, 64), (16, 128), (32, 256)] {
            let samp = lat(Toolkit::Samp, LayerMode::Fp16, b, s);
            let ft = lat(Toolkit::FasterTransformer, LayerMode::Fp16, b, s);
            let pt = lat(Toolkit::PyTorch, LayerMode::Fp16, b, s);
            assert!(samp < ft, "samp {samp} !< ft {ft} at ({b},{s})");
            assert!(ft < pt, "ft {ft} !< pt {pt} at ({b},{s})");
        }
    }

    #[test]
    fn samp_int8_edge_over_ft_is_5_to_15_percent() {
        // §4.3: SAMP INT8 exceeds FasterTransformer by 5~10% (we accept a
        // slightly wider band across shapes).
        for (b, s) in [(1, 64), (8, 64), (16, 128)] {
            let samp = lat(Toolkit::Samp, LayerMode::Int8Full, b, s);
            let ft = lat(Toolkit::FasterTransformer, LayerMode::Int8Full, b, s);
            let edge = ft / samp;
            assert!((1.02..1.30).contains(&edge),
                    "edge {edge:.3} out of band at ({b},{s})");
        }
    }

    #[test]
    fn int8_faster_than_fp16_faster_than_fp32() {
        let i8_ = lat(Toolkit::Samp, LayerMode::Int8Full, 8, 64);
        let f16 = lat(Toolkit::Samp, LayerMode::Fp16, 8, 64);
        let f32_ = lat(Toolkit::Samp, LayerMode::Fp32, 8, 64);
        assert!(i8_ < f16 && f16 < f32_);
    }

    #[test]
    fn ffn_only_speedup_grows_linearly_with_k() {
        // each additional Quant-FFN-Only layer buys roughly constant time
        let base = lat(Toolkit::Samp, LayerMode::Fp16, 8, 64);
        let mut prev = base;
        let mut deltas = vec![];
        for k in 1..=12 {
            let mut plan = uniform(LayerMode::Fp16);
            for m in plan.iter_mut().take(k) {
                *m = LayerMode::Int8Ffn;
            }
            let t = encoder_schedule(Toolkit::Samp, BERT_BASE,
                                     Workload { batch: 8, seq: 64 }, &plan)
                .total_us(&TESLA_T4);
            deltas.push(prev - t);
            prev = t;
        }
        let mean: f64 = deltas.iter().sum::<f64>() / deltas.len() as f64;
        for d in &deltas {
            assert!((d - mean).abs() < 0.25 * mean.abs().max(1.0),
                    "non-linear step {d} vs mean {mean}");
        }
        // and each layer buys roughly 2~3% of the FP16 baseline (paper §3.2)
        let pct = mean / base * 100.0;
        assert!((0.5..6.0).contains(&pct), "per-layer gain {pct:.2}%");
    }

    #[test]
    fn samp_fuses_away_standalone_quant_kernels() {
        // "reducing CUDA kernel calls by half" (§1) refers to the
        // quantization-related operations: SAMP folds every Quant/deQuant
        // into the adjacent GEMM / big-kernel epilogue, FT launches them
        // standalone.  Also the embedding: 1 fused kernel vs 4+.
        let count_quant = |tk| {
            encoder_schedule(tk, BERT_BASE, Workload { batch: 8, seq: 64 },
                             &uniform(LayerMode::Int8Full))
                .kernels
                .iter()
                .filter(|k| k.name.contains("/quant_"))
                .count()
        };
        assert_eq!(count_quant(Toolkit::Samp), 0);
        assert!(count_quant(Toolkit::FasterTransformer) >= 2 * BERT_BASE.layers);

        let count_emb = |tk| {
            encoder_schedule(tk, BERT_BASE, Workload { batch: 8, seq: 64 },
                             &uniform(LayerMode::Int8Full))
                .kernels
                .iter()
                .filter(|k| k.name.starts_with("emb"))
                .count()
        };
        assert_eq!(count_emb(Toolkit::Samp), 1);
        assert!(count_emb(Toolkit::FasterTransformer) >= 4);
    }

    #[test]
    fn pytorch_has_no_int8() {
        let r = std::panic::catch_unwind(|| {
            lat(Toolkit::PyTorch, LayerMode::Int8Full, 1, 32)
        });
        assert!(r.is_err());
    }

    #[test]
    fn mixed_plan_latency_between_bounds() {
        let mut plan = uniform(LayerMode::Fp16);
        for m in plan.iter_mut().take(6) {
            *m = LayerMode::Int8Full;
        }
        let mixed = encoder_schedule(Toolkit::Samp, BERT_BASE,
                                     Workload { batch: 8, seq: 64 }, &plan)
            .total_us(&TESLA_T4);
        let fp16 = lat(Toolkit::Samp, LayerMode::Fp16, 8, 64);
        let full = lat(Toolkit::Samp, LayerMode::Int8Full, 8, 64);
        assert!(full < mixed && mixed < fp16);
    }
}
