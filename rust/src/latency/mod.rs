//! GPU latency cost model (Tesla T4) + per-toolkit kernel schedules.
//!
//! The paper's speedup numbers (Table 2 columns, Figure 3) were measured on a
//! Tesla T4 with CUDA 11; that hardware is not available here, so DESIGN.md §4
//! substitutes an *analytical cost model*:
//!
//! ```text
//! t(kernel) = launch_overhead + max(flops / peak(dtype), bytes / mem_bw)
//! ```
//!
//! The three effects the paper's speedups are built from are exactly what the
//! model encodes:
//!   1. dtype throughput ratios (T4: FP32 8.1 TF, FP16 TC 65 TF, INT8 TC 130 TOPS);
//!   2. kernel-launch counts — SAMP's fusion strategies remove launches;
//!   3. inter-kernel memory traffic bit-width — Fully-Quant keeps dataflow
//!      INT8 ("all green arrows", Fig 2a), halving elementwise kernel bytes.
//!
//! Schedules are built per toolkit (SAMP / FasterTransformer / TurboTransformers
//! / PyTorch) x per layer precision plan, mirroring each system's public fusion
//! behaviour.  Absolute microseconds are a model; *ratios* are the deliverable
//! (EXPERIMENTS.md compares their shape against the paper's).

pub mod schedules;

pub use schedules::{encoder_schedule, Toolkit};

/// Numeric mode of one Transformer layer (mirrors python model.MODES).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerMode {
    Fp32,
    Fp16,
    /// Quant-FFN-Only (Fig 2b).
    Int8Ffn,
    /// Fully-Quant (Fig 2a).
    Int8Full,
}

impl LayerMode {
    pub fn parse(s: &str) -> Option<LayerMode> {
        Some(match s {
            "fp32" => LayerMode::Fp32,
            "fp16" => LayerMode::Fp16,
            "int8_ffn" => LayerMode::Int8Ffn,
            "int8_full" => LayerMode::Int8Full,
            _ => return None,
        })
    }

    /// The manifest spelling of this mode (inverse of [`LayerMode::parse`]).
    pub fn as_str(self) -> &'static str {
        match self {
            LayerMode::Fp32 => "fp32",
            LayerMode::Fp16 => "fp16",
            LayerMode::Int8Ffn => "int8_ffn",
            LayerMode::Int8Full => "int8_full",
        }
    }

    /// Whether any GEMM of this layer runs INT8.
    pub fn is_int8(self) -> bool {
        matches!(self, LayerMode::Int8Ffn | LayerMode::Int8Full)
    }
}

/// Compute dtype of a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    F16,
    I8,
}

impl DType {
    pub fn bytes(self) -> f64 {
        match self {
            DType::F32 => 4.0,
            DType::F16 => 2.0,
            DType::I8 => 1.0,
        }
    }
}

/// GPU device description for the roofline.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub name: &'static str,
    pub fp32_tflops: f64,
    pub fp16_tflops: f64,
    pub int8_tops: f64,
    pub mem_bw_gbs: f64,
    /// Fixed per-kernel CUDA launch + scheduling overhead (us).
    pub launch_us: f64,
    /// Achievable fraction of peak for dense GEMMs.
    pub gemm_eff: f64,
    /// Achievable fraction of peak memory bandwidth.
    pub mem_eff: f64,
}

/// NVIDIA Tesla T4 (the paper's testbed, §4.1).
pub const TESLA_T4: GpuSpec = GpuSpec {
    name: "Tesla T4",
    fp32_tflops: 8.1,
    fp16_tflops: 65.0,
    int8_tops: 130.0,
    mem_bw_gbs: 300.0,
    launch_us: 3.0,
    gemm_eff: 0.60,
    mem_eff: 0.75,
};

/// One modeled kernel launch.
#[derive(Debug, Clone)]
pub struct Kernel {
    pub name: String,
    /// Multiply-accumulate-style operations (2*M*N*K for GEMM).
    pub flops: f64,
    /// Bytes moved to/from HBM (reads + writes).
    pub bytes: f64,
    /// dtype whose throughput lane the flops use.
    pub dtype: DType,
}

impl Kernel {
    pub fn gemm(name: impl Into<String>, m: usize, n: usize, k: usize,
                dtype: DType, in_bytes: f64, out_bytes: f64) -> Kernel {
        Kernel {
            name: name.into(),
            flops: 2.0 * m as f64 * n as f64 * k as f64,
            bytes: in_bytes + out_bytes,
            dtype,
        }
    }

    /// Elementwise/reduction kernel: negligible flops, pure memory.
    pub fn elementwise(name: impl Into<String>, bytes: f64, dtype: DType) -> Kernel {
        Kernel { name: name.into(), flops: 0.0, bytes, dtype }
    }

    /// Modeled execution time in microseconds.
    pub fn time_us(&self, gpu: &GpuSpec) -> f64 {
        let peak_flops = match self.dtype {
            DType::F32 => gpu.fp32_tflops,
            DType::F16 => gpu.fp16_tflops,
            DType::I8 => gpu.int8_tops,
        } * 1e12
            * gpu.gemm_eff;
        let compute_us = if self.flops > 0.0 { self.flops / peak_flops * 1e6 } else { 0.0 };
        let mem_us = self.bytes / (gpu.mem_bw_gbs * 1e9 * gpu.mem_eff) * 1e6;
        gpu.launch_us + compute_us.max(mem_us)
    }
}

/// A full kernel sequence for one forward pass.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    pub kernels: Vec<Kernel>,
}

impl Schedule {
    pub fn push(&mut self, k: Kernel) {
        self.kernels.push(k);
    }

    pub fn total_us(&self, gpu: &GpuSpec) -> f64 {
        self.kernels.iter().map(|k| k.time_us(gpu)).sum()
    }

    pub fn launches(&self) -> usize {
        self.kernels.len()
    }

    pub fn total_bytes(&self) -> f64 {
        self.kernels.iter().map(|k| k.bytes).sum()
    }

    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum()
    }
}

/// Encoder geometry (BERT-base by default — the Fig 3 comparisons).
#[derive(Debug, Clone, Copy)]
pub struct Geometry {
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub ffn: usize,
}

pub const BERT_BASE: Geometry =
    Geometry { layers: 12, hidden: 768, heads: 12, ffn: 3072 };

/// Request shape.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub batch: usize,
    pub seq: usize,
}

/// Convenience: end-to-end modeled latency for a uniform plan.
pub fn encoder_latency_us(toolkit: Toolkit, geom: Geometry, wl: Workload,
                          plan: &[LayerMode], gpu: &GpuSpec) -> f64 {
    encoder_schedule(toolkit, geom, wl, plan).total_us(gpu)
}

/// Speedup of `a` over `b` (>1 means a is faster).
pub fn speedup(a_us: f64, b_us: f64) -> f64 {
    b_us / a_us
}

/// Modeled SAMP encoder latency (ms) of an arbitrary per-layer plan at a
/// serving shape.  The evaluation models are tiny (H=64, launch-dominated —
/// INT8 gains would invert), so latency is always modeled at the paper's
/// BERT-base width; the task contributes its layer count and [batch, seq].
/// Shared by `Router::model_latency_ms` and the plan-search subsystem
/// (`planner`), so the router and the planner can never disagree about what a
/// plan costs.
pub fn samp_plan_latency_ms(layers: usize, batch: usize, seq: usize,
                            plan: &[LayerMode]) -> f64 {
    let geom = Geometry {
        layers,
        hidden: BERT_BASE.hidden,
        heads: BERT_BASE.heads,
        ffn: BERT_BASE.ffn,
    };
    encoder_latency_us(Toolkit::Samp, geom, Workload { batch, seq }, plan,
                       &TESLA_T4) / 1000.0
}

/// Modeled **native CPU** encoder latency (ms) of a per-layer plan: an
/// Amdahl roofline of the in-tree kernels, at the same BERT-base-width
/// convention as [`samp_plan_latency_ms`].  GEMM work (the INT8/f32 matrix
/// multiplies) divides across the `--gemm-threads` batch-row partitioning;
/// attention mixing, layernorms and activation quantization stay serial per
/// dispatcher worker.  The T4 model above is the paper's reporting
/// convention and is deliberately untouched by CPU threading.
pub fn native_cpu_plan_latency_ms(layers: usize, batch: usize, seq: usize,
                                  plan: &[LayerMode], threads: usize) -> f64 {
    CpuCostModel::default().plan_latency_ms(layers, batch, seq, plan, threads)
}

/// The native-CPU roofline's constants, held in one place so they can be
/// **calibrated** against a measured `bench_gemm` raw sweep instead of
/// staying hand-picked forever ([`CpuCostModel::calibrated`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CpuCostModel {
    /// Effective single-core f32 GEMM throughput, GOP/s (mul + add = 2 ops).
    pub f32_gops: f64,
    /// Effective single-core INT8 GEMM throughput, GOP/s.
    pub int8_gops: f64,
    /// Serial (non-GEMM) path throughput: attention mixing + epilogues.
    pub serial_gops: f64,
    /// Fixed per-layer cost (dispatch, quant epilogues), microseconds.
    pub layer_overhead_us: f64,
}

impl Default for CpuCostModel {
    /// The hand-picked defaults: the bench_gemm raw sweep's order of
    /// magnitude — the INT8/f32 ratio (5x) is what matters, mirroring the
    /// >= 3x CI gate with headroom, not the absolute numbers.
    fn default() -> Self {
        CpuCostModel {
            f32_gops: 4.0,
            int8_gops: 20.0,
            serial_gops: 2.0,
            layer_overhead_us: 20.0,
        }
    }
}

impl CpuCostModel {
    /// Fit the throughput constants to a measured `bench_gemm` raw sweep
    /// (`raw_f32_gflops` / `raw_int8_gops` of the `"gemm"` section in
    /// `BENCH_SERVING.json`): the measured rates *are* the effective
    /// single-thread whole-matrix throughputs the roofline needs.  The
    /// serial path is f32 vector math, so it scales with the measured f32
    /// rate; the per-layer overhead has no bench_gemm counterpart and
    /// stays at its default.  Non-positive measurements keep the default
    /// constant they would have replaced.
    pub fn calibrated(raw_f32_gflops: f64, raw_int8_gops: f64) -> CpuCostModel {
        let d = CpuCostModel::default();
        let f32_gops = if raw_f32_gflops > 0.0 && raw_f32_gflops.is_finite() {
            raw_f32_gflops
        } else {
            d.f32_gops
        };
        let int8_gops = if raw_int8_gops > 0.0 && raw_int8_gops.is_finite() {
            raw_int8_gops
        } else {
            d.int8_gops
        };
        CpuCostModel {
            f32_gops,
            int8_gops,
            serial_gops: d.serial_gops * (f32_gops / d.f32_gops),
            layer_overhead_us: d.layer_overhead_us,
        }
    }

    /// [`CpuCostModel::calibrated`] from a parsed `BENCH_SERVING.json`
    /// (reads `gemm.raw_f32_gflops` / `gemm.raw_int8_gops`); `None` when
    /// the file has no `"gemm"` section yet.
    pub fn from_bench_json(bench: &crate::util::json::Json)
                           -> Option<CpuCostModel> {
        let gemm = bench.get("gemm");
        let f32_gflops = gemm.get("raw_f32_gflops").as_f64()?;
        let int8_gops = gemm.get("raw_int8_gops").as_f64()?;
        Some(CpuCostModel::calibrated(f32_gflops, int8_gops))
    }

    /// The Amdahl roofline of [`native_cpu_plan_latency_ms`] on this
    /// model's constants.
    pub fn plan_latency_ms(&self, layers: usize, batch: usize, seq: usize,
                           plan: &[LayerMode], threads: usize) -> f64 {
        let threads = threads.max(1) as f64;
        let rows = (batch * seq) as f64;
        let h = BERT_BASE.hidden as f64;
        let f = BERT_BASE.ffn as f64;
        let mut total_us = 0.0;
        for li in 0..layers {
            let mode = plan.get(li).copied().unwrap_or(LayerMode::Fp16);
            let proj_ops = 2.0 * 4.0 * rows * h * h; // QKV + output projection
            let ffn_ops = 2.0 * 2.0 * rows * h * f; // W1 + W2
            let (proj_gops, ffn_gops) = match mode {
                LayerMode::Int8Full => (self.int8_gops, self.int8_gops),
                LayerMode::Int8Ffn => (self.f32_gops, self.int8_gops),
                // fp32/fp16 plans both run the f32 reference kernels on CPU
                _ => (self.f32_gops, self.f32_gops),
            };
            // ops / (GOPS * 1e9) seconds = ops / GOPS / 1e3 microseconds
            let gemm_us =
                (proj_ops / proj_gops + ffn_ops / ffn_gops) / 1e3 / threads;
            let serial_us = 4.0 * rows * seq as f64 * h / self.serial_gops
                / 1e3;
            total_us += gemm_us + serial_us + self.layer_overhead_us;
        }
        total_us / 1000.0
    }
}

/// Modeled PyTorch-FP16 baseline latency (ms) at the same convention — the
/// Table-2 speedup denominator.
pub fn pytorch_fp16_baseline_ms(layers: usize, batch: usize, seq: usize) -> f64 {
    let geom = Geometry {
        layers,
        hidden: BERT_BASE.hidden,
        heads: BERT_BASE.heads,
        ffn: BERT_BASE.ffn,
    };
    let plan = vec![LayerMode::Fp16; layers];
    encoder_latency_us(Toolkit::PyTorch, geom, Workload { batch, seq }, &plan,
                       &TESLA_T4) / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_throughput_ordering() {
        // For a large compute-bound GEMM the dtype lanes must order
        // INT8 < FP16 < FP32 in time.
        let g = |d| Kernel::gemm("g", 4096, 4096, 4096, d, 0.0, 0.0).time_us(&TESLA_T4);
        assert!(g(DType::I8) < g(DType::F16));
        assert!(g(DType::F16) < g(DType::F32));
    }

    #[test]
    fn launch_overhead_floors_small_kernels() {
        let k = Kernel::elementwise("tiny", 16.0, DType::F32);
        assert!(k.time_us(&TESLA_T4) >= TESLA_T4.launch_us);
    }

    #[test]
    fn memory_bound_kernel_uses_bandwidth() {
        // 1 GiB at 300 GB/s * 0.75 eff ~ 4.7 ms >> launch overhead
        let k = Kernel::elementwise("big", 1e9, DType::F16);
        let t = k.time_us(&TESLA_T4);
        let want = TESLA_T4.launch_us + 1e9 / (300e9 * 0.75) * 1e6;
        assert!((t - want).abs() < 1.0);
    }

    #[test]
    fn mode_string_roundtrip() {
        for m in [LayerMode::Fp32, LayerMode::Fp16, LayerMode::Int8Ffn,
                  LayerMode::Int8Full] {
            assert_eq!(LayerMode::parse(m.as_str()), Some(m));
        }
        assert!(LayerMode::Fp16.as_str() == "fp16");
        assert!(!LayerMode::Fp32.is_int8());
        assert!(LayerMode::Int8Ffn.is_int8());
    }

    #[test]
    fn plan_latency_is_monotone_in_int8_layer_count() {
        // quantizing one more layer can only remove modeled cost — the
        // invariant the planner's frontier relies on
        let mut prev = f64::INFINITY;
        for k in 0..=12usize {
            let mut plan = vec![LayerMode::Fp16; 12];
            for m in plan.iter_mut().take(k) {
                *m = LayerMode::Int8Full;
            }
            let ms = samp_plan_latency_ms(12, 8, 64, &plan);
            assert!(ms <= prev, "k={k}: {ms} > {prev}");
            prev = ms;
        }
        // and the baseline helper is slower than fully-quantized SAMP
        assert!(pytorch_fp16_baseline_ms(12, 8, 64)
                > samp_plan_latency_ms(12, 8, 64,
                                       &[LayerMode::Int8Full; 12]));
    }

    #[test]
    fn native_cpu_latency_is_monotone_in_int8_layers_and_threads() {
        // the planner's frontier invariant, on the CPU column too: one more
        // INT8 layer can only remove modeled cost, at every thread count
        for threads in [1usize, 4] {
            let mut prev = f64::INFINITY;
            for k in 0..=12usize {
                let mut plan = vec![LayerMode::Fp16; 12];
                for m in plan.iter_mut().take(k) {
                    *m = LayerMode::Int8Full;
                }
                let ms = native_cpu_plan_latency_ms(12, 8, 64, &plan, threads);
                assert!(ms < prev, "threads={threads} k={k}: {ms} >= {prev}");
                prev = ms;
            }
        }
        // FFN-only sits strictly between fp16 and fully-quantized
        let fp16 = vec![LayerMode::Fp16; 12];
        let ffn = vec![LayerMode::Int8Ffn; 12];
        let full = vec![LayerMode::Int8Full; 12];
        let ms = |p: &[LayerMode]| native_cpu_plan_latency_ms(12, 8, 64, p, 1);
        assert!(ms(&full) < ms(&ffn) && ms(&ffn) < ms(&fp16));
    }

    #[test]
    fn native_cpu_latency_threads_strictly_help_gemm_time() {
        // more GEMM threads must strictly reduce the modeled latency (the
        // GEMM term is never zero), but can't beat the serial floor: 4
        // threads gain less than 4x end to end (Amdahl)
        let plan = vec![LayerMode::Int8Full; 12];
        let t1 = native_cpu_plan_latency_ms(12, 8, 64, &plan, 1);
        let t4 = native_cpu_plan_latency_ms(12, 8, 64, &plan, 4);
        assert!(t4 < t1, "threads=4 ({t4}) not faster than 1 ({t1})");
        assert!(t1 / t4 < 4.0, "speedup {:.2} ignores the serial part",
                t1 / t4);
        // threads=0 is clamped to 1, not a crash
        assert_eq!(native_cpu_plan_latency_ms(12, 8, 64, &plan, 0), t1);
    }

    #[test]
    fn cost_model_calibration_fits_measured_rates() {
        let d = CpuCostModel::default();
        let c = CpuCostModel::calibrated(8.0, 40.0);
        assert_eq!(c.f32_gops, 8.0);
        assert_eq!(c.int8_gops, 40.0);
        // the serial path is f32 vector math: 2x the measured f32 rate
        // scales it 2x too
        assert_eq!(c.serial_gops, d.serial_gops * 2.0);
        assert_eq!(c.layer_overhead_us, d.layer_overhead_us);
        // unusable measurements keep the defaults they would have replaced
        assert_eq!(CpuCostModel::calibrated(0.0, f64::NAN), d);
        // and the helper reads bench_gemm's section of BENCH_SERVING.json
        let bench = crate::util::json::Json::parse(
            r#"{"gemm": {"raw_f32_gflops": 6.0, "raw_int8_gops": 30.0}}"#)
            .unwrap();
        let m = CpuCostModel::from_bench_json(&bench).unwrap();
        assert_eq!(m.f32_gops, 6.0);
        assert_eq!(m.int8_gops, 30.0);
        let empty = crate::util::json::Json::parse("{}").unwrap();
        assert!(CpuCostModel::from_bench_json(&empty).is_none());
    }

    #[test]
    fn calibrated_model_ranks_plans_like_measurements() {
        use crate::backend::native::{gemm_f32_with, gemm_i8_with,
                                     quantize_dynamic, GemmKernel, PackedI8};
        use crate::util::prng::Prng;

        // measure the raw single-thread kernel rates, bench_gemm-style
        let (m, k, n) = (128, 256, 256);
        let mut p = Prng::new(7);
        let a: Vec<f32> =
            (0..m * k).map(|_| p.f64() as f32 - 0.5).collect();
        let w: Vec<f32> =
            (0..k * n).map(|_| p.f64() as f32 - 0.5).collect();
        let packed = PackedI8::pack(&w, k, n);
        let mut qa = Vec::new();
        let sa = quantize_dynamic(&a, &mut qa);
        let mut out = vec![0f32; m * n];
        let kern = GemmKernel::active();
        let ops = 2.0 * (m * k * n) as f64;
        let time_best = |f: &mut dyn FnMut()| -> f64 {
            f(); // warm caches before timing
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                f();
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };
        let f32_s = time_best(&mut || {
            gemm_f32_with(kern, &a, &w, None, m, k, n, &mut out).unwrap();
        });
        let i8_s = time_best(&mut || {
            gemm_i8_with(kern, &qa, sa, &packed, None, m, &mut out).unwrap();
        });
        let model =
            CpuCostModel::calibrated(ops / f32_s / 1e9, ops / i8_s / 1e9);

        // the calibrated model must rank plan points in the same order the
        // measured kernels do: run each plan's GEMM mix for real and
        // compare rank orders
        let layers = 12usize;
        let plan_points = [0usize, 6, 12];
        let measured: Vec<f64> = plan_points
            .iter()
            .map(|&int8_layers| {
                time_best(&mut || {
                    for li in 0..layers {
                        if li < int8_layers {
                            gemm_i8_with(kern, &qa, sa, &packed, None, m,
                                         &mut out)
                                .unwrap();
                        } else {
                            gemm_f32_with(kern, &a, &w, None, m, k, n,
                                          &mut out)
                                .unwrap();
                        }
                    }
                })
            })
            .collect();
        let modeled: Vec<f64> = plan_points
            .iter()
            .map(|&int8_layers| {
                let mut plan = vec![LayerMode::Fp16; layers];
                for mode in plan.iter_mut().take(int8_layers) {
                    *mode = LayerMode::Int8Full;
                }
                model.plan_latency_ms(layers, 8, 64, &plan, 1)
            })
            .collect();
        let rank = |v: &[f64]| -> Vec<usize> {
            let mut idx: Vec<usize> = (0..v.len()).collect();
            idx.sort_by(|&i, &j| v[i].total_cmp(&v[j]));
            idx
        };
        assert_eq!(rank(&measured), rank(&modeled),
                   "measured {measured:?} vs modeled {modeled:?}");
    }

    #[test]
    fn schedule_totals_add_up() {
        let mut s = Schedule::default();
        s.push(Kernel::elementwise("a", 100.0, DType::F32));
        s.push(Kernel::gemm("b", 8, 8, 8, DType::F32, 256.0, 256.0));
        assert_eq!(s.launches(), 2);
        assert!(s.total_us(&TESLA_T4) > 2.0 * TESLA_T4.launch_us);
        assert_eq!(s.total_flops(), 2.0 * 8.0 * 8.0 * 8.0);
    }
}
