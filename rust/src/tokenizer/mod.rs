//! End-to-end tokenization (the paper's Tokenizer module, §3.1).
//!
//! SAMP ships a complete C++ preprocessing module so nothing upstream of the
//! encoder runs Python; this is the Rust equivalent:
//!
//! * [`Vocab`] — vocabulary file (one token per line, line number = id).
//! * [`BasicTokenizer`] — whitespace/punctuation splitting, lower-casing,
//!   CJK character isolation (the "character-based tokenization" granularity).
//! * [`WordpieceTokenizer`] — greedy longest-match-first subword split with
//!   `##` continuation pieces.
//! * [`BertTokenizer`] — the full pipeline: basic -> wordpiece -> specials
//!   ([CLS]/[SEP]/[PAD]) + segment ids for sentence pairs + attention mask —
//!   i.e. "general BertTokenizer" in Table 1.
//!
//! Multi-granularity (§3.1: character / wordpiece / Bert) is selected with
//! [`Granularity`].

pub mod vocab;
pub mod wordpiece;

pub use vocab::Vocab;
pub use wordpiece::WordpieceTokenizer;

/// Tokenization granularity (Table 1 "multi-granularity tokenization").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// Every CJK char isolated, other text split per word, no subwords.
    Char,
    /// Wordpiece subwords (BERT default).
    Wordpiece,
}

/// Output of the full pipeline: ready-to-batch model inputs.
#[derive(Debug, Clone, PartialEq)]
pub struct Encoding {
    pub ids: Vec<i32>,
    pub segment_ids: Vec<i32>,
    pub attention_mask: Vec<i32>,
    /// Surface tokens (diagnostics / NER detokenization).
    pub tokens: Vec<String>,
}

/// Basic tokenizer: lower-case, strip control chars, isolate CJK and
/// punctuation, split on whitespace.
#[derive(Debug, Clone)]
pub struct BasicTokenizer {
    pub lower_case: bool,
}

impl Default for BasicTokenizer {
    fn default() -> Self {
        BasicTokenizer { lower_case: true }
    }
}

fn is_cjk(c: char) -> bool {
    matches!(c as u32,
        0x4E00..=0x9FFF | 0x3400..=0x4DBF | 0xF900..=0xFAFF
        | 0x20000..=0x2A6DF | 0x2A700..=0x2B73F)
}

fn is_punct(c: char) -> bool {
    c.is_ascii_punctuation()
        || matches!(c as u32, 0x3000..=0x303F | 0xFF00..=0xFFEF)
}

impl BasicTokenizer {
    /// Split text into basic tokens.
    pub fn tokenize(&self, text: &str) -> Vec<String> {
        let mut out = Vec::new();
        let mut cur = String::new();
        for mut c in text.chars() {
            if c.is_control() && c != '\t' && c != '\n' {
                continue;
            }
            if self.lower_case {
                c = c.to_ascii_lowercase();
            }
            if c.is_whitespace() {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
            } else if is_cjk(c) || is_punct(c) {
                if !cur.is_empty() {
                    out.push(std::mem::take(&mut cur));
                }
                out.push(c.to_string());
            } else {
                cur.push(c);
            }
        }
        if !cur.is_empty() {
            out.push(cur);
        }
        out
    }
}

/// The full BERT pipeline over a [`Vocab`].
#[derive(Debug)]
pub struct BertTokenizer {
    pub vocab: Vocab,
    pub basic: BasicTokenizer,
    pub wordpiece: WordpieceTokenizer,
    pub granularity: Granularity,
}

impl BertTokenizer {
    pub fn new(vocab: Vocab) -> Self {
        let wordpiece = WordpieceTokenizer::default();
        BertTokenizer {
            vocab,
            basic: BasicTokenizer::default(),
            wordpiece,
            granularity: Granularity::Wordpiece,
        }
    }

    pub fn with_granularity(mut self, g: Granularity) -> Self {
        self.granularity = g;
        self
    }

    /// Tokenize raw text to surface tokens (no specials).
    pub fn tokenize(&self, text: &str) -> Vec<String> {
        let base = self.basic.tokenize(text);
        match self.granularity {
            Granularity::Char => base,
            Granularity::Wordpiece => base
                .iter()
                .flat_map(|t| self.wordpiece.tokenize(t, &self.vocab))
                .collect(),
        }
    }

    /// Encode one sentence (or a pair, `text_b`) to fixed length `max_len`:
    /// [CLS] a... [SEP] (b... [SEP]) + padding, BERT segment ids.
    pub fn encode(&self, text_a: &str, text_b: Option<&str>, max_len: usize)
                  -> Encoding {
        self.encode_opts(text_a, text_b, max_len, true)
    }

    /// Like [`BertTokenizer::encode`], with surface-token materialization
    /// optional.  The serving hot path never reads `Encoding::tokens` (NER
    /// decode passes `tokens: None`), so `want_tokens: false` skips one
    /// `String` allocation per sequence position, padding included.
    pub fn encode_opts(&self, text_a: &str, text_b: Option<&str>,
                       max_len: usize, want_tokens: bool) -> Encoding {
        let cls = self.vocab.cls_id();
        let sep = self.vocab.sep_id();
        let pad = self.vocab.pad_id();

        let a = self.tokenize(text_a);
        let b: Vec<String> = text_b.map(|t| self.tokenize(t)).unwrap_or_default();

        // truncate longest-first to fit specials (BERT convention)
        let n_special = if b.is_empty() { 2 } else { 3 };
        let budget = max_len.saturating_sub(n_special);
        let (mut la, mut lb) = (a.len(), b.len());
        while la + lb > budget {
            if la >= lb {
                la -= 1;
            } else {
                lb -= 1;
            }
        }

        let mut tokens = Vec::with_capacity(if want_tokens { max_len } else { 0 });
        let push_tok = |tokens: &mut Vec<String>, t: &str| {
            if want_tokens {
                tokens.push(t.to_string());
            }
        };
        let mut ids = Vec::with_capacity(max_len);
        let mut segs = Vec::with_capacity(max_len);
        push_tok(&mut tokens, "[CLS]");
        ids.push(cls);
        segs.push(0);
        for t in &a[..la] {
            ids.push(self.vocab.id_of(t));
            push_tok(&mut tokens, t);
            segs.push(0);
        }
        push_tok(&mut tokens, "[SEP]");
        ids.push(sep);
        segs.push(0);
        if !b.is_empty() {
            for t in &b[..lb] {
                ids.push(self.vocab.id_of(t));
                push_tok(&mut tokens, t);
                segs.push(1);
            }
            push_tok(&mut tokens, "[SEP]");
            ids.push(sep);
            segs.push(1);
        }
        let used = ids.len();
        let mut mask = vec![1; used];
        while ids.len() < max_len {
            ids.push(pad);
            segs.push(0);
            mask.push(0);
            push_tok(&mut tokens, "[PAD]");
        }
        Encoding { ids, segment_ids: segs, attention_mask: mask, tokens }
    }

    /// Encode a request that may contain a tab-separated sentence pair
    /// (the matching-task wire format).
    pub fn encode_request(&self, text: &str, max_len: usize) -> Encoding {
        match text.split_once('\t') {
            Some((a, b)) => self.encode(a, Some(b), max_len),
            None => self.encode(text, None, max_len),
        }
    }

    /// [`BertTokenizer::encode_request`] without surface-token strings — the
    /// allocation-lean variant the serving pipeline uses.
    pub fn encode_request_lean(&self, text: &str, max_len: usize) -> Encoding {
        match text.split_once('\t') {
            Some((a, b)) => self.encode_opts(a, Some(b), max_len, false),
            None => self.encode_opts(text, None, max_len, false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_vocab() -> Vocab {
        Vocab::from_lines(
            ["[PAD]", "[UNK]", "[CLS]", "[SEP]", "[MASK]", "hello", "world",
             "un", "##aff", "##able", "中", "文", ",", "w00042"]
                .iter()
                .map(|s| s.to_string()),
        )
    }

    #[test]
    fn basic_splits_whitespace_and_punct() {
        let b = BasicTokenizer::default();
        assert_eq!(b.tokenize("Hello,  world!"),
                   vec!["hello", ",", "world", "!"]);
    }

    #[test]
    fn basic_isolates_cjk() {
        let b = BasicTokenizer::default();
        assert_eq!(b.tokenize("ab中文cd"), vec!["ab", "中", "文", "cd"]);
    }

    #[test]
    fn encode_single_sentence_layout() {
        let t = BertTokenizer::new(tiny_vocab());
        let e = t.encode("hello world", None, 8);
        assert_eq!(e.ids[0], 2); // [CLS]
        assert_eq!(e.ids[1], 5); // hello
        assert_eq!(e.ids[2], 6); // world
        assert_eq!(e.ids[3], 3); // [SEP]
        assert_eq!(&e.ids[4..], &[0, 0, 0, 0]);
        assert_eq!(e.attention_mask, vec![1, 1, 1, 1, 0, 0, 0, 0]);
        assert!(e.segment_ids.iter().all(|&s| s == 0));
    }

    #[test]
    fn encode_pair_segments() {
        let t = BertTokenizer::new(tiny_vocab());
        let e = t.encode("hello", Some("world"), 8);
        // [CLS] hello [SEP] world [SEP] pad pad pad
        assert_eq!(e.segment_ids, vec![0, 0, 0, 1, 1, 0, 0, 0]);
        assert_eq!(e.ids[3], 6);
    }

    #[test]
    fn encode_request_splits_on_tab() {
        let t = BertTokenizer::new(tiny_vocab());
        let pair = t.encode_request("hello\tworld", 8);
        assert_eq!(pair.segment_ids[3], 1);
        let single = t.encode_request("hello world", 8);
        assert!(single.segment_ids.iter().all(|&s| s == 0));
    }

    #[test]
    fn lean_encoding_matches_full_except_tokens() {
        let t = BertTokenizer::new(tiny_vocab());
        for text in ["hello world", "hello\tworld", "un aff 中文"] {
            let full = t.encode_request(text, 8);
            let lean = t.encode_request_lean(text, 8);
            assert_eq!(lean.ids, full.ids);
            assert_eq!(lean.segment_ids, full.segment_ids);
            assert_eq!(lean.attention_mask, full.attention_mask);
            assert_eq!(full.tokens.len(), 8);
            assert!(lean.tokens.is_empty());
        }
    }

    #[test]
    fn wordpiece_subwords_via_pipeline() {
        let t = BertTokenizer::new(tiny_vocab());
        assert_eq!(t.tokenize("unaffable"), vec!["un", "##aff", "##able"]);
    }

    #[test]
    fn unknown_maps_to_unk() {
        let t = BertTokenizer::new(tiny_vocab());
        let e = t.encode("zzzqqq", None, 6);
        assert_eq!(e.ids[1], 1); // [UNK]
    }

    #[test]
    fn truncation_fits_budget() {
        let t = BertTokenizer::new(tiny_vocab());
        let e = t.encode("hello world hello world hello", Some("world world"), 8);
        assert_eq!(e.ids.len(), 8);
        assert_eq!(e.attention_mask.iter().sum::<i32>(), 8);
        // must still terminate with [SEP]
        assert_eq!(*e.ids.last().unwrap(), 3);
    }

    #[test]
    fn char_granularity_skips_wordpiece() {
        let t = BertTokenizer::new(tiny_vocab()).with_granularity(Granularity::Char);
        assert_eq!(t.tokenize("unaffable"), vec!["unaffable"]);
        assert_eq!(t.tokenize("中文"), vec!["中", "文"]);
    }
}
