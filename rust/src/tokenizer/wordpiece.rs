//! WordPiece: greedy longest-match-first subword tokenization with `##`
//! continuation prefixes (Devlin et al. 2018; the paper's Table-1 wordpiece
//! granularity).

use super::vocab::Vocab;

#[derive(Debug, Clone)]
pub struct WordpieceTokenizer {
    /// Continuation prefix for non-initial pieces.
    pub prefix: &'static str,
    /// Words longer than this become a single [UNK] (BERT uses 100 chars).
    pub max_chars_per_word: usize,
}

impl Default for WordpieceTokenizer {
    fn default() -> Self {
        WordpieceTokenizer { prefix: "##", max_chars_per_word: 100 }
    }
}

impl WordpieceTokenizer {
    /// Split one basic token into wordpieces; falls back to ["[UNK]"] when no
    /// decomposition exists.
    pub fn tokenize(&self, word: &str, vocab: &Vocab) -> Vec<String> {
        let chars: Vec<char> = word.chars().collect();
        if chars.is_empty() {
            return vec![];
        }
        if chars.len() > self.max_chars_per_word {
            return vec![super::vocab::UNK.to_string()];
        }
        let mut pieces = Vec::new();
        let mut start = 0usize;
        while start < chars.len() {
            let mut end = chars.len();
            let mut cur: Option<String> = None;
            while start < end {
                let mut sub: String = chars[start..end].iter().collect();
                if start > 0 {
                    sub = format!("{}{}", self.prefix, sub);
                }
                if vocab.lookup(&sub).is_some() {
                    cur = Some(sub);
                    break;
                }
                end -= 1;
            }
            match cur {
                Some(p) => {
                    pieces.push(p);
                    start = end;
                }
                None => return vec![super::vocab::UNK.to_string()],
            }
        }
        pieces
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab() -> Vocab {
        Vocab::from_lines(
            ["[PAD]", "[UNK]", "[CLS]", "[SEP]", "un", "##aff", "##able",
             "hello", "##lo", "hell"]
                .iter()
                .map(|s| s.to_string()),
        )
    }

    #[test]
    fn classic_unaffable() {
        let wp = WordpieceTokenizer::default();
        assert_eq!(wp.tokenize("unaffable", &vocab()),
                   vec!["un", "##aff", "##able"]);
    }

    #[test]
    fn longest_match_first() {
        let wp = WordpieceTokenizer::default();
        // "hello" is in vocab whole — must NOT split into hell + ##lo
        assert_eq!(wp.tokenize("hello", &vocab()), vec!["hello"]);
    }

    #[test]
    fn no_decomposition_is_unk() {
        let wp = WordpieceTokenizer::default();
        assert_eq!(wp.tokenize("xyz", &vocab()), vec!["[UNK]"]);
        // decomposable head but impossible tail -> whole word UNK
        assert_eq!(wp.tokenize("unxyz", &vocab()), vec!["[UNK]"]);
    }

    #[test]
    fn empty_and_overlong() {
        let wp = WordpieceTokenizer { max_chars_per_word: 4, ..Default::default() };
        assert!(wp.tokenize("", &vocab()).is_empty());
        assert_eq!(wp.tokenize("toolong", &vocab()), vec!["[UNK]"]);
    }

    #[test]
    fn roundtrip_on_vocab_words() {
        // every non-special, non-continuation vocab word must tokenize to
        // itself (the property test in rust/tests exercises this at scale)
        let v = vocab();
        let wp = WordpieceTokenizer::default();
        for w in ["un", "hello", "hell"] {
            assert_eq!(wp.tokenize(w, &v), vec![w.to_string()]);
        }
    }
}
