//! Vocabulary: token string <-> id, loaded from the `vocab.txt` artifact
//! (line number = id, the BERT convention).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

/// BERT special-token surface forms.
pub const PAD: &str = "[PAD]";
pub const UNK: &str = "[UNK]";
pub const CLS: &str = "[CLS]";
pub const SEP: &str = "[SEP]";
pub const MASK: &str = "[MASK]";

#[derive(Debug, Clone)]
pub struct Vocab {
    id_by_token: HashMap<String, i32>,
    token_by_id: Vec<String>,
    unk: i32,
}

impl Vocab {
    pub fn from_lines<I: IntoIterator<Item = String>>(lines: I) -> Vocab {
        let token_by_id: Vec<String> = lines
            .into_iter()
            .map(|l| l.trim_end_matches(['\r', '\n']).to_string())
            .collect();
        let id_by_token = token_by_id
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as i32))
            .collect::<HashMap<_, _>>();
        let unk = *id_by_token.get(UNK).unwrap_or(&1);
        Vocab { id_by_token, token_by_id, unk }
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Vocab> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading vocab {}", path.display()))?;
        Ok(Vocab::from_lines(text.lines().map(|l| l.to_string())))
    }

    pub fn len(&self) -> usize {
        self.token_by_id.len()
    }

    pub fn is_empty(&self) -> bool {
        self.token_by_id.is_empty()
    }

    /// Token id, [UNK] for out-of-vocabulary.
    pub fn id_of(&self, token: &str) -> i32 {
        *self.id_by_token.get(token).unwrap_or(&self.unk)
    }

    /// Exact lookup (None when OOV) — used by wordpiece longest-match.
    pub fn lookup(&self, token: &str) -> Option<i32> {
        self.id_by_token.get(token).copied()
    }

    pub fn token_of(&self, id: i32) -> Option<&str> {
        self.token_by_id.get(id as usize).map(|s| s.as_str())
    }

    pub fn pad_id(&self) -> i32 {
        *self.id_by_token.get(PAD).unwrap_or(&0)
    }

    pub fn unk_id(&self) -> i32 {
        self.unk
    }

    pub fn cls_id(&self) -> i32 {
        *self.id_by_token.get(CLS).unwrap_or(&2)
    }

    pub fn sep_id(&self) -> i32 {
        *self.id_by_token.get(SEP).unwrap_or(&3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookups_and_specials() {
        let v = Vocab::from_lines(
            ["[PAD]", "[UNK]", "[CLS]", "[SEP]", "x"].iter().map(|s| s.to_string()));
        assert_eq!(v.len(), 5);
        assert_eq!(v.id_of("x"), 4);
        assert_eq!(v.id_of("missing"), v.unk_id());
        assert_eq!(v.lookup("missing"), None);
        assert_eq!(v.token_of(4), Some("x"));
        assert_eq!(v.pad_id(), 0);
        assert_eq!(v.cls_id(), 2);
        assert_eq!(v.sep_id(), 3);
    }

    #[test]
    fn strips_line_endings() {
        let v = Vocab::from_lines(["a\r\n".to_string(), "b\n".to_string()]);
        assert_eq!(v.id_of("a"), 0);
        assert_eq!(v.id_of("b"), 1);
    }
}
