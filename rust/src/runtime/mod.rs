//! Execution runtime: the [`Backend`] trait, the PJRT engine cache, and the
//! native-model cache.
//!
//! [`Backend`] is the seam between the coordinator and the compute: a
//! pipeline holds `Arc<dyn Backend>` halves (encoder + head) and does not
//! know whether they are PJRT executables or in-tree native kernels.
//!
//! * **PJRT** — [`Engine`] wraps the `xla` crate (xla_extension 0.5.1, PJRT
//!   C API): `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//!   `client.compile` -> `execute`.  Artifacts are self-contained HLO with
//!   weights and calibration scales baked in as constants; Python never
//!   runs on the request path.
//! * **native** — [`crate::backend::native`]: blocked INT8 / f32 Rust
//!   kernels driven by a per-layer precision plan.  Selected by
//!   `coordinator::pipeline` whenever a variant's HLO artifact is absent.
//!
//! One [`Engine`] per loaded artifact; the [`Runtime`] owns the client, a
//! cache of compiled engines keyed by artifact path, and a cache of native
//! models keyed by task (all precision variants of a task share weights).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use crate::backend::native::{GemmPool, NativeModel};

/// A compute backend able to run encoder and/or head bundles.
///
/// Both methods return flat row-major f32 tensors; a backend that only
/// implements one half errors cleanly on the other (the PJRT `Engine` is
/// whatever its artifact was lowered as, the native backend splits the two
/// halves into separate adapter types).
pub trait Backend: Send + Sync {
    /// "pjrt" or "native" — surfaced in diagnostics.
    fn backend_name(&self) -> &'static str;

    /// Encoder bundle: (ids, segs, mask) -> hidden `[B, S, H]`.
    fn run_encoder(&self, b: &EncoderBatch) -> Result<Vec<f32>>;

    /// Head bundle: hidden `[B, S, H]` -> logits.
    fn run_head(&self, hidden: &[f32], batch: usize, seq: usize,
                hidden_dim: usize) -> Result<Vec<f32>>;

    /// True when this backend can no longer produce trustworthy output and
    /// its owner should rebuild it (native: a poisoned GEMM pool).  The
    /// default is healthy-forever; only backends with fallible internal
    /// state override it.
    fn is_poisoned(&self) -> bool {
        false
    }
}

/// Engine input batch: ids/segments/mask with a [batch, seq] shape.
///
/// Blocks are pooled across batches (`coordinator::pool::BlockPool`), so a
/// block may carry stale rows from its previous use.  `set_row` tracks the
/// written high-water mark and [`EncoderBatch::reset_rows`] scrubs only the
/// dirty tail instead of re-zeroing the whole tensor — the steady-state cost
/// of forming a batch is proportional to the rows actually written, not to
/// the static shape.
///
/// The shape is *static per engine call*, not per block lifetime: the
/// continuous batcher reinterprets a pooled block's storage as a different
/// `[rows, bucket_seq]` geometry via [`EncoderBatch::reshape`] (the native
/// backend accepts any shape; PJRT lanes keep the fixed shape their HLO was
/// lowered with).
#[derive(Debug, Clone, PartialEq)]
pub struct EncoderBatch {
    pub batch: usize,
    pub seq: usize,
    pub ids: Vec<i32>,
    pub segment_ids: Vec<i32>,
    /// 1.0 keep / 0.0 pad (f32 — matches the lowered signature).
    pub attention_mask: Vec<f32>,
    /// High-water mark of rows written since the last `reset_rows`.
    rows: usize,
}

impl EncoderBatch {
    pub fn zeros(batch: usize, seq: usize) -> EncoderBatch {
        EncoderBatch {
            batch,
            seq,
            ids: vec![0; batch * seq],
            segment_ids: vec![0; batch * seq],
            attention_mask: vec![0.0; batch * seq],
            rows: 0,
        }
    }

    /// Copy one encoded request into row `row`.  All three slices must be
    /// exactly `seq` long: blocks are pooled, so a full overwrite of the row
    /// is what keeps the previous batch's values from leaking through.
    pub fn set_row(&mut self, row: usize, ids: &[i32], segs: &[i32], mask: &[i32]) {
        assert!(row < self.batch
                && ids.len() == self.seq
                && segs.len() == self.seq
                && mask.len() == self.seq);
        let o = row * self.seq;
        self.ids[o..o + self.seq].copy_from_slice(ids);
        self.segment_ids[o..o + self.seq].copy_from_slice(segs);
        // i32 -> f32 mask conversion as a straight-line copy over two
        // equal-length slices: no per-element bounds checks, so the loop
        // autovectorizes (was an indexed `mask[o + i]` loop).
        let dst = &mut self.attention_mask[o..o + self.seq];
        for (d, &m) in dst.iter_mut().zip(mask.iter()) {
            *d = m as f32;
        }
        self.rows = self.rows.max(row + 1);
    }

    /// Fast path for full-length rows (every position a real token): the
    /// mask row is the constant 1.0, so skip the conversion loop entirely.
    /// The batcher uses this whenever an encoding's mask has no padding.
    pub fn set_row_unmasked(&mut self, row: usize, ids: &[i32], segs: &[i32]) {
        assert!(row < self.batch
                && ids.len() == self.seq
                && segs.len() == self.seq);
        let o = row * self.seq;
        self.ids[o..o + self.seq].copy_from_slice(ids);
        self.segment_ids[o..o + self.seq].copy_from_slice(segs);
        self.attention_mask[o..o + self.seq].fill(1.0);
        self.rows = self.rows.max(row + 1);
    }

    /// Number of rows written since the last reset.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Reinterpret this block's storage as a `[batch, seq]` tensor (the
    /// continuous batcher's variable-shape reuse path).  Contents become
    /// stale in the new geometry, so every row is marked dirty: callers
    /// follow the pooled-block contract (`set_row` the rows they use, then
    /// `reset_rows(n)`).  Growing within the original allocation does not
    /// reallocate; `Vec::resize` only touches the length.
    pub fn reshape(&mut self, batch: usize, seq: usize) {
        if batch == self.batch && seq == self.seq {
            return;
        }
        let cells = batch * seq;
        self.ids.resize(cells, 0);
        self.segment_ids.resize(cells, 0);
        self.attention_mask.resize(cells, 0.0);
        self.batch = batch;
        self.seq = seq;
        // old rows may alias arbitrary new rows: treat the whole block as
        // dirty so reset_rows scrubs everything the caller does not write
        self.rows = batch;
    }

    /// Keep rows `[0, keep)` and zero any stale rows `[keep, rows)` left over
    /// from a previous use of this (pooled) block.  Padding rows end up
    /// all-zero with a fully-masked attention row, exactly as `zeros` would
    /// produce, but without touching already-clean memory.
    pub fn reset_rows(&mut self, keep: usize) {
        let keep = keep.min(self.batch);
        let lo = keep * self.seq;
        let hi = self.rows.min(self.batch) * self.seq;
        if hi > lo {
            self.ids[lo..hi].fill(0);
            self.segment_ids[lo..hi].fill(0);
            self.attention_mask[lo..hi].fill(0.0);
        }
        self.rows = keep;
    }
}

/// A compiled executable + its I/O geometry.
pub struct Engine {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Engine {
    /// Execute the encoder bundle: (ids, segs, mask) -> hidden [B, S, H].
    pub fn run_encoder(&self, b: &EncoderBatch) -> Result<Vec<f32>> {
        let ids = xla::Literal::vec1(&b.ids)
            .reshape(&[b.batch as i64, b.seq as i64])?;
        let segs = xla::Literal::vec1(&b.segment_ids)
            .reshape(&[b.batch as i64, b.seq as i64])?;
        let mask = xla::Literal::vec1(&b.attention_mask)
            .reshape(&[b.batch as i64, b.seq as i64])?;
        let out = self.exe.execute::<xla::Literal>(&[ids, segs, mask])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let tuple = out.to_tuple1()?;
        Ok(tuple.to_vec::<f32>()?)
    }

    /// Execute the head: hidden [B, S, H] -> logits.
    pub fn run_head(&self, hidden: &[f32], batch: usize, seq: usize,
                    hidden_dim: usize) -> Result<Vec<f32>> {
        let lit = xla::Literal::vec1(hidden)
            .reshape(&[batch as i64, seq as i64, hidden_dim as i64])?;
        let out = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()?;
        let tuple = out.to_tuple1()?;
        Ok(tuple.to_vec::<f32>()?)
    }

    /// Raw execute for generic artifacts (benches / tools).
    pub fn run_raw(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let out = self.exe.execute::<xla::Literal>(inputs)?[0][0]
            .to_literal_sync()?;
        Ok(out)
    }
}

impl Backend for Engine {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn run_encoder(&self, b: &EncoderBatch) -> Result<Vec<f32>> {
        Engine::run_encoder(self, b)
    }

    fn run_head(&self, hidden: &[f32], batch: usize, seq: usize,
                hidden_dim: usize) -> Result<Vec<f32>> {
        Engine::run_head(self, hidden, batch, seq, hidden_dim)
    }
}

/// Kernel execution policy for native models built through this runtime:
/// GEMM parallelism and the per-replica core sets.  Installed once by the
/// deployment (from `--gemm-threads` / `--pin-cores`) *before* any pipeline
/// loads, so every cached [`NativeModel`] is born with its pool.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelConfig {
    /// Per-GEMM parallelism (caller thread included); 1 = no worker pool.
    pub gemm_threads: usize,
    /// One core set per `--pin-cores` flag; replica `r` draws
    /// `pin_cores[r % len]`.  Empty = leave threads unpinned.
    pub pin_cores: Vec<Vec<usize>>,
}

impl Default for KernelConfig {
    fn default() -> KernelConfig {
        KernelConfig { gemm_threads: 1, pin_cores: Vec::new() }
    }
}

/// Owns the PJRT client and the engine cache.
///
/// The cache is read on every request (the serving hot path resolves
/// engines through it), so lookups take a `RwLock` read lock only; the
/// write lock is taken on compile misses, with a double-checked insert so
/// concurrent loaders of the same artifact still share one `Engine`.
pub struct Runtime {
    client: xla::PjRtClient,
    engines: RwLock<HashMap<PathBuf, Arc<Engine>>>,
    natives: RwLock<HashMap<String, Arc<NativeModel>>>,
    kernel: RwLock<KernelConfig>,
}

impl Runtime {
    /// Create a CPU PJRT runtime (the only backend in this environment; a
    /// TPU/GPU PJRT plugin would slot in here unchanged).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            engines: RwLock::new(HashMap::new()),
            natives: RwLock::new(HashMap::new()),
            kernel: RwLock::new(KernelConfig::default()),
        })
    }

    /// Install the kernel policy.  Must run before the first
    /// [`native_model_for_replica`] call — models already cached keep the
    /// pool they were built with.
    ///
    /// [`native_model_for_replica`]: Runtime::native_model_for_replica
    pub fn set_kernel_config(&self, cfg: KernelConfig) {
        *self.kernel.write().unwrap() = cfg;
    }

    /// The installed per-GEMM parallelism.
    pub fn gemm_threads(&self) -> usize {
        self.kernel.read().unwrap().gemm_threads
    }

    /// The core set replica `replica` should pin to (empty = unpinned).
    /// Replicas beyond the configured sets wrap around, so two replicas
    /// share a set only when the operator gave fewer sets than replicas.
    pub fn replica_cores(&self, replica: usize) -> Vec<usize> {
        let cfg = self.kernel.read().unwrap();
        if cfg.pin_cores.is_empty() {
            Vec::new()
        } else {
            cfg.pin_cores[replica % cfg.pin_cores.len()].clone()
        }
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    ///
    /// Steady state takes only the read lock.  On a miss the parse+compile
    /// runs outside any lock (it can take seconds); two threads racing on
    /// the same cold path may both compile, but the double-checked insert
    /// guarantees they end up sharing a single cached `Engine`.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<Engine>> {
        let path = path.as_ref().to_path_buf();
        if let Some(e) = self.engines.read().unwrap().get(&path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile {}", path.display()))?;
        let engine = Arc::new(Engine { exe, path: path.clone() });
        let mut engines = self.engines.write().unwrap();
        Ok(engines.entry(path).or_insert(engine).clone())
    }

    /// Get or build the native weights bundle for `key` (one per task —
    /// every precision variant of a task shares the same weights; only the
    /// per-layer plan differs).  Same double-checked pattern as [`load`]:
    /// `build` runs outside any lock, the first insert wins.
    ///
    /// [`load`]: Runtime::load
    pub fn native_model<F>(&self, key: &str, build: F) -> Result<Arc<NativeModel>>
    where
        F: FnOnce() -> Result<NativeModel>,
    {
        self.native_model_for_replica(key, 0, build)
    }

    /// [`native_model`] for a specific replica index: a cache miss builds
    /// the model, then attaches a [`GemmPool`] sized by the installed
    /// [`KernelConfig`] and pinned to this replica's core set.  Replicas use
    /// distinct cache keys (`task#rN`), so each gets its own pool while all
    /// precision variants of one replica share a model.
    ///
    /// [`native_model`]: Runtime::native_model
    pub fn native_model_for_replica<F>(&self, key: &str, replica: usize,
                                       build: F) -> Result<Arc<NativeModel>>
    where
        F: FnOnce() -> Result<NativeModel>,
    {
        if let Some(m) = self.natives.read().unwrap().get(key) {
            return Ok(m.clone());
        }
        let mut model = build()?;
        let threads = self.gemm_threads();
        if threads > 1 {
            let cores = self.replica_cores(replica);
            model.set_gemm_pool(Some(Arc::new(GemmPool::new(threads,
                                                            &cores))));
        }
        let model = Arc::new(model);
        let mut natives = self.natives.write().unwrap();
        Ok(natives.entry(key.to_string()).or_insert(model).clone())
    }

    /// Number of native models currently cached.
    pub fn native_count(&self) -> usize {
        self.natives.read().unwrap().len()
    }

    /// Number of compiled engines currently cached.
    pub fn loaded_count(&self) -> usize {
        self.engines.read().unwrap().len()
    }

    /// Drop a cached engine (memory management for large sweeps).
    pub fn evict(&self, path: impl AsRef<Path>) {
        self.engines.write().unwrap().remove(path.as_ref());
    }

    /// Drop a cached native model — the self-healing path: evicting a
    /// poisoned replica's key forces the next
    /// [`native_model_for_replica`](Runtime::native_model_for_replica) to
    /// rebuild the model (and its GEMM pool) from scratch.
    pub fn evict_native(&self, key: &str) {
        self.natives.write().unwrap().remove(key);
    }
}

// The PJRT client/executable handles are internally synchronized; the xla
// crate just doesn't mark them Send/Sync.  The coordinator shares Runtime
// behind Arc across worker threads.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

// Compile-time guarantee for the sharded dispatch path: every Backend handle
// a lane's N workers share via `Arc<dyn Backend>` must be callable
// concurrently.  `Backend: Send + Sync` plus `&self` methods make each
// implementation's interior state responsible for its own synchronization
// (the native backend pools per-call scratch; PJRT is internally locked).
const _: () = {
    const fn assert_shareable<T: ?Sized + Send + Sync>() {}
    assert_shareable::<dyn Backend>();
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoder_batch_set_row() {
        let mut b = EncoderBatch::zeros(2, 4);
        b.set_row(1, &[5, 6, 7, 8], &[0, 0, 1, 1], &[1, 1, 1, 0]);
        assert_eq!(&b.ids[4..], &[5, 6, 7, 8]);
        assert_eq!(&b.segment_ids[4..], &[0, 0, 1, 1]);
        assert_eq!(&b.attention_mask[4..], &[1.0, 1.0, 1.0, 0.0]);
        // row 0 untouched
        assert!(b.ids[..4].iter().all(|&x| x == 0));
    }

    #[test]
    fn set_row_unmasked_equals_all_ones_mask() {
        let mut a = EncoderBatch::zeros(2, 4);
        let mut b = EncoderBatch::zeros(2, 4);
        a.set_row(1, &[5, 6, 7, 8], &[0, 0, 1, 1], &[1, 1, 1, 1]);
        b.set_row_unmasked(1, &[5, 6, 7, 8], &[0, 0, 1, 1]);
        assert_eq!(a, b);
        assert_eq!(b.rows(), 2);
    }

    #[test]
    #[should_panic]
    fn set_row_rejects_bad_len() {
        let mut b = EncoderBatch::zeros(1, 4);
        b.set_row(0, &[1, 2], &[0, 0], &[1, 1]);
    }

    #[test]
    fn reset_rows_scrubs_only_the_stale_tail() {
        let mut b = EncoderBatch::zeros(3, 2);
        for row in 0..3 {
            b.set_row(row, &[9, 9], &[1, 1], &[1, 1]);
        }
        assert_eq!(b.rows(), 3);
        // reuse for a 1-row batch: rows 1..3 must come back all-zero/masked
        b.set_row(0, &[5, 6], &[0, 0], &[1, 0]);
        b.reset_rows(1);
        assert_eq!(b.rows(), 1);
        assert_eq!(&b.ids[..2], &[5, 6]);
        assert!(b.ids[2..].iter().all(|&x| x == 0));
        assert!(b.segment_ids[2..].iter().all(|&x| x == 0));
        assert!(b.attention_mask[2..].iter().all(|&m| m == 0.0));
        // and the scrubbed block equals a freshly zeroed one with the row set
        let mut fresh = EncoderBatch::zeros(3, 2);
        fresh.set_row(0, &[5, 6], &[0, 0], &[1, 0]);
        assert_eq!(b, fresh);
    }

    #[test]
    fn reshape_marks_all_rows_dirty_and_scrubs_clean() {
        // taint a [4, 8] block, reshape to [8, 4] (same cells, different
        // geometry): after the caller writes 2 rows and scrubs, the block
        // must equal a fresh one — nothing of the old geometry survives
        let mut b = EncoderBatch::zeros(4, 8);
        for row in 0..4 {
            b.set_row_unmasked(row, &[9; 8], &[1; 8]);
        }
        b.reshape(8, 4);
        assert_eq!((b.batch, b.seq), (8, 4));
        assert_eq!(b.rows(), 8, "reshape must mark every row dirty");
        b.set_row(0, &[1, 2, 3, 4], &[0; 4], &[1, 1, 1, 1]);
        b.set_row(1, &[5, 6, 7, 8], &[0; 4], &[1, 1, 0, 0]);
        b.reset_rows(2);
        let mut fresh = EncoderBatch::zeros(8, 4);
        fresh.set_row(0, &[1, 2, 3, 4], &[0; 4], &[1, 1, 1, 1]);
        fresh.set_row(1, &[5, 6, 7, 8], &[0; 4], &[1, 1, 0, 0]);
        assert_eq!(b, fresh, "stale cells leaked through reshape");
        // shrink, then grow back within the original allocation
        b.reshape(2, 4);
        assert_eq!(b.ids.len(), 8);
        b.reshape(4, 8);
        assert_eq!(b.ids.len(), 32);
        b.reset_rows(0);
        assert_eq!(b, EncoderBatch::zeros(4, 8));
    }

    #[test]
    fn reshape_same_shape_preserves_row_tracking() {
        let mut b = EncoderBatch::zeros(4, 2);
        b.set_row(0, &[1, 1], &[0, 0], &[1, 1]);
        b.reshape(4, 2);
        assert_eq!(b.rows(), 1, "no-op reshape must keep the high-water mark");
    }

    #[test]
    fn reset_rows_is_noop_on_clean_block() {
        let mut b = EncoderBatch::zeros(2, 4);
        b.reset_rows(0);
        assert_eq!(b, EncoderBatch::zeros(2, 4));
    }
}
