//! Dataset readers: the SAMP binary dev-set format (pre-tokenized ids, exact
//! parity with the python generator) and the JSONL text format (end-to-end
//! path through the Rust tokenizer).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// A pre-tokenized evaluation set (written by compile/aot.py).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub seq: usize,
    /// labels are per-token (NER) or per-example
    pub per_token: bool,
    pub ids: Vec<i32>,
    pub segs: Vec<i32>,
    pub mask: Vec<i32>,
    pub labels: Vec<i32>,
}

impl Dataset {
    /// Read the `SAMPDAT1` binary format.
    pub fn load_bin(path: impl AsRef<Path>) -> Result<Dataset> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading dataset {}", path.display()))?;
        if bytes.len() < 20 || &bytes[..8] != b"SAMPDAT1" {
            bail!("{}: bad magic", path.display());
        }
        let n = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let seq = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let per_token = bytes[16] != 0;
        let mut off = 20;
        let mut read_i32 = |count: usize| -> Result<Vec<i32>> {
            let need = count * 4;
            if off + need > bytes.len() {
                bail!("{}: truncated (need {} at {})", path.display(), need, off);
            }
            let v = bytes[off..off + need]
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            off += need;
            Ok(v)
        };
        let ids = read_i32(n * seq)?;
        let segs = read_i32(n * seq)?;
        let mask = read_i32(n * seq)?;
        let labels = read_i32(if per_token { n * seq } else { n })?;
        Ok(Dataset { n, seq, per_token, ids, segs, mask, labels })
    }

    /// Row accessors.
    pub fn row_ids(&self, i: usize) -> &[i32] {
        &self.ids[i * self.seq..(i + 1) * self.seq]
    }

    pub fn row_segs(&self, i: usize) -> &[i32] {
        &self.segs[i * self.seq..(i + 1) * self.seq]
    }

    pub fn row_mask(&self, i: usize) -> &[i32] {
        &self.mask[i * self.seq..(i + 1) * self.seq]
    }

    pub fn label(&self, i: usize) -> i32 {
        assert!(!self.per_token);
        self.labels[i]
    }

    pub fn row_labels(&self, i: usize) -> &[i32] {
        assert!(self.per_token);
        &self.labels[i * self.seq..(i + 1) * self.seq]
    }
}

/// One text example from the JSONL rendering.
#[derive(Debug, Clone, PartialEq)]
pub struct TextExample {
    pub text: String,
    /// classification label, or first label for NER rows
    pub label: i64,
}

/// Load `{"text": ..., "label": ...}` lines.
pub fn load_jsonl(path: impl AsRef<Path>) -> Result<Vec<TextExample>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading jsonl {}", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .with_context(|| format!("{}:{}", path.display(), ln + 1))?;
        let label = match j.get("label") {
            Json::Num(n) => *n as i64,
            Json::Arr(a) => a.first().and_then(|x| x.as_i64()).unwrap_or(0),
            _ => 0,
        };
        out.push(TextExample {
            text: j.get("text").as_str().unwrap_or("").to_string(),
            label,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_bin(n: u32, seq: u32, per_token: bool) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "samp_ds_test_{}_{}_{}", n, seq, per_token));
        let _ = std::fs::create_dir_all(&dir);
        let p = dir.join("d.bin");
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(b"SAMPDAT1").unwrap();
        f.write_all(&n.to_le_bytes()).unwrap();
        f.write_all(&seq.to_le_bytes()).unwrap();
        f.write_all(&[per_token as u8, 0, 0, 0]).unwrap();
        let cells = (n * seq) as usize;
        for arr in 0..3 {
            for i in 0..cells {
                f.write_all(&((arr * 1000 + i) as i32).to_le_bytes()).unwrap();
            }
        }
        let labels = if per_token { cells } else { n as usize };
        for i in 0..labels {
            f.write_all(&(i as i32).to_le_bytes()).unwrap();
        }
        p
    }

    #[test]
    fn reads_binary_format() {
        let p = write_bin(3, 4, false);
        let d = Dataset::load_bin(&p).unwrap();
        assert_eq!((d.n, d.seq, d.per_token), (3, 4, false));
        assert_eq!(d.row_ids(1), &[4, 5, 6, 7]);
        assert_eq!(d.row_segs(0), &[1000, 1001, 1002, 1003]);
        assert_eq!(d.label(2), 2);
    }

    #[test]
    fn reads_per_token_labels() {
        let p = write_bin(2, 3, true);
        let d = Dataset::load_bin(&p).unwrap();
        assert_eq!(d.row_labels(1), &[3, 4, 5]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir();
        let p = dir.join("samp_bad_magic.bin");
        std::fs::write(&p, b"NOTSAMP!aaaaaaaaaaaaaaaa").unwrap();
        assert!(Dataset::load_bin(&p).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let good = write_bin(3, 4, false);
        let bytes = std::fs::read(&good).unwrap();
        let p = std::env::temp_dir().join("samp_trunc.bin");
        std::fs::write(&p, &bytes[..bytes.len() - 8]).unwrap();
        assert!(Dataset::load_bin(&p).is_err());
    }

    #[test]
    fn jsonl_parsing() {
        let p = std::env::temp_dir().join("samp_test.jsonl");
        std::fs::write(&p,
            "{\"text\": \"hello\\tworld\", \"label\": 3}\n\n{\"text\": \"x\", \"label\": [1,2]}\n")
            .unwrap();
        let rows = load_jsonl(&p).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].text, "hello\tworld");
        assert_eq!(rows[0].label, 3);
        assert_eq!(rows[1].label, 1);
    }
}
