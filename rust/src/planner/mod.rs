//! Self-adaptive precision planner: calibration-driven plan search.
//!
//! PR #2 made the execution stack able to run *any* per-layer precision plan
//! (`VariantSpec::plan()` -> native backend), but every plan was still
//! hand-written in the manifest.  This subsystem closes the *Self-Adaptive*
//! half of SAMP: it decides the plan from data.
//!
//! ```text
//!   calibration set        sensitivity pass            search
//!  (JSONL texts or   ->  f32 reference vs per-   ->  greedy ascent in
//!   synthetic ids)       layer INT8: logit MSE,      sensitivity order
//!                        flip rate, act. scales      (+ swap refinement)
//!                                                          |
//!        manifest.json  <-  persist plan + scales  <-  frontier + choice
//! ```
//!
//! * The calibration set ([`CalibrationSet`]) is either a JSONL text file
//!   (`{"text": ...}` rows, e.g. the dev set or
//!   `python/compile/export_calib.py` output) run through the real
//!   tokenizer, or a deterministic synthetic batch when no data ships with
//!   the checkout.
//! * Sensitivity + scales come from [`sensitivity`]: real native-backend
//!   forwards, logit-level damage metrics, max-abs/percentile activation
//!   scales recorded at every [`Tap`](crate::backend::native::Tap).
//! * The search ([`search`]) walks the accuracy/latency frontier under an
//!   accuracy budget or a latency target (T4 cost model via
//!   `latency::samp_plan_latency_ms`).
//! * The winner persists through `config::upsert_planned_variant` into the
//!   ordinary manifest format — `Router`, `VariantSpec::plan()` and the
//!   serving path consume it with no special cases, and the calibrated
//!   scales turn the native INT8 path's activation quantization static.
//!
//! Entry points: `samp plan` (CLI), [`run_plan`] (library),
//! `GET /v1/plan` (serving introspection).

pub mod search;
pub mod sensitivity;

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::backend::native::{NativeModel, Tap};
use crate::config::{self, Manifest, ModelSpec};
use crate::latency::{CpuCostModel, LayerMode};
use crate::runtime::EncoderBatch;
use crate::tokenizer::{BertTokenizer, Vocab};
use crate::util::json::Json;
use crate::util::prng::Prng;

pub use search::{choose, greedy_frontier, refine_swaps, CostCtx,
                 FrontierPoint, Objective};
pub use sensitivity::{ascending_order, calibrate_reference, eval_plan,
                      measure_sensitivity, Calibrator, LayerSensitivity};

/// A tokenized calibration set, pre-formed into engine-shaped blocks.
#[derive(Debug, Clone)]
pub struct CalibrationSet {
    pub blocks: Vec<EncoderBatch>,
    /// Where the texts came from (diagnostics / report).
    pub source: String,
}

impl CalibrationSet {
    /// Total real (non-padding) rows across all blocks.
    pub fn rows(&self) -> usize {
        self.blocks.iter().map(|b| b.rows()).sum()
    }

    /// Tokenize request texts into `[batch, seq]` blocks (the last block may
    /// be part-filled; evaluation only reads the written rows).
    pub fn from_texts<S: AsRef<str>>(texts: &[S], tokenizer: &BertTokenizer,
                                     batch: usize, seq: usize, source: String)
                                     -> Result<CalibrationSet> {
        ensure!(!texts.is_empty(), "calibration set is empty ({source})");
        let mut blocks = Vec::with_capacity(texts.len().div_ceil(batch));
        for chunk in texts.chunks(batch) {
            let mut block = EncoderBatch::zeros(batch, seq);
            for (r, text) in chunk.iter().enumerate() {
                let enc = tokenizer.encode_request_lean(text.as_ref(), seq);
                block.set_row(r, &enc.ids, &enc.segment_ids,
                              &enc.attention_mask);
            }
            blocks.push(block);
        }
        Ok(CalibrationSet { blocks, source })
    }

    /// Deterministic synthetic fallback: random token ids at varied lengths
    /// (seeded, so every run of `samp plan` sees the same set).
    pub fn synthetic(vocab_size: usize, batch: usize, seq: usize,
                     examples: usize, seed: u64) -> CalibrationSet {
        let vocab = vocab_size.max(8) as u64;
        let examples = examples.max(1);
        let mut p = Prng::new(seed);
        let mut blocks = Vec::with_capacity(examples.div_ceil(batch));
        let mut remaining = examples;
        while remaining > 0 {
            let rows = remaining.min(batch);
            let mut block = EncoderBatch::zeros(batch, seq);
            for r in 0..rows {
                let len = p.range(2, seq.max(2));
                let ids: Vec<i32> = (0..seq)
                    .map(|t| if t < len { p.below(vocab) as i32 } else { 0 })
                    .collect();
                let segs = vec![0i32; seq];
                let mask: Vec<i32> = (0..seq)
                    .map(|t| i32::from(t < len))
                    .collect();
                block.set_row(r, &ids, &segs, &mask);
            }
            blocks.push(block);
            remaining -= rows;
        }
        CalibrationSet { blocks, source: "synthetic".to_string() }
    }
}

/// Everything `samp plan` can be told (defaults match the CLI defaults).
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    pub task: String,
    /// INT8 mode candidate layers switch into.
    pub mode: LayerMode,
    pub objective: Objective,
    /// Explicit calibration JSONL; `None` falls back to the task's
    /// `dev_jsonl` if present, then to the synthetic set.
    pub calib_jsonl: Option<PathBuf>,
    /// Cap on calibration examples (synthetic size / JSONL truncation).
    pub calib_examples: usize,
    pub calibrator: Calibrator,
    /// Run the swap-refinement pass on the chosen plan.
    pub refine: bool,
    /// Name the winning variant persists under.
    pub variant_name: String,
    /// Measure + report only; do not touch the manifest.
    pub dry_run: bool,
    pub seed: u64,
    /// GEMM threads assumed by the native-CPU latency column on every
    /// frontier point (0 = auto, same resolution as `samp serve`).
    pub gemm_threads: usize,
    /// Calibrate the native-CPU cost model from this `BENCH_SERVING.json`
    /// (`--cost-model-from`; the CLI defaults it to `./BENCH_SERVING.json`
    /// when that file exists).  `None`, a file without a usable `"gemm"`
    /// section, or an unreadable path fall back to the built-in constants.
    pub cost_model_from: Option<PathBuf>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            task: String::new(),
            mode: LayerMode::Int8Full,
            objective: Objective::AccuracyBudget(1e-2),
            calib_jsonl: None,
            calib_examples: 64,
            calibrator: Calibrator::MaxAbs,
            refine: false,
            variant_name: "auto".to_string(),
            dry_run: false,
            seed: 0x5A3B,
            gemm_threads: 0,
            cost_model_from: None,
        }
    }
}

/// The planner's full output (what `samp plan` prints and serializes).
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub task: String,
    pub variant: String,
    pub mode: LayerMode,
    pub objective: Objective,
    pub calib_source: String,
    pub calib_rows: usize,
    pub sensitivity: Vec<LayerSensitivity>,
    pub frontier: Vec<FrontierPoint>,
    /// Greedy frontier step the objective selected.  `chosen` starts as
    /// `frontier[chosen_index]`; with `refine` it may hold an improved
    /// same-count plan instead (then [`PlanReport::refined`] is true), so
    /// `chosen` — not this index — is what gets persisted.
    pub chosen_index: usize,
    pub chosen: FrontierPoint,
    /// True when swap refinement replaced the greedy pick's layer set.
    pub refined: bool,
    pub feasible: bool,
    /// Manifest path the plan was persisted to (None on --dry-run).
    pub persisted: Option<PathBuf>,
}

impl PlanReport {
    pub fn to_json(&self) -> Json {
        let obj = match self.objective {
            Objective::AccuracyBudget(e) => {
                Json::obj(vec![("accuracy_budget_mse", Json::num(e))])
            }
            Objective::LatencyTargetMs(t) => {
                Json::obj(vec![("latency_target_ms", Json::num(t))])
            }
        };
        Json::obj(vec![
            ("report", Json::str("samp_plan")),
            ("task", Json::str(self.task.clone())),
            ("variant", Json::str(self.variant.clone())),
            ("mode", Json::str(self.mode.as_str())),
            ("objective", obj),
            ("feasible", Json::Bool(self.feasible)),
            ("calib_source", Json::str(self.calib_source.clone())),
            ("calib_rows", Json::num(self.calib_rows as f64)),
            ("sensitivity", Json::arr(self.sensitivity.iter().map(|s| {
                Json::obj(vec![
                    ("layer", Json::num(s.layer as f64)),
                    ("logit_mse", Json::num(s.logit_mse)),
                    ("top1_flip_rate", Json::num(s.top1_flip_rate)),
                ])
            }))),
            ("frontier",
             Json::arr(self.frontier.iter().map(|p| p.to_json()))),
            ("chosen_index", Json::num(self.chosen_index as f64)),
            ("chosen", self.chosen.to_json()),
            ("refined", Json::Bool(self.refined)),
            ("persisted", match &self.persisted {
                Some(p) => Json::str(p.display().to_string()),
                None => Json::Null,
            }),
        ])
    }
}

/// Run the whole pipeline: calibrate, rank, search, persist.  This is the
/// body of `samp plan`; tests call it directly.
pub fn run_plan(artifacts_dir: impl AsRef<Path>, cfg: &PlannerConfig)
                -> Result<PlanReport> {
    let artifacts_dir = artifacts_dir.as_ref();
    ensure!(cfg.mode.is_int8(),
            "--mode must be an INT8 mode, got {}", cfg.mode.as_str());
    let manifest = Manifest::load(artifacts_dir)?;
    let spec = manifest.model(&cfg.task)?.clone();

    let calib = build_calibration_set(&manifest, &spec, cfg)?;
    // the planner always measures from a clean slate: fresh scales are about
    // to be calibrated, so any previously-persisted ones must not interfere
    let weights_path = spec.weights.as_ref().map(|w| manifest.path(w));
    let mut model = NativeModel::for_spec_uncalibrated(
        &spec, weights_path.as_deref(), manifest.vocab_size)?;

    let (ref_logits, scales) =
        calibrate_reference(&model, &spec, &calib, cfg.calibrator)?;
    // search with the static scales installed, so the measured error is
    // exactly what serving will produce from the persisted manifest
    model.set_static_scales(scales.clone())?;

    let sens = measure_sensitivity(&model, &spec, &calib, &ref_logits,
                                   cfg.mode)?;
    let order = ascending_order(&sens);
    let threads = if cfg.gemm_threads > 0 {
        cfg.gemm_threads
    } else {
        config::auto_threads()
    };
    let cost = CostCtx { model: load_cost_model(cfg), threads };
    let frontier = greedy_frontier(&model, &spec, &calib, &ref_logits, &order,
                                   cfg.mode, cost)?;
    let (chosen_index, feasible) = choose(&frontier, cfg.objective);
    let mut chosen = frontier[chosen_index].clone();
    if cfg.refine {
        chosen = refine_swaps(&model, &spec, &calib, &ref_logits, &chosen,
                              cfg.mode, cost)?;
    }
    let refined = chosen.layers != frontier[chosen_index].layers;

    let persisted = if cfg.dry_run {
        None
    } else {
        let mut scale_map = std::collections::BTreeMap::new();
        for (l, ls) in scales.iter().enumerate() {
            for tap in Tap::ALL {
                if let Some(s) = ls.get(tap) {
                    scale_map.insert(tap.key(l), s as f64);
                }
            }
        }
        Some(config::upsert_planned_variant(artifacts_dir, &cfg.task,
                                            &cfg.variant_name, &chosen.plan,
                                            &scale_map)?)
    };

    Ok(PlanReport {
        task: cfg.task.clone(),
        variant: cfg.variant_name.clone(),
        mode: cfg.mode,
        objective: cfg.objective,
        calib_source: calib.source.clone(),
        calib_rows: calib.rows(),
        sensitivity: sens,
        frontier,
        chosen_index,
        chosen,
        refined,
        feasible,
        persisted,
    })
}

/// Resolve the native-CPU cost model `run_plan` prices frontier points
/// with: constants calibrated from the measured GEMM throughputs in
/// `cfg.cost_model_from` when that file parses, the built-in defaults
/// otherwise.  Degrades loudly but gracefully — a missing or malformed
/// file is a note on stderr, never a failed plan.
fn load_cost_model(cfg: &PlannerConfig) -> CpuCostModel {
    let Some(path) = &cfg.cost_model_from else {
        return CpuCostModel::default();
    };
    let calibrated = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|json| CpuCostModel::from_bench_json(&json));
    match calibrated {
        Some(model) => {
            eprintln!("[plan] cost model calibrated from {}", path.display());
            model
        }
        None => {
            eprintln!("[plan] {} has no usable gemm benchmark section; \
                       using the built-in cost model", path.display());
            CpuCostModel::default()
        }
    }
}

fn build_calibration_set(manifest: &Manifest, spec: &ModelSpec,
                         cfg: &PlannerConfig) -> Result<CalibrationSet> {
    let jsonl: Option<PathBuf> = match &cfg.calib_jsonl {
        Some(p) => Some(p.clone()),
        None if !spec.dev_jsonl.is_empty() => {
            let p = manifest.path(&spec.dev_jsonl);
            p.exists().then_some(p)
        }
        None => None,
    };
    match jsonl {
        Some(path) => {
            let mut texts: Vec<String> = crate::data::load_jsonl(&path)?
                .into_iter()
                .map(|e| e.text)
                .filter(|t| !t.is_empty())
                .collect();
            if texts.is_empty() {
                bail!("calibration file {} has no usable texts",
                      path.display());
            }
            texts.truncate(cfg.calib_examples.max(1));
            let vocab = Vocab::load(manifest.path(&manifest.vocab))?;
            let tokenizer = BertTokenizer::new(vocab);
            CalibrationSet::from_texts(&texts, &tokenizer, spec.batch,
                                       spec.seq_len,
                                       format!("jsonl:{}", path.display()))
        }
        None => Ok(CalibrationSet::synthetic(
            if manifest.vocab_size > 0 { manifest.vocab_size } else { 4096 },
            spec.batch, spec.seq_len, cfg.calib_examples, cfg.seed)),
    }
}

/// Scaffold a self-contained synthetic artifacts directory (vocab + manifest
/// with an fp16 baseline variant, no HLO, no weights) — the zero-setup path
/// for `samp plan --scaffold`, the CI smoke run and the planner tests.  The
/// native backend synthesizes deterministic weights for it at load time.
pub fn scaffold_synthetic_artifacts(dir: impl AsRef<Path>, task: &str)
                                    -> Result<PathBuf> {
    scaffold_synthetic_artifacts_opts(dir, task, false)
}

/// [`scaffold_synthetic_artifacts`] with an explicit overwrite policy:
/// `force` (`samp plan --scaffold --force`) replaces an existing
/// `manifest.json`/`vocab.txt` instead of refusing.
pub fn scaffold_synthetic_artifacts_opts(dir: impl AsRef<Path>, task: &str,
                                         force: bool) -> Result<PathBuf> {
    let dir = dir.as_ref();
    // never clobber a real artifacts directory (the CLI's --artifacts
    // default is `artifacts`, i.e. the compiled one): scaffolding only
    // writes into a directory with no manifest yet, unless --force says
    // the caller really means it
    ensure!(force || !dir.join("manifest.json").exists(),
            "{} already contains a manifest.json — refusing to overwrite it \
             with synthetic artifacts; point --artifacts at a fresh \
             directory or pass --force",
            dir.display());
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let mut vocab = vec!["[PAD]".to_string(), "[UNK]".to_string(),
                         "[CLS]".to_string(), "[SEP]".to_string(),
                         "[MASK]".to_string()];
    for i in 0..123 {
        vocab.push(format!("w{i:05}"));
    }
    std::fs::write(dir.join("vocab.txt"), vocab.join("\n"))
        .context("writing vocab.txt")?;
    // batch 4 x seq 32 keeps the modeled GEMM savings comfortably above the
    // extra INT8 launch overhead, so the frontier is strictly monotone
    let manifest = format!(r#"{{
  "format": 1, "serve_batch": 4, "vocab": "vocab.txt", "vocab_size": 128,
  "models": [{{
    "task": "{task}", "kind": "classification", "num_labels": 5,
    "seq_len": 32, "batch": 4, "hidden": 32, "layers": 4, "heads": 4,
    "ffn": 64, "head_hlo": "hlo/{task}/head.hlo.txt",
    "head_type": "classification", "calibrator": "minmax",
    "variants": {{
      "fp16": {{"hlo": "hlo/{task}/encoder_fp16.hlo.txt",
               "layer_modes": ["fp16", "fp16", "fp16", "fp16"],
               "n_full_quant": 0, "n_ffn_only": 0}}
    }},
    "dev_data": "", "dev_jsonl": ""
  }}]
}}"#);
    std::fs::write(dir.join("manifest.json"), manifest)
        .context("writing manifest.json")?;
    Ok(dir.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_calibration_set_is_deterministic_and_shaped() {
        let a = CalibrationSet::synthetic(128, 4, 16, 10, 7);
        let b = CalibrationSet::synthetic(128, 4, 16, 10, 7);
        assert_eq!(a.rows(), 10);
        assert_eq!(a.blocks.len(), 3); // 4 + 4 + 2
        assert_eq!(a.blocks[2].rows(), 2);
        for (x, y) in a.blocks.iter().zip(&b.blocks) {
            assert_eq!(x, y);
        }
        let c = CalibrationSet::synthetic(128, 4, 16, 10, 8);
        assert_ne!(a.blocks[0], c.blocks[0]);
        // every row has at least 2 real tokens
        for blk in &a.blocks {
            for r in 0..blk.rows() {
                let m: f32 = blk.attention_mask[r * 16..(r + 1) * 16]
                    .iter()
                    .sum();
                assert!(m >= 2.0, "row {r} mask sum {m}");
            }
        }
    }

    #[test]
    fn load_cost_model_reads_bench_json_and_falls_back() {
        // no path configured: built-in constants
        let cfg = PlannerConfig::default();
        assert_eq!(load_cost_model(&cfg), CpuCostModel::default());
        // a measured BENCH_SERVING.json with a gemm section calibrates
        let dir = std::env::temp_dir().join(format!(
            "samp_cost_model_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_SERVING.json");
        std::fs::write(&path,
                       r#"{"gemm": {"raw_f32_gflops": 20.0,
                                    "raw_int8_gops": 80.0}}"#)
            .unwrap();
        let cfg = PlannerConfig {
            cost_model_from: Some(path.clone()),
            ..PlannerConfig::default()
        };
        let calibrated = load_cost_model(&cfg);
        assert_ne!(calibrated, CpuCostModel::default());
        assert_eq!(calibrated,
                   CpuCostModel::from_bench_json(
                       &Json::parse(
                           &std::fs::read_to_string(&path).unwrap())
                       .unwrap())
                   .unwrap());
        // unreadable / sectionless files degrade to the defaults
        std::fs::write(&path, r#"{"openloop": {}}"#).unwrap();
        assert_eq!(load_cost_model(&cfg), CpuCostModel::default());
        let cfg = PlannerConfig {
            cost_model_from: Some(dir.join("missing.json")),
            ..PlannerConfig::default()
        };
        assert_eq!(load_cost_model(&cfg), CpuCostModel::default());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scaffold_produces_loadable_artifacts_and_never_clobbers() {
        let dir = std::env::temp_dir().join(format!(
            "samp_scaffold_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        scaffold_synthetic_artifacts(&dir, "demo").unwrap();
        let m = Manifest::load(&dir).unwrap();
        let spec = m.model("demo").unwrap();
        assert_eq!(spec.layers, 4);
        assert!(spec.variants.contains_key("fp16"));
        // a directory that already has a manifest (e.g. the real compiled
        // artifacts) must be refused, not overwritten
        let err = scaffold_synthetic_artifacts(&dir, "demo")
            .unwrap_err()
            .to_string();
        assert!(err.contains("refusing to overwrite"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scaffold_force_overwrites_an_existing_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "samp_scaffold_force_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        // an existing (corrupt) manifest blocks the default path ...
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(scaffold_synthetic_artifacts(&dir, "demo").is_err());
        assert!(Manifest::load(&dir).is_err(), "corrupt manifest must stay");
        // ... and --force replaces it with loadable synthetic artifacts
        scaffold_synthetic_artifacts_opts(&dir, "demo", true).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("demo").unwrap().variants.contains_key("fp16"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
