//! Calibration pass: per-layer quantization sensitivity + static activation
//! scales.
//!
//! Everything here runs through the native backend's real kernels — the same
//! INT8 GEMMs that serve traffic — so a sensitivity number is a measurement,
//! not a proxy.  One calibration produces two artifacts:
//!
//! * **Static activation scales** — the f32 reference forward is observed at
//!   every quantization site ([`Tap`]); per (layer, tap) the max-abs across
//!   the whole calibration set (optionally clipped at a |x| percentile via
//!   `quant::calibrators`) becomes the serving-time static scale.
//! * **Per-layer sensitivity** — each candidate layer is quantized *alone*
//!   (every other layer on the f32 reference path) and the damage is read
//!   off the task head's logits: mean-squared logit error plus the top-1
//!   flip rate against the reference predictions.  This is the
//!   measure-then-search recipe of zero-shot PTQ (El-Kurdi et al.) applied
//!   with SAMP's layer granularity.

use anyhow::{ensure, Result};

use crate::backend::native::{LayerScales, NativeModel, Tap};
use crate::config::ModelSpec;
use crate::latency::LayerMode;
use crate::quant::{self, scale_percentile, Histogram};

use super::CalibrationSet;

/// Histogram resolution for the percentile calibrator.
const CALIB_BINS: usize = 2048;

/// How to turn observed |activation| statistics into a static scale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Calibrator {
    /// scale = amax / 127 (the paper tool's min-max default).
    MaxAbs,
    /// Clip at the given |x| percentile (e.g. 99.9) before scaling — costs
    /// one extra reference pass for the histograms.
    Percentile(f64),
}

impl Calibrator {
    pub fn parse(s: &str) -> Option<Calibrator> {
        match s {
            "maxabs" | "minmax" => Some(Calibrator::MaxAbs),
            _ => s.strip_prefix("percentile")
                .and_then(|rest| {
                    let rest = rest.trim_start_matches([':', '=']);
                    if rest.is_empty() {
                        Some(99.9)
                    } else {
                        rest.parse().ok()
                    }
                })
                // out-of-range percentiles would clip at (or beyond) the
                // first histogram bin and persist garbage scales — reject
                .filter(|p: &f64| *p > 0.0 && *p <= 100.0)
                .map(Calibrator::Percentile),
        }
    }
}

/// Measured quantization damage of turning ONE layer INT8 with every other
/// layer on the reference path.
#[derive(Debug, Clone, Copy)]
pub struct LayerSensitivity {
    pub layer: usize,
    /// Mean squared logit error vs the f32 reference over the calibration
    /// set (the planner's primary ordering key).
    pub logit_mse: f64,
    /// Fraction of calibration rows whose top-1 prediction flipped.
    pub top1_flip_rate: f64,
}

/// Logit error of an arbitrary plan vs the reference logits: (MSE, top-1
/// flip rate).  Shared by the sensitivity ranking and the plan search so
/// both report the same metric.
pub fn eval_plan(model: &NativeModel, spec: &ModelSpec,
                 calib: &CalibrationSet, ref_logits: &[Vec<f32>],
                 plan: &[LayerMode]) -> Result<(f64, f64)> {
    ensure!(ref_logits.len() == calib.blocks.len(),
            "reference logits out of sync with the calibration set");
    let nl = spec.num_labels;
    let mut sq_err = 0f64;
    let mut n_logits = 0usize;
    let mut flips = 0usize;
    let mut preds_total = 0usize;
    for (block, refs) in calib.blocks.iter().zip(ref_logits) {
        let hidden = model.forward(block, plan)?;
        let logits = model.head_forward(&hidden, block.batch, block.seq)?;
        ensure!(logits.len() == refs.len(), "logit shape drift");
        // score only the logits the task actually reads: the really-written
        // rows (blocks may be part-filled), and for NER only the unmasked
        // token positions of those rows — decode ignores padding positions,
        // so quantization noise there must not steer the plan
        let mut score = |off: usize| {
            let (got, want) = (&logits[off..off + nl], &refs[off..off + nl]);
            for (a, b) in got.iter().zip(want) {
                let d = (*a - *b) as f64;
                sq_err += d * d;
            }
            n_logits += nl;
            if crate::tasks::argmax(got) != crate::tasks::argmax(want) {
                flips += 1;
            }
            preds_total += 1;
        };
        if spec.head_type == "ner" {
            for r in 0..block.rows() {
                for t in 0..block.seq {
                    let pos = r * block.seq + t;
                    if block.attention_mask[pos] > 0.5 {
                        score(pos * nl);
                    }
                }
            }
        } else {
            for r in 0..block.rows() {
                score(r * nl);
            }
        }
    }
    ensure!(n_logits > 0, "empty calibration set");
    Ok((sq_err / n_logits as f64, flips as f64 / preds_total as f64))
}

/// The reference pass: run the calibration set on the pure-f32 path,
/// recording (a) the reference logits per block and (b) a static activation
/// scale per (layer, tap).  `Percentile` adds a second observed pass for the
/// histograms (amax must be known before binning).
pub fn calibrate_reference(model: &NativeModel, spec: &ModelSpec,
                           calib: &CalibrationSet, calibrator: Calibrator)
                           -> Result<(Vec<Vec<f32>>, Vec<LayerScales>)> {
    let layers = model.geom().layers;
    let f32_plan = vec![LayerMode::Fp32; layers];
    let mut amax = vec![[0f32; 4]; layers];
    let mut ref_logits = Vec::with_capacity(calib.blocks.len());
    for block in &calib.blocks {
        let hidden = model.forward_observed(block, &f32_plan,
            &mut |l, tap, xs| {
                let m = xs.iter().fold(0f32, |a, &x| a.max(x.abs()));
                let slot = &mut amax[l][tap_index(tap)];
                *slot = slot.max(m);
            })?;
        ref_logits.push(model.head_forward(&hidden, block.batch, block.seq)?);
    }

    let mut out = vec![LayerScales::default(); layers];
    match calibrator {
        Calibrator::MaxAbs => {
            for (l, ls) in out.iter_mut().enumerate() {
                for tap in Tap::ALL {
                    ls.set(tap, quant::amax_to_scale(amax[l][tap_index(tap)]));
                }
            }
        }
        Calibrator::Percentile(pct) => {
            let mut hists: Vec<Vec<Histogram>> = amax
                .iter()
                .map(|taps| {
                    taps.iter()
                        .map(|&m| Histogram::new(CALIB_BINS, m))
                        .collect()
                })
                .collect();
            for block in &calib.blocks {
                model.forward_observed(block, &f32_plan, &mut |l, tap, xs| {
                    hists[l][tap_index(tap)].add(xs);
                })?;
            }
            for (l, ls) in out.iter_mut().enumerate() {
                for tap in Tap::ALL {
                    ls.set(tap,
                           scale_percentile(&hists[l][tap_index(tap)], pct));
                }
            }
        }
    }
    Ok((ref_logits, out))
}

/// Rank every layer by quantizing it alone in `mode` and measuring the logit
/// damage.  Returns one entry per layer, in layer order (callers sort).
pub fn measure_sensitivity(model: &NativeModel, spec: &ModelSpec,
                           calib: &CalibrationSet, ref_logits: &[Vec<f32>],
                           mode: LayerMode) -> Result<Vec<LayerSensitivity>> {
    ensure!(mode.is_int8(), "sensitivity is defined for INT8 modes, got \
                             {mode:?}");
    let layers = model.geom().layers;
    let mut out = Vec::with_capacity(layers);
    for l in 0..layers {
        let mut plan = vec![LayerMode::Fp32; layers];
        plan[l] = mode;
        let (logit_mse, top1_flip_rate) =
            eval_plan(model, spec, calib, ref_logits, &plan)?;
        out.push(LayerSensitivity { layer: l, logit_mse, top1_flip_rate });
    }
    Ok(out)
}

/// Sensitivity-ascending layer order (least damaging first) — the greedy
/// search's insertion order.  Ties break toward the earlier layer, so the
/// order is deterministic.
pub fn ascending_order(sens: &[LayerSensitivity]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..sens.len()).collect();
    idx.sort_by(|&a, &b| {
        sens[a]
            .logit_mse
            .partial_cmp(&sens[b].logit_mse)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

fn tap_index(tap: Tap) -> usize {
    match tap {
        Tap::AttnIn => 0,
        Tap::AttnCtx => 1,
        Tap::FfnIn => 2,
        Tap::FfnAct => 3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrator_parse() {
        assert_eq!(Calibrator::parse("maxabs"), Some(Calibrator::MaxAbs));
        assert_eq!(Calibrator::parse("minmax"), Some(Calibrator::MaxAbs));
        assert_eq!(Calibrator::parse("percentile"),
                   Some(Calibrator::Percentile(99.9)));
        assert_eq!(Calibrator::parse("percentile=99.0"),
                   Some(Calibrator::Percentile(99.0)));
        assert_eq!(Calibrator::parse("percentile:95"),
                   Some(Calibrator::Percentile(95.0)));
        assert_eq!(Calibrator::parse("bogus"), None);
        // out-of-range percentiles are rejected, not silently persisted
        assert_eq!(Calibrator::parse("percentile:0"), None);
        assert_eq!(Calibrator::parse("percentile:-5"), None);
        assert_eq!(Calibrator::parse("percentile:100.5"), None);
    }

    #[test]
    fn ascending_order_sorts_by_mse_with_stable_ties() {
        let sens = vec![
            LayerSensitivity { layer: 0, logit_mse: 0.5, top1_flip_rate: 0.0 },
            LayerSensitivity { layer: 1, logit_mse: 0.1, top1_flip_rate: 0.0 },
            LayerSensitivity { layer: 2, logit_mse: 0.5, top1_flip_rate: 0.0 },
            LayerSensitivity { layer: 3, logit_mse: 0.0, top1_flip_rate: 0.0 },
        ];
        assert_eq!(ascending_order(&sens), vec![3, 1, 0, 2]);
    }
}
