//! Plan-space search: greedy sensitivity-ordered ascent over the
//! accuracy/latency frontier, plus an optional swap-refinement pass.
//!
//! The space of per-layer plans is 2^L; the classic mixed-precision result
//! (Rakka et al.'s survey, the paper's own prefix plans) is that greedy
//! insertion in sensitivity order recovers near-optimal fronts at a tiny
//! fraction of the cost.  Here:
//!
//! 1. **Greedy ascent** — start from the all-floating plan and flip layers
//!    to INT8 one at a time, least-sensitive first.  Each step is measured
//!    (real kernels, real calibration logits) and costed (T4 model), giving
//!    one frontier point per quantization rate: `k = 0..=L`.
//! 2. **Selection** — under an accuracy budget, take the highest-k point
//!    whose logit error fits; under a latency target, the lowest-k point
//!    that is fast enough (most accurate plan meeting the target).
//! 3. **Swap refinement** (optional) — hill-climb single swaps (one INT8
//!    layer out, one floating layer in) on the chosen point under a bounded
//!    evaluation budget; count-preserving swaps keep the latency story while
//!    strictly improving the measured error.

use anyhow::{ensure, Result};

use crate::backend::native::NativeModel;
use crate::config::ModelSpec;
use crate::latency::{samp_plan_latency_ms, CpuCostModel, LayerMode};
use crate::util::json::Json;

use super::sensitivity::eval_plan;
use super::CalibrationSet;

/// What the planner optimizes for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Highest INT8 rate whose calibration-set logit MSE stays <= epsilon.
    AccuracyBudget(f64),
    /// Most accurate plan whose modeled latency is <= the target.
    LatencyTargetMs(f64),
}

/// One measured point of the accuracy/latency frontier.
#[derive(Debug, Clone)]
pub struct FrontierPoint {
    /// Number of INT8 layers (the quantization rate numerator).
    pub int8_layers: usize,
    /// Which layers are INT8, ascending.
    pub layers: Vec<usize>,
    pub plan: Vec<LayerMode>,
    pub logit_mse: f64,
    pub top1_flip_rate: f64,
    pub modeled_latency_ms: f64,
    /// Modeled native-CPU latency at the planner's `--gemm-threads` count
    /// (the machine this process actually serves on); the T4 column above
    /// stays the paper's reporting convention.
    pub native_cpu_latency_ms: f64,
}

impl FrontierPoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("int8_layers", Json::num(self.int8_layers as f64)),
            ("layers",
             Json::arr(self.layers.iter().map(|&l| Json::num(l as f64)))),
            ("plan",
             Json::arr(self.plan.iter().map(|m| Json::str(m.as_str())))),
            ("logit_mse", Json::num(self.logit_mse)),
            ("top1_flip_rate", Json::num(self.top1_flip_rate)),
            ("modeled_latency_ms", Json::num(self.modeled_latency_ms)),
            ("native_cpu_latency_ms", Json::num(self.native_cpu_latency_ms)),
        ])
    }
}

/// Cap on extra plan evaluations the swap-refinement pass may spend.
const REFINE_EVAL_BUDGET: usize = 32;

/// How the search costs the native-CPU latency column of every frontier
/// point: the roofline constants (hand-picked defaults, or calibrated from
/// a measured `BENCH_SERVING.json` via `--cost-model-from`) plus the GEMM
/// thread count the column assumes.
#[derive(Debug, Clone, Copy)]
pub struct CostCtx {
    pub model: CpuCostModel,
    pub threads: usize,
}

impl CostCtx {
    /// The uncalibrated default model at `threads` (what the search used
    /// before `--cost-model-from` existed).
    pub fn with_threads(threads: usize) -> CostCtx {
        CostCtx { model: CpuCostModel::default(), threads }
    }
}

fn point(model: &NativeModel, spec: &ModelSpec, calib: &CalibrationSet,
         ref_logits: &[Vec<f32>], int8: &[usize], mode: LayerMode,
         cost: CostCtx) -> Result<FrontierPoint> {
    let layers = model.geom().layers;
    let mut plan = vec![LayerMode::Fp16; layers];
    for &l in int8 {
        plan[l] = mode;
    }
    let (logit_mse, top1_flip_rate) = if int8.is_empty() {
        // the all-floating native plan is bit-identical to the reference
        (0.0, 0.0)
    } else {
        eval_plan(model, spec, calib, ref_logits, &plan)?
    };
    let modeled_latency_ms =
        samp_plan_latency_ms(spec.layers, spec.batch, spec.seq_len, &plan);
    let native_cpu_latency_ms = cost.model.plan_latency_ms(
        spec.layers, spec.batch, spec.seq_len, &plan, cost.threads);
    let mut sorted = int8.to_vec();
    sorted.sort_unstable();
    Ok(FrontierPoint {
        int8_layers: int8.len(),
        layers: sorted,
        plan,
        logit_mse,
        top1_flip_rate,
        modeled_latency_ms,
        native_cpu_latency_ms,
    })
}

/// Greedy sensitivity-ordered ascent: one frontier point per INT8-layer
/// count, flipping layers in `order` (least sensitive first).
pub fn greedy_frontier(model: &NativeModel, spec: &ModelSpec,
                       calib: &CalibrationSet, ref_logits: &[Vec<f32>],
                       order: &[usize], mode: LayerMode, cost: CostCtx)
                       -> Result<Vec<FrontierPoint>> {
    let layers = model.geom().layers;
    ensure!(order.len() == layers, "order length {} != layers {layers}",
            order.len());
    let mut frontier = Vec::with_capacity(layers + 1);
    let mut active: Vec<usize> = Vec::with_capacity(layers);
    frontier.push(point(model, spec, calib, ref_logits, &active, mode,
                        cost)?);
    for &l in order {
        active.push(l);
        frontier.push(point(model, spec, calib, ref_logits, &active, mode,
                            cost)?);
    }
    Ok(frontier)
}

/// Pick the frontier point the objective asks for.  Returns (index,
/// feasible).
pub fn choose(frontier: &[FrontierPoint], objective: Objective)
              -> (usize, bool) {
    match objective {
        Objective::AccuracyBudget(eps) => {
            // highest INT8 rate within budget; k=0 is exact, so always
            // feasible
            let mut best = 0;
            for (i, p) in frontier.iter().enumerate() {
                if p.logit_mse <= eps {
                    best = i;
                }
            }
            (best, true)
        }
        Objective::LatencyTargetMs(target) => {
            // lowest INT8 rate that is fast enough = most accurate plan
            // meeting the target (greedy latency falls monotonically with k)
            for (i, p) in frontier.iter().enumerate() {
                if p.modeled_latency_ms <= target {
                    return (i, true);
                }
            }
            (frontier.len() - 1, false)
        }
    }
}

/// Hill-climb count-preserving swaps on `start`: move one INT8 layer out and
/// one floating layer in whenever that strictly lowers the measured logit
/// MSE.  Bounded by [`REFINE_EVAL_BUDGET`] extra evaluations; returns the
/// improved point (or a clone of `start` if no swap helped).
pub fn refine_swaps(model: &NativeModel, spec: &ModelSpec,
                    calib: &CalibrationSet, ref_logits: &[Vec<f32>],
                    start: &FrontierPoint, mode: LayerMode,
                    cost: CostCtx) -> Result<FrontierPoint> {
    let layers = model.geom().layers;
    let mut best = start.clone();
    if best.layers.is_empty() || best.layers.len() == layers {
        return Ok(best); // nothing to swap
    }
    let mut evals = 0usize;
    let mut improved = true;
    while improved && evals < REFINE_EVAL_BUDGET {
        improved = false;
        let current = best.layers.clone();
        'swap: for &out in &current {
            for candidate in 0..layers {
                if current.contains(&candidate) {
                    continue;
                }
                if evals >= REFINE_EVAL_BUDGET {
                    break 'swap;
                }
                let mut trial: Vec<usize> = current
                    .iter()
                    .copied()
                    .filter(|&l| l != out)
                    .collect();
                trial.push(candidate);
                let p = point(model, spec, calib, ref_logits, &trial, mode,
                              cost)?;
                evals += 1;
                if p.logit_mse < best.logit_mse {
                    best = p;
                    improved = true;
                    break 'swap;
                }
            }
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(k: usize, mse: f64, ms: f64) -> FrontierPoint {
        FrontierPoint {
            int8_layers: k,
            layers: (0..k).collect(),
            plan: vec![],
            logit_mse: mse,
            top1_flip_rate: 0.0,
            modeled_latency_ms: ms,
            native_cpu_latency_ms: ms * 10.0,
        }
    }

    #[test]
    fn choose_accuracy_budget_takes_highest_rate_within_eps() {
        let f = vec![pt(0, 0.0, 9.0), pt(1, 0.001, 8.0), pt(2, 0.004, 7.0),
                     pt(3, 0.02, 6.0)];
        assert_eq!(choose(&f, Objective::AccuracyBudget(0.005)), (2, true));
        assert_eq!(choose(&f, Objective::AccuracyBudget(1.0)), (3, true));
        assert_eq!(choose(&f, Objective::AccuracyBudget(0.0)), (0, true));
    }

    #[test]
    fn choose_latency_target_takes_most_accurate_fast_enough() {
        let f = vec![pt(0, 0.0, 9.0), pt(1, 0.001, 8.0), pt(2, 0.004, 7.0)];
        assert_eq!(choose(&f, Objective::LatencyTargetMs(8.5)), (1, true));
        assert_eq!(choose(&f, Objective::LatencyTargetMs(100.0)), (0, true));
        // unreachable target: fastest point, flagged infeasible
        assert_eq!(choose(&f, Objective::LatencyTargetMs(1.0)), (2, false));
    }

    #[test]
    fn frontier_point_serializes() {
        let j = pt(2, 0.5, 3.25).to_json();
        assert_eq!(j.get("int8_layers").as_usize(), Some(2));
        assert_eq!(j.get("layers").as_arr().unwrap().len(), 2);
        assert!((j.get("modeled_latency_ms").as_f64().unwrap() - 3.25).abs()
                < 1e-12);
    }
}
