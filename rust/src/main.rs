//! `samp` CLI — leader entrypoint of the Layer-3 coordinator.
//!
//! Subcommands (see `samp help`): serve / infer / sweep / allocate / plan /
//! latency / tokenize.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use samp::allocator::Requirements;
use samp::cli::{Args, HELP};
use samp::config::{Manifest, ServerConfig};
use samp::coordinator::{Router, TaskOutput};
use samp::data::Dataset;
use samp::latency::{encoder_latency_us, LayerMode, Toolkit, Workload, BERT_BASE,
                    TESLA_T4};
use samp::planner::{self, Calibrator, Objective, PlannerConfig};
use samp::runtime::Runtime;
use samp::server::Server;
use samp::tokenizer::Granularity;

fn main() {
    let args = match Args::parse(std::env::args()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: Args) -> Result<()> {
    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "serve" => serve(&args),
        "infer" => infer(&args),
        "sweep" => sweep(&args),
        "allocate" => allocate(&args),
        "plan" => plan(&args),
        "latency" => latency(&args),
        "tokenize" => tokenize(&args),
        other => bail!("unknown subcommand `{other}`\n\n{HELP}"),
    }
}

fn router_from(args: &Args) -> Result<Router> {
    let dir = args.flag_or("artifacts", "artifacts");
    let rt = Arc::new(Runtime::cpu()?);
    let manifest = Manifest::load(&dir)
        .with_context(|| format!("loading artifacts from `{dir}` \
                                  (run `make artifacts` first?)"))?;
    Router::new(rt, manifest)
}

fn serve(args: &Args) -> Result<()> {
    // --artifacts is repeatable: `ID=DIR` registers one model per
    // occurrence, a bare `DIR` is the `default` model
    let mut models: Vec<(String, PathBuf)> = Vec::new();
    for spec in args.flag_all("artifacts") {
        let (id, dir) = match spec.split_once('=') {
            Some((id, dir)) if !id.is_empty() && !dir.is_empty() => {
                (id.to_string(), PathBuf::from(dir))
            }
            Some(_) => bail!("--artifacts expects DIR or ID=DIR, got `{spec}`"),
            None => ("default".to_string(), PathBuf::from(spec)),
        };
        if models.iter().any(|(existing, _)| *existing == id) {
            bail!("duplicate model id `{id}` in --artifacts");
        }
        models.push((id, dir));
    }
    if models.is_empty() {
        models.push(("default".to_string(), PathBuf::from("artifacts")));
    }
    // --lane-weight ID=W is repeatable: each occurrence weights one model's
    // slice of the global dispatcher/queue pool
    let mut lane_weights: Vec<(String, f64)> = Vec::new();
    for spec in args.flag_all("lane-weight") {
        let (id, w) = match spec.split_once('=') {
            Some((id, w)) if !id.is_empty() && !w.is_empty() => {
                let w: f64 = w.parse().map_err(|_| anyhow::anyhow!(
                    "--lane-weight expects ID=NUMBER, got `{spec}`"))?;
                (id.to_string(), w)
            }
            _ => bail!("--lane-weight expects ID=NUMBER, got `{spec}`"),
        };
        if !w.is_finite() || w <= 0.0 {
            bail!("--lane-weight {id}: weight must be a positive number");
        }
        if models.iter().all(|(m, _)| *m != id) {
            bail!("--lane-weight {id}: no such model in --artifacts");
        }
        if lane_weights.iter().any(|(existing, _)| *existing == id) {
            bail!("duplicate model id `{id}` in --lane-weight");
        }
        lane_weights.push((id, w));
    }
    let config = ServerConfig {
        addr: args.flag_or("addr", "127.0.0.1:8117"),
        artifacts_dir: models[0].1.clone(),
        batch_timeout_ms: args.flag_usize("batch-timeout-ms", 5)? as u64,
        workers: args.flag_usize("workers", 2)?,
        // 0 = auto (min(4, cores)); each task lane gets this many
        // dispatcher workers pulling from one shared queue
        workers_per_lane: args.flag_usize("workers-per-lane", 0)?,
        default_variant: args.flag("variant").map(String::from),
        max_queue_depth: args.flag_usize("max-queue-depth", 1024)?,
        replicas_per_lane: args.flag_usize("replicas-per-lane", 1)?,
        watch_manifest: args.flag_bool("watch-manifest"),
        watch_interval_ms: args.flag_usize("watch-interval-ms", 500)? as u64,
        models,
        gemm_threads: args.flag_usize("gemm-threads", 0)?,
        pin_cores: args
            .flag_all("pin-cores")
            .into_iter()
            .map(samp::config::parse_core_list)
            .collect::<Result<Vec<_>>>()?,
        ladder: args.flag_bool("ladder"),
        slo_p99_ms: args.flag_usize("slo-p99-ms", 0)? as u64,
        default_deadline_ms: args.flag_usize("default-deadline-ms", 0)? as u64,
        trace_responses: args.flag_bool("trace-responses"),
        lane_weights,
        steal: !args.flag_bool("no-steal"),
        learn_weights: args.flag_bool("learn-weights"),
        flight_recorder: !args.flag_bool("no-flight-recorder"),
        flight_cap: args.flag_usize("flight-cap", 4096)?,
    };
    if config.max_queue_depth == 0 {
        bail!("--max-queue-depth must be >= 1 (0 would reject every request)");
    }
    if config.replicas_per_lane == 0 {
        bail!("--replicas-per-lane must be >= 1");
    }
    if let Some(v) = &config.default_variant {
        eprintln!("[serve] default variant: {v} (applied to every model \
                   generation, including reloads)");
    }
    let server = Server::from_config(config)?;
    install_shutdown_watcher(&server);
    server.run()
}

/// SIGINT/SIGTERM flip a flag; a watcher thread turns it into a graceful
/// [`Server::shutdown`], so lanes drain through the registry's
/// generation-retire path (in-flight rows finish, dispatcher workers join)
/// instead of the process aborting mid-batch.
#[cfg(unix)]
fn install_shutdown_watcher(server: &Arc<Server>) {
    use std::sync::atomic::{AtomicBool, Ordering};

    static SHUTDOWN_SIGNAL: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_sig: i32) {
        if SHUTDOWN_SIGNAL.swap(true, Ordering::SeqCst) {
            // second signal: the graceful drain is taking too long (or is
            // wedged) and the operator insists — hard-exit.  `_exit` is
            // async-signal-safe; `exit`/`abort` are not guaranteed to be.
            unsafe { _exit(130) }
        }
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
        fn _exit(status: i32) -> !;
    }
    let handler = on_signal as extern "C" fn(i32);
    unsafe {
        signal(2, handler as usize); // SIGINT
        signal(15, handler as usize); // SIGTERM
    }
    let srv = server.clone();
    std::thread::spawn(move || loop {
        if SHUTDOWN_SIGNAL.load(Ordering::SeqCst) {
            eprintln!("[serve] shutdown signal received — draining lanes");
            srv.shutdown();
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    });
}

#[cfg(not(unix))]
fn install_shutdown_watcher(_server: &Arc<Server>) {}

fn infer(args: &Args) -> Result<()> {
    let task = args.flag("task").context("--task required")?.to_string();
    let text = args.flag("text").context("--text required")?.to_string();
    let router = router_from(args)?;
    let pipe = match args.flag("variant") {
        Some(v) => router.activate(&task, v)?,
        None => router.pipeline(&task)?,
    };
    let out = pipe.infer_text(&text)?;
    match out {
        TaskOutput::Classification(c) => {
            println!("label={} confidence={:.4}", c.label, c.confidence);
            for (l, p) in c.top_k {
                println!("  top-k: label={l} prob={p:.4}");
            }
        }
        TaskOutput::Matching(m) => {
            println!("is_match={} probability={:.4}", m.is_match, m.probability);
        }
        TaskOutput::Ner(ents) => {
            for e in ents {
                println!("[{} {}..{}]", e.entity_type, e.start, e.end);
            }
        }
    }
    Ok(())
}

fn sweep(args: &Args) -> Result<()> {
    let task = args.flag("task").context("--task required")?.to_string();
    let mode = args.flag_or("mode", "ffn_only");
    let limit = match args.flag_usize("limit", 0)? {
        0 => Some(256),
        n => Some(n),
    };
    let router = router_from(args)?;
    let spec = router.manifest.model(&task)?;
    let ds = Dataset::load_bin(router.manifest.path(&spec.dev_data))?;
    println!("task={task} mode={mode} dev_n={} (limit {:?})", ds.n, limit);
    println!("{:>14} {:>6} {:>10} {:>14} {:>10} {:>12}",
             "variant", "k", "accuracy", "T4 latency ms", "speedup", "cpu ms/b");
    let points = router.sweep(&task, &mode, &ds, limit)?;
    for p in &points {
        println!("{:>14} {:>6} {:>10.4} {:>14.4} {:>10.4} {:>12.2}",
                 p.variant, p.quantized_layers, p.accuracy, p.model_latency_ms,
                 p.speedup_vs_pytorch_fp16, p.cpu_batch_ms);
    }
    Ok(())
}

fn allocate(args: &Args) -> Result<()> {
    let task = args.flag("task").context("--task required")?.to_string();
    let mode = args.flag_or("mode", "ffn_only");
    let limit = Some(args.flag_usize("limit", 256)?);
    let req = Requirements {
        max_latency_ms: args.flag_f64("max-latency-ms")?,
        min_accuracy: args.flag_f64("min-accuracy")?,
    };
    let router = router_from(args)?;
    let spec = router.manifest.model(&task)?;
    let ds = Dataset::load_bin(router.manifest.path(&spec.dev_data))?;
    let (variant, points) = router.self_adapt(&task, &mode, &ds, req, limit)?;
    for p in &points {
        let mark = if p.variant == variant { " <== recommended" } else { "" };
        println!("{:>14} k={:<2} acc={:.4} lat={:.4}ms speedup={:.4}{}",
                 p.variant, p.quantized_layers, p.accuracy, p.model_latency_ms,
                 p.speedup_vs_pytorch_fp16, mark);
    }
    println!("\nactivated: {task} -> {variant}");
    Ok(())
}

fn plan(args: &Args) -> Result<()> {
    let task = args.flag("task").context("--task required")?.to_string();
    let dir = args.flag_or("artifacts", "artifacts");
    if args.flag_bool("scaffold") {
        planner::scaffold_synthetic_artifacts_opts(&dir, &task,
                                                   args.flag_bool("force"))?;
        eprintln!("[plan] scaffolded synthetic artifacts in {dir}/");
    }
    let quick = args.flag_bool("quick");
    let mode = match args.flag_or("mode", "int8_full").as_str() {
        "int8_full" => LayerMode::Int8Full,
        "int8_ffn" => LayerMode::Int8Ffn,
        other => bail!("bad --mode `{other}` (int8_full|int8_ffn)"),
    };
    let objective = match (args.flag_f64("accuracy-budget")?,
                           args.flag_f64("latency-target-ms")?) {
        (Some(_), Some(_)) => {
            bail!("--accuracy-budget and --latency-target-ms are mutually \
                   exclusive")
        }
        (None, Some(t)) => Objective::LatencyTargetMs(t),
        (Some(e), None) => Objective::AccuracyBudget(e),
        (None, None) => Objective::AccuracyBudget(1e-2),
    };
    let calibrator = Calibrator::parse(&args.flag_or("calibrator", "maxabs"))
        .context("bad --calibrator (maxabs|percentile[:P])")?;
    let cfg = PlannerConfig {
        task,
        mode,
        objective,
        calib_jsonl: args.flag("calib").map(PathBuf::from),
        calib_examples: args.flag_usize("calib-size",
                                        if quick { 16 } else { 64 })?,
        calibrator,
        refine: args.flag_bool("refine"),
        variant_name: args.flag_or("name", "auto"),
        dry_run: args.flag_bool("dry-run"),
        // thread count the native-CPU latency column assumes (0 = auto)
        gemm_threads: args.flag_usize("gemm-threads", 0)?,
        // calibrate the native-CPU latency column from a measured bench
        // artifact: explicit path, else ./BENCH_SERVING.json when present
        cost_model_from: match args.flag("cost-model-from") {
            Some(p) => Some(PathBuf::from(p)),
            None => {
                let p = PathBuf::from("BENCH_SERVING.json");
                p.exists().then_some(p)
            }
        },
        ..PlannerConfig::default()
    };
    let report = planner::run_plan(&dir, &cfg)?;

    println!("task={} mode={} calib={} ({} rows)", report.task,
             report.mode.as_str(), report.calib_source, report.calib_rows);
    println!("sensitivity (per-layer, alone-quantized):");
    for s in &report.sensitivity {
        println!("  l{:<3} logit_mse={:.3e}  top1_flip={:.4}", s.layer,
                 s.logit_mse, s.top1_flip_rate);
    }
    println!("frontier:");
    println!("{:>4} {:>12} {:>10} {:>14}  {}", "k", "logit MSE", "flips",
             "T4 latency ms", "int8 layers");
    for (i, p) in report.frontier.iter().enumerate() {
        let mark = if i != report.chosen_index {
            ""
        } else if report.refined {
            "  <== greedy pick (refined below)"
        } else {
            "  <== chosen"
        };
        let layers: Vec<String> =
            p.layers.iter().map(|l| l.to_string()).collect();
        println!("{:>4} {:>12.3e} {:>10.4} {:>14.4}  [{}]{}", p.int8_layers,
                 p.logit_mse, p.top1_flip_rate, p.modeled_latency_ms,
                 layers.join(","), mark);
    }
    if report.refined {
        let layers: Vec<String> =
            report.chosen.layers.iter().map(|l| l.to_string()).collect();
        println!("refined: swaps improved the greedy pick to layers [{}] \
                  (logit_mse {:.3e})", layers.join(","),
                 report.chosen.logit_mse);
    }
    let modes: Vec<&str> =
        report.chosen.plan.iter().map(|m| m.as_str()).collect();
    println!("chosen plan ({} INT8 layers, logit_mse {:.3e}, {:.4} ms): [{}]",
             report.chosen.int8_layers, report.chosen.logit_mse,
             report.chosen.modeled_latency_ms, modes.join(","));
    if !report.feasible {
        eprintln!("warning: latency target unreachable even fully quantized \
                   — fastest plan chosen");
    }
    if let Some(out) = args.flag("frontier-out") {
        std::fs::write(out, report.to_json().to_string())
            .with_context(|| format!("writing {out}"))?;
        println!("frontier report -> {out}");
    }
    match &report.persisted {
        Some(p) => println!("persisted variant `{}` -> {}", report.variant,
                            p.display()),
        None => println!("(dry run: manifest untouched)"),
    }
    Ok(())
}

fn latency(args: &Args) -> Result<()> {
    let tk = Toolkit::parse(&args.flag_or("toolkit", "samp"))
        .context("bad --toolkit")?;
    let precision = args.flag_or("precision", "fp16");
    let batch = args.flag_usize("batch", 8)?;
    let seq = args.flag_usize("seq", 64)?;
    let mode = match precision.as_str() {
        "fp32" => LayerMode::Fp32,
        "fp16" => LayerMode::Fp16,
        "int8" => LayerMode::Int8Full,
        other => bail!("bad --precision {other}"),
    };
    let plan = vec![mode; BERT_BASE.layers];
    let us = encoder_latency_us(tk, BERT_BASE, Workload { batch, seq }, &plan,
                                &TESLA_T4);
    println!("{tk:?} BERT-base {precision} batch={batch} seq={seq}: \
              {:.1} us (modeled, {})", us, TESLA_T4.name);
    Ok(())
}

fn tokenize(args: &Args) -> Result<()> {
    let text = args.flag("text").context("--text required")?.to_string();
    let router = router_from(args)?;
    let g = match args.flag_or("granularity", "wordpiece").as_str() {
        "char" => Granularity::Char,
        _ => Granularity::Wordpiece,
    };
    let toks = match g {
        Granularity::Char => router.tokenizer.basic.tokenize(&text),
        Granularity::Wordpiece => router.tokenizer.tokenize(&text),
    };
    let ids: Vec<i32> = toks.iter().map(|t| router.tokenizer.vocab.id_of(t))
        .collect();
    println!("tokens: {toks:?}");
    println!("ids:    {ids:?}");
    Ok(())
}
