//! The four PTQ calibrators (min-max / percentile / entropy-KL / MSE) over
//! |x| histograms — Rust ports of compile/calib.py with identical semantics
//! (parity-tested in python/tests/test_calib.py goldens + rust unit tests).

use super::{amax_to_scale, QMAX};

/// |x| histogram with fixed range [0, amax].
#[derive(Debug, Clone)]
pub struct Histogram {
    pub amax: f32,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(num_bins: usize, amax: f32) -> Histogram {
        Histogram { amax, counts: vec![0; num_bins] }
    }

    /// Build from data in one pass (amax must already be known).
    pub fn collect(data: &[f32], num_bins: usize, amax: f32) -> Histogram {
        let mut h = Histogram::new(num_bins, amax);
        h.add(data);
        h
    }

    pub fn add(&mut self, data: &[f32]) {
        if self.amax <= 0.0 {
            return;
        }
        let n = self.counts.len() as f32;
        for &x in data {
            let a = x.abs();
            if a > self.amax {
                continue;
            }
            let mut b = (a / self.amax * n) as usize;
            if b >= self.counts.len() {
                b = self.counts.len() - 1;
            }
            self.counts[b] += 1;
        }
    }

    pub fn bin_width(&self) -> f32 {
        self.amax / self.counts.len() as f32
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

/// minmax: scale = amax / 127.
pub fn scale_minmax(hist: &Histogram) -> f32 {
    amax_to_scale(hist.amax)
}

/// percentile: clip at the given |x| percentile (default in the paper's tool
/// is 99.9).
pub fn scale_percentile(hist: &Histogram, percentile: f64) -> f32 {
    let total = hist.total();
    if total == 0 {
        return amax_to_scale(hist.amax);
    }
    let target = percentile / 100.0 * total as f64;
    let mut cum = 0u64;
    for (i, &c) in hist.counts.iter().enumerate() {
        cum += c;
        if cum as f64 >= target {
            let clip = (i + 1) as f32 * hist.bin_width();
            return amax_to_scale(clip.min(hist.amax));
        }
    }
    amax_to_scale(hist.amax)
}

fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    let sp: f64 = p.iter().sum();
    let sq: f64 = q.iter().sum();
    if sp <= 0.0 || sq <= 0.0 {
        return f64::INFINITY;
    }
    let mut d = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        let pn = pi / sp;
        if pn > 0.0 {
            let qn = (qi / sq).max(1e-12);
            d += pn * (pn / qn).ln();
        }
    }
    d
}

/// entropy: TensorRT-style KL minimization (mirror of calib.scale_entropy).
pub fn scale_entropy(hist: &Histogram, start_bin: usize, stride: usize) -> f32 {
    let n = hist.counts.len();
    if hist.total() == 0 {
        return amax_to_scale(hist.amax);
    }
    let mut best = (f64::INFINITY, n);
    let tail_total: Vec<u64> = {
        // suffix sums for O(1) tail mass
        let mut s = vec![0u64; n + 1];
        for i in (0..n).rev() {
            s[i] = s[i + 1] + hist.counts[i];
        }
        s
    };
    let mut i = start_bin;
    while i <= n {
        // P: first i bins with the clipped tail folded into the last bin
        let mut p: Vec<f64> = hist.counts[..i].iter().map(|&c| c as f64).collect();
        p[i - 1] += tail_total[i] as f64;
        // Q: project the first i bins onto 128 levels, averaging per level
        let chunk = i as f64 / 128.0;
        let mut level_sum = [0f64; 128];
        let mut level_nonzero = [0f64; 128];
        let mut edges = vec![0usize; i];
        for j in 0..i {
            let lvl = ((j as f64 / chunk) as usize).min(127);
            edges[j] = lvl;
            level_sum[lvl] += hist.counts[j] as f64;
            if hist.counts[j] > 0 {
                level_nonzero[lvl] += 1.0;
            }
        }
        let q: Vec<f64> = (0..i)
            .map(|j| {
                if hist.counts[j] > 0 {
                    level_sum[edges[j]] / level_nonzero[edges[j]].max(1.0)
                } else {
                    0.0
                }
            })
            .collect();
        let d = kl_divergence(&p, &q);
        if d < best.0 {
            best = (d, i);
        }
        i += stride;
    }
    let clip = best.1 as f32 * hist.bin_width();
    amax_to_scale(clip.min(hist.amax))
}

/// mse: sweep clip candidates, minimize histogram-estimated quantization MSE.
pub fn scale_mse(hist: &Histogram, num_candidates: usize) -> f32 {
    if hist.total() == 0 {
        return amax_to_scale(hist.amax);
    }
    let n = hist.counts.len();
    let bw = hist.bin_width();
    let mut best = (f64::INFINITY, hist.amax);
    for c in 0..num_candidates {
        let frac = 0.2 + 0.8 * c as f64 / (num_candidates - 1).max(1) as f64;
        let clip = frac as f32 * hist.amax;
        let scale = clip / QMAX as f32;
        let mut err = 0.0f64;
        for j in 0..n {
            if hist.counts[j] == 0 {
                continue;
            }
            let center = (j as f32 + 0.5) * bw;
            let q = (center / scale).round().clamp(-(QMAX as f32), QMAX as f32);
            let e = (center - q * scale) as f64;
            err += hist.counts[j] as f64 * e * e;
        }
        if err < best.0 {
            best = (err, clip);
        }
    }
    amax_to_scale(best.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn normal_hist(n: usize) -> Histogram {
        // synthetic |N(0,1)|-ish histogram with a long thin tail
        let mut h = Histogram::new(2048, 8.0);
        let mut rng = crate::util::prng::Prng::new(7);
        let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        h.add(&data);
        h
    }

    #[test]
    fn minmax_uses_full_range() {
        let h = normal_hist(50_000);
        assert!((scale_minmax(&h) - 8.0 / 127.0).abs() < 1e-6);
    }

    #[test]
    fn percentile_clips_tail() {
        let h = normal_hist(50_000);
        let p999 = scale_percentile(&h, 99.9);
        let p100 = scale_percentile(&h, 100.0);
        assert!(p999 < p100, "{p999} !< {p100}");
        // 99.9th percentile of |N(0,1)| ~ 3.29 sigma
        let clip = p999 * 127.0;
        assert!((2.5..4.5).contains(&clip), "clip {clip}");
    }

    #[test]
    fn entropy_and_mse_clip_below_amax() {
        let h = normal_hist(50_000);
        for s in [scale_entropy(&h, 128, 16), scale_mse(&h, 64)] {
            assert!(s > 0.0 && s <= scale_minmax(&h) + 1e-9);
        }
    }

    #[test]
    fn empty_histogram_degenerates_to_minmax() {
        let h = Histogram::new(128, 4.0);
        assert_eq!(scale_percentile(&h, 99.9), amax_to_scale(4.0));
        assert_eq!(scale_entropy(&h, 16, 4), amax_to_scale(4.0));
        assert_eq!(scale_mse(&h, 8), amax_to_scale(4.0));
    }

    #[test]
    fn uniform_data_mse_keeps_range() {
        // uniform data has mass at the edges: clipping hurts, MSE should
        // keep (nearly) the full range
        let mut h = Histogram::new(512, 1.0);
        let data: Vec<f32> = (0..10_000).map(|i| i as f32 / 10_000.0).collect();
        h.add(&data);
        let s = scale_mse(&h, 64);
        assert!(s * 127.0 > 0.9, "clip {}", s * 127.0);
    }
}
