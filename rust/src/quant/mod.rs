//! Symmetric INT8 quantization math + the four PTQ calibrators, Rust side.
//!
//! The serving path never quantizes (scales are baked into the AOT HLO), but
//! the coordinator still needs this module for:
//!   * the Fig-4 distribution study (`samp latency`/`bench_fig4` quantize
//!     recorded activations and histogram the codes);
//!   * calibrator reports (`samp calibrate-report`) and parity tests against
//!     the python implementation (same algorithms in compile/calib.py);
//!   * property tests of the quantization error bound.

pub mod calibrators;

pub use calibrators::{scale_entropy, scale_minmax, scale_mse, scale_percentile,
                      Histogram};

/// Symmetric INT8 range: [-127, 127]; -128 is never produced
/// (pytorch-quantization convention, paper Appendix B).
pub const QMIN: i32 = -127;
pub const QMAX: i32 = 127;

/// Quantize one value: clip(round(x / scale)).
#[inline]
pub fn quantize(x: f32, scale: f32) -> i8 {
    let q = (x / scale).round();
    q.clamp(QMIN as f32, QMAX as f32) as i8
}

/// Dequantize.
#[inline]
pub fn dequantize(q: i8, scale: f32) -> f32 {
    q as f32 * scale
}

/// Vector quantization into a caller-owned buffer: the repeated-use form
/// (Fig-4 bench, activation taps) amortizes the output allocation to zero.
/// The fixed-width inner chunks keep bounds checks out of the loop and give
/// the autovectorizer straight-line 8-lane bodies.
pub fn quantize_into(xs: &[f32], scale: f32, out: &mut Vec<i8>) {
    out.clear();
    out.reserve(xs.len());
    let mut chunks = xs.chunks_exact(8);
    for c in &mut chunks {
        let mut q = [0i8; 8];
        for (qi, &x) in q.iter_mut().zip(c) {
            *qi = quantize(x, scale);
        }
        out.extend_from_slice(&q);
    }
    for &x in chunks.remainder() {
        out.push(quantize(x, scale));
    }
}

/// Vector quantization (allocating convenience wrapper over
/// [`quantize_into`]).
pub fn quantize_slice(xs: &[f32], scale: f32) -> Vec<i8> {
    let mut out = Vec::new();
    quantize_into(xs, scale, &mut out);
    out
}

/// amax -> scale (degenerate tensors get scale 1.0, like the python side).
pub fn amax_to_scale(amax: f32) -> f32 {
    if amax <= 0.0 || !amax.is_finite() {
        1.0
    } else {
        amax / QMAX as f32
    }
}

/// Count of distinct INT8 codes used by quantized data + the unused fraction
/// — the Appendix-B statistic (67.58% unused for softmax output vs 4.30% for
/// MHA output).
#[derive(Debug, Clone, PartialEq)]
pub struct CodeUsage {
    /// histogram over the 256 codes, index = code + 128
    pub counts: [u64; 256],
    pub used: usize,
    pub unused: usize,
    pub unused_fraction: f64,
}

pub fn code_usage(codes: &[i8]) -> CodeUsage {
    let mut counts = [0u64; 256];
    for &c in codes {
        counts[(c as i32 + 128) as usize] += 1;
    }
    let used = counts.iter().filter(|&&c| c > 0).count();
    CodeUsage {
        counts,
        used,
        unused: 256 - used,
        unused_fraction: (256 - used) as f64 / 256.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let scale = 0.05f32;
        for i in -1000..1000 {
            let x = i as f32 * 0.005;
            if x.abs() <= scale * 126.0 {
                let err = (dequantize(quantize(x, scale), scale) - x).abs();
                assert!(err <= scale / 2.0 + 1e-6, "x={x} err={err}");
            }
        }
    }

    #[test]
    fn never_produces_minus_128() {
        for i in -100000..100000 {
            let q = quantize(i as f32, 0.3);
            assert!(q >= -127);
        }
    }

    #[test]
    fn degenerate_amax() {
        assert_eq!(amax_to_scale(0.0), 1.0);
        assert_eq!(amax_to_scale(f32::NAN), 1.0);
        assert_eq!(amax_to_scale(127.0), 1.0);
    }

    #[test]
    fn code_usage_counts() {
        // softmax-like data: all non-negative codes
        let codes: Vec<i8> = (0..=64).collect();
        let u = code_usage(&codes);
        assert_eq!(u.used, 65);
        assert_eq!(u.unused, 191);
        assert!(u.unused_fraction > 0.7);
    }

    #[test]
    fn quantize_into_matches_slice_and_reuses_capacity() {
        let xs: Vec<f32> = (0..37).map(|i| (i as f32 - 18.0) * 0.013).collect();
        let scale = 0.05f32;
        let mut out = Vec::new();
        quantize_into(&xs, scale, &mut out);
        assert_eq!(out, quantize_slice(&xs, scale));
        let cap = out.capacity();
        // second call with fewer elements must not reallocate
        quantize_into(&xs[..9], scale, &mut out);
        assert_eq!(out, quantize_slice(&xs[..9], scale));
        assert_eq!(out.capacity(), cap);
    }

    #[test]
    fn parity_with_python_quantize() {
        // mirrors compile/kernels/common.py::quantize on a fixed vector
        let xs = [0.0f32, 0.024, -0.024, 1.0, -5.0, 0.05, 0.074, 0.076];
        let scale = 0.05f32;
        let got = quantize_slice(&xs, scale);
        assert_eq!(got, vec![0, 0, 0, 20, -100, 1, 1, 2]);
    }
}
