//! Fault injection for robustness testing.
//!
//! A fault plan is a comma-separated spec, set either via the `SAMP_FAULT`
//! environment variable at startup or at runtime through
//! `POST /v1/debug/fault` (`{"spec": "..."}`; empty spec clears).  Grammar,
//! per clause `key:value[:budget]`:
//!
//! * `gemm_panic:P[:N]` — each threaded GEMM panics one worker job with
//!   probability `P` (0..=1); an optional budget `N` caps total injections
//!   so tests can arm exactly one deterministic fault (`gemm_panic:1:1`).
//! * `slow_forward:Dms` — every native encoder forward sleeps `D` ms.
//! * `slow_fp32:Dms` — a native forward sleeps `D` ms scaled by the
//!   fraction of non-INT8 layers in its plan: a 100%-INT8 variant pays
//!   nothing, full f32 pays the whole delay.  This makes precision-ladder
//!   overload tests deterministic: pressure genuinely clears when the
//!   ladder degrades to INT8.
//!
//! The module is a no-op on the hot path when no plan is armed (one
//! relaxed atomic load).

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::RwLock;
use std::time::Duration;

use anyhow::{bail, Result};

/// Parsed fault plan; `None` fields are un-armed.
#[derive(Debug, Clone, Default, PartialEq)]
struct FaultPlan {
    spec: String,
    gemm_panic: Option<f64>,
    gemm_panic_budget: Option<i64>,
    slow_forward: Option<Duration>,
    slow_fp32: Option<Duration>,
}

/// Fast-path gate: false means `plan()` is never consulted.
static ARMED: AtomicBool = AtomicBool::new(false);
/// Remaining `gemm_panic` injections (i64::MAX = unbounded).
static GEMM_BUDGET: AtomicI64 = AtomicI64::new(0);
/// Total faults injected since process start (all kinds).
static INJECTED: AtomicU64 = AtomicU64::new(0);
/// xorshift state for injection probability draws.
static RNG: AtomicU64 = AtomicU64::new(0x9e3779b97f4a7c15);

static PLAN: RwLock<Option<FaultPlan>> = RwLock::new(None);
static ENV_LOADED: AtomicBool = AtomicBool::new(false);

fn parse_duration_ms(v: &str) -> Result<Duration> {
    let digits = v.strip_suffix("ms").unwrap_or(v);
    match digits.parse::<u64>() {
        Ok(ms) => Ok(Duration::from_millis(ms)),
        Err(_) => bail!("expected a millisecond duration like `50ms`, got `{v}`"),
    }
}

fn parse_spec(spec: &str) -> Result<FaultPlan> {
    let mut plan = FaultPlan { spec: spec.to_string(), ..FaultPlan::default() };
    for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
        let mut parts = clause.splitn(3, ':');
        let key = parts.next().unwrap_or("");
        let val = parts.next();
        let budget = parts.next();
        match (key, val) {
            ("gemm_panic", Some(p)) => {
                let p: f64 = p
                    .parse()
                    .map_err(|_| anyhow::anyhow!("gemm_panic expects a probability, got `{clause}`"))?;
                if !(0.0..=1.0).contains(&p) {
                    bail!("gemm_panic probability must be in 0..=1, got {p}");
                }
                plan.gemm_panic = Some(p);
                plan.gemm_panic_budget = match budget {
                    None => None,
                    Some(b) => match b.parse::<i64>() {
                        Ok(n) if n >= 0 => Some(n),
                        _ => bail!("gemm_panic budget must be a non-negative integer, got `{clause}`"),
                    },
                };
            }
            ("slow_forward", Some(v)) => plan.slow_forward = Some(parse_duration_ms(v)?),
            ("slow_fp32", Some(v)) => plan.slow_fp32 = Some(parse_duration_ms(v)?),
            _ => bail!(
                "unknown fault clause `{clause}` (expected gemm_panic:P[:N], \
                 slow_forward:Dms, or slow_fp32:Dms)"
            ),
        }
    }
    Ok(plan)
}

fn install(plan: Option<FaultPlan>) {
    let armed = plan.is_some();
    let budget = plan
        .as_ref()
        .and_then(|p| p.gemm_panic.map(|_| p.gemm_panic_budget.unwrap_or(i64::MAX)))
        .unwrap_or(0);
    GEMM_BUDGET.store(budget, Ordering::SeqCst);
    *PLAN.write().unwrap() = plan;
    ARMED.store(armed, Ordering::SeqCst);
}

fn ensure_env_loaded() {
    if ENV_LOADED.swap(true, Ordering::SeqCst) {
        return;
    }
    if let Ok(spec) = std::env::var("SAMP_FAULT") {
        if !spec.trim().is_empty() {
            match parse_spec(&spec) {
                Ok(plan) => {
                    eprintln!("[fault] SAMP_FAULT armed: {spec}");
                    install(Some(plan));
                }
                Err(e) => eprintln!("[fault] ignoring invalid SAMP_FAULT `{spec}`: {e}"),
            }
        }
    }
}

/// Arm a fault plan at runtime (the `/v1/debug/fault` endpoint).  An empty
/// spec clears every armed fault.
pub fn set_spec(spec: &str) -> Result<()> {
    ensure_env_loaded();
    if spec.trim().is_empty() {
        install(None);
        return Ok(());
    }
    install(Some(parse_spec(spec)?));
    Ok(())
}

/// The currently armed spec (empty string when no plan is armed).
pub fn current_spec() -> String {
    ensure_env_loaded();
    PLAN.read().unwrap().as_ref().map(|p| p.spec.clone()).unwrap_or_default()
}

/// Total faults injected since process start.
pub fn injected_total() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

fn next_f64() -> f64 {
    let mut x = RNG.load(Ordering::Relaxed);
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    RNG.store(x, Ordering::Relaxed);
    (x >> 11) as f64 / (1u64 << 53) as f64
}

/// Should the next threaded GEMM inject a panicking worker job?  Draws the
/// armed probability and decrements the injection budget atomically.
pub fn gemm_panic_armed() -> bool {
    ensure_env_loaded();
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    let p = match PLAN.read().unwrap().as_ref().and_then(|p| p.gemm_panic) {
        Some(p) => p,
        None => return false,
    };
    if next_f64() >= p {
        return false;
    }
    // consume one unit of budget; losing the race means the budget is spent
    if GEMM_BUDGET.fetch_sub(1, Ordering::SeqCst) <= 0 {
        GEMM_BUDGET.store(0, Ordering::SeqCst);
        return false;
    }
    INJECTED.fetch_add(1, Ordering::Relaxed);
    true
}

/// Flat per-forward delay (`slow_forward`), if armed.
pub fn forward_delay() -> Option<Duration> {
    ensure_env_loaded();
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    PLAN.read().unwrap().as_ref().and_then(|p| p.slow_forward)
}

/// Precision-scaled delay (`slow_fp32`): the armed delay times the given
/// fraction of full-precision layers (0.0 = all INT8 = no delay).
pub fn fp32_delay(fp32_fraction: f64) -> Option<Duration> {
    ensure_env_loaded();
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let base = PLAN.read().unwrap().as_ref().and_then(|p| p.slow_fp32)?;
    let scaled = base.mul_f64(fp32_fraction.clamp(0.0, 1.0));
    if scaled.is_zero() {
        None
    } else {
        INJECTED.fetch_add(1, Ordering::Relaxed);
        Some(scaled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_compound_specs() {
        let p = parse_spec("gemm_panic:0.5:3, slow_forward:50ms,slow_fp32:20").unwrap();
        assert_eq!(p.gemm_panic, Some(0.5));
        assert_eq!(p.gemm_panic_budget, Some(3));
        assert_eq!(p.slow_forward, Some(Duration::from_millis(50)));
        assert_eq!(p.slow_fp32, Some(Duration::from_millis(20)));
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(parse_spec("gemm_panic:1.5").is_err());
        assert!(parse_spec("gemm_panic").is_err());
        assert!(parse_spec("slow_forward:abc").is_err());
        assert!(parse_spec("warp_core_breach:1").is_err());
        assert!(parse_spec("gemm_panic:1:-2").is_err());
    }

    #[test]
    fn empty_spec_parses_to_unarmed_plan() {
        let p = parse_spec("").unwrap();
        assert_eq!(p.gemm_panic, None);
        assert_eq!(p.slow_forward, None);
    }
}
