//! Router: task registry + self-adaptive precision selection.
//!
//! The router owns one [`Pipeline`] per task, keyed by the *active* precision
//! variant.  Selection follows §3.2:
//!
//!   1. sweep: evaluate every variant's dev accuracy through the real runtime
//!      and model its T4 latency with the cost model (`latency::`);
//!   2. feed the (accuracy, latency) arrays per mode into the allocator
//!      (Algorithm 1 / Appendix-A thresholds);
//!   3. activate the recommended variant.
//!
//! The sweep result is also exactly the data of Table 2, which is how
//! `examples/self_adaptive.rs` and `bench_table2` regenerate it.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use crate::allocator::{self, Candidate, Requirements};
use crate::config::Manifest;
use crate::data::Dataset;
use crate::latency::{pytorch_fp16_baseline_ms, samp_plan_latency_ms, LayerMode};
use crate::runtime::Runtime;
use crate::tokenizer::{BertTokenizer, Vocab};

use super::pipeline::{EvalReport, Pipeline};

/// One point of the Table-2 sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub variant: String,
    pub quantized_layers: usize,
    pub accuracy: f64,
    /// modeled T4 latency of the encoder at this task's serving shape (ms)
    pub model_latency_ms: f64,
    /// speedup vs the modeled PyTorch-FP16 baseline (the Table-2 convention)
    pub speedup_vs_pytorch_fp16: f64,
    /// local wall-clock per batch (diagnostics)
    pub cpu_batch_ms: f64,
}

/// Task registry + active pipelines.
pub struct Router {
    pub runtime: Arc<Runtime>,
    pub manifest: Manifest,
    pub tokenizer: Arc<BertTokenizer>,
    active: RwLock<HashMap<String, Arc<Pipeline>>>,
}

impl Router {
    pub fn new(runtime: Arc<Runtime>, manifest: Manifest) -> Result<Router> {
        let vocab = Vocab::load(manifest.path(&manifest.vocab))?;
        let tokenizer = Arc::new(BertTokenizer::new(vocab));
        Ok(Router { runtime, manifest, tokenizer, active: RwLock::new(HashMap::new()) })
    }

    pub fn tasks(&self) -> Vec<String> {
        self.manifest.models.iter().map(|m| m.task.clone()).collect()
    }

    /// Activate `variant` for `task` (loads + compiles on first use).
    pub fn activate(&self, task: &str, variant: &str) -> Result<Arc<Pipeline>> {
        let p = Arc::new(Pipeline::load(&self.runtime, &self.manifest, task,
                                        variant, self.tokenizer.clone())?);
        self.active.write().unwrap().insert(task.to_string(), p.clone());
        Ok(p)
    }

    /// The pipeline currently serving `task` (activating fp16 by default).
    ///
    /// Steady state is a read lock only.  On a cold task the default variant
    /// loads outside any lock, then inserts double-checked: if a concurrent
    /// caller (or an explicit `activate`) won the race, their pipeline wins
    /// and our redundant load is dropped — default activation never clobbers
    /// an explicitly activated variant.
    pub fn pipeline(&self, task: &str) -> Result<Arc<Pipeline>> {
        if let Some(p) = self.active.read().unwrap().get(task) {
            return Ok(p.clone());
        }
        let p = Arc::new(Pipeline::load(&self.runtime, &self.manifest, task,
                                        "fp16", self.tokenizer.clone())?);
        let mut active = self.active.write().unwrap();
        Ok(active.entry(task.to_string()).or_insert(p).clone())
    }

    /// The pipeline currently active for `task`, if any (no default
    /// activation side effect — `/v1/plan` reads through this).
    pub fn active(&self, task: &str) -> Option<Arc<Pipeline>> {
        self.active.read().unwrap().get(task).cloned()
    }

    /// Load `variant` of `task` under a replica-private native weight cache
    /// key, without touching the active-pipeline table.  Engine replica sets
    /// duplicate packed native weights (and per-replica GEMM pools, pinned
    /// to `replica`'s core set) through this; see [`Pipeline::load_keyed`].
    pub fn pipeline_replica(&self, task: &str, variant: &str,
                            native_key: &str, replica: usize)
                            -> Result<Arc<Pipeline>> {
        Ok(Arc::new(Pipeline::load_keyed(&self.runtime, &self.manifest, task,
                                         variant, self.tokenizer.clone(),
                                         Some(native_key), replica)?))
    }

    /// Modeled T4 encoder latency for one variant of one task.
    pub fn model_latency_ms(&self, task: &str, variant: &str) -> Result<f64> {
        let spec = self.manifest.model(task)?;
        let vs = spec.variants.get(variant)
            .with_context(|| format!("unknown variant {variant}"))?;
        // the same plan the native backend executes — cost model and
        // compute can never disagree about what a variant means; the shared
        // helper models at BERT-base width (the tiny evaluation model's H=64
        // is launch-dominated and would invert the INT8 gains)
        let plan: Vec<LayerMode> = vs.plan(spec.layers)?;
        Ok(samp_plan_latency_ms(spec.layers, spec.batch, spec.seq_len, &plan))
    }

    /// Modeled PyTorch-FP16 baseline latency (the Table-2 denominator).
    pub fn pytorch_fp16_latency_ms(&self, task: &str) -> Result<f64> {
        let spec = self.manifest.model(task)?;
        Ok(pytorch_fp16_baseline_ms(spec.layers, spec.batch, spec.seq_len))
    }

    /// Modeled **native CPU** encoder latency for one variant of one task,
    /// at the GEMM thread count this runtime was configured with — the cost
    /// model the local serving path actually matches (the T4 model above is
    /// the paper's reporting convention).
    pub fn native_cpu_latency_ms(&self, task: &str, variant: &str)
                                 -> Result<f64> {
        let spec = self.manifest.model(task)?;
        let vs = spec.variants.get(variant)
            .with_context(|| format!("unknown variant {variant}"))?;
        let plan: Vec<LayerMode> = vs.plan(spec.layers)?;
        Ok(crate::latency::native_cpu_plan_latency_ms(
            spec.layers, spec.batch, spec.seq_len, &plan,
            self.runtime.gemm_threads()))
    }

    /// Sweep one mode family ("ffn_only" or "full_quant"), evaluating dev
    /// accuracy through the real runtime.  Returns points ordered by k,
    /// starting with the fp16 baseline (k = 0).
    pub fn sweep(&self, task: &str, mode_prefix: &str, ds: &Dataset,
                 limit: Option<usize>) -> Result<Vec<SweepPoint>> {
        let spec = self.manifest.model(task)?.clone();
        let pt = self.pytorch_fp16_latency_ms(task)?;
        let mut points = Vec::new();
        for vs in spec.sweep(mode_prefix) {
            let pipe = Pipeline::load(&self.runtime, &self.manifest, task,
                                      &vs.name, self.tokenizer.clone())?;
            let report: EvalReport = pipe.evaluate(ds, limit)?;
            let ml = self.model_latency_ms(task, &vs.name)?;
            points.push(SweepPoint {
                variant: vs.name.clone(),
                quantized_layers: vs.quantized_layers(),
                accuracy: report.accuracy,
                model_latency_ms: ml,
                speedup_vs_pytorch_fp16: pt / ml,
                cpu_batch_ms: report.mean_batch_ms,
            });
        }
        Ok(points)
    }

    /// Self-adaptive activation (§3.2 + Appendix A): sweep, allocate,
    /// activate.  Returns (chosen variant, the sweep for reporting).
    pub fn self_adapt(&self, task: &str, mode_prefix: &str, ds: &Dataset,
                      req: Requirements, limit: Option<usize>)
                      -> Result<(String, Vec<SweepPoint>)> {
        let points = self.sweep(task, mode_prefix, ds, limit)?;
        let cands: Vec<Candidate> = points
            .iter()
            .map(|p| Candidate {
                quantized_layers: p.quantized_layers,
                accuracy: p.accuracy,
                latency_ms: p.model_latency_ms,
            })
            .collect();
        let chosen = allocator::recommend(&cands, req)?;
        let variant = points
            .iter()
            .find(|p| p.quantized_layers == chosen.quantized_layers)
            .map(|p| p.variant.clone())
            .context("allocator chose unknown point")?;
        self.activate(task, &variant)?;
        Ok((variant, points))
    }
}
