//! L3 coordinator: the serving contribution.
//!
//! Composition (Fig 1 end-to-end, Python never on this path):
//!
//! ```text
//!   HTTP/JSON -> Router -> [per-task Pipeline]
//!     Pipeline: BertTokenizer -> Batcher -> Engine(encoder variant)
//!               -> Engine(head) -> tasks::decode_* -> reply
//! ```
//!
//! * [`batcher`] — dynamic batching to the static AOT shapes.
//! * [`pool`] — reusable tensor blocks; steady-state batch forming does not
//!   allocate.
//! * [`pipeline`] — one task's tokenizer/engines/postprocessing bundle, plus
//!   dev-set evaluation (the Table-2 accuracy column).
//! * [`router`] — task registry + precision-variant selection, including the
//!   allocator-driven self-adaptive mode (§3.2) and the sweep used by
//!   `examples/self_adaptive.rs`.

pub mod batcher;
pub mod pipeline;
pub mod pool;
pub mod router;

pub use batcher::{Batcher, FormedBatch};
pub use pipeline::{EvalReport, Pipeline, TaskOutput};
pub use pool::BlockPool;
pub use router::{Router, SweepPoint};
