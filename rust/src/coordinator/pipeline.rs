//! One task's inference pipeline: tokenizer -> encoder variant -> head ->
//! decode.  Also hosts the dev-set evaluator that produces the accuracy
//! column of Table 2 through the *real* runtime (compiled HLO, not python).
//!
//! Backend selection happens here: if the variant's HLO artifact exists the
//! pipeline runs on PJRT engines; otherwise it runs on the in-tree native
//! backend (`backend::native`) with the variant's per-layer precision plan.
//! Callers never see the difference — both sides are `Arc<dyn Backend>`.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::backend::native::{KernelInfo, NativeEncoder, NativeHead,
                             NativeModel};
use crate::config::{Manifest, ModelSpec};
use crate::data::Dataset;
use crate::latency::LayerMode;
use crate::metrics::{accuracy, token_accuracy};
use crate::runtime::{Backend, EncoderBatch, Runtime};
use crate::tasks::{decode_classification, decode_matching, decode_ner_row,
                   Classification, Entity, Matching};
use crate::tokenizer::{BertTokenizer, Encoding};

/// Decoded output of one request.
#[derive(Debug, Clone)]
pub enum TaskOutput {
    Classification(Classification),
    Matching(Matching),
    Ner(Vec<Entity>),
}

/// Evaluation result for one (task, variant).
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub task: String,
    pub variant: String,
    pub n: usize,
    pub accuracy: f64,
    /// wall-clock per batch through the local runtime (diagnostics; the
    /// Table-2 speedup column comes from the T4 cost model)
    pub mean_batch_ms: f64,
}

/// A loaded (encoder variant + head) pair for one task.
pub struct Pipeline {
    pub spec: ModelSpec,
    pub variant: String,
    pub tokenizer: Arc<BertTokenizer>,
    /// The variant's per-layer precision plan (what `/v1/plan` reports).
    plan: Vec<LayerMode>,
    /// Activation-quantization source per layer: "static"/"dynamic"/
    /// "mixed(n/m)"/"-" on native, "baked" on PJRT (scales live in the HLO).
    act_quant: Vec<String>,
    /// Native-backend kernel identity (ISA rung, GEMM threads, observed
    /// pinning) — `None` on PJRT, surfaced on `/v1/models`.
    kernel: Option<KernelInfo>,
    encoder: Arc<dyn Backend>,
    head: Arc<dyn Backend>,
}

impl Pipeline {
    /// Load `variant` of `task` through the runtime caches.  PJRT when the
    /// variant's HLO artifact exists on disk, the native backend otherwise
    /// (exported weights file if the manifest names one, deterministic
    /// synthetic weights as the last resort).
    pub fn load(rt: &Runtime, manifest: &Manifest, task: &str, variant: &str,
                tokenizer: Arc<BertTokenizer>) -> Result<Pipeline> {
        Self::load_keyed(rt, manifest, task, variant, tokenizer, None, 0)
    }

    /// Like [`Pipeline::load`], but native weights are cached under
    /// `native_key` instead of the task name, and built for replica index
    /// `replica`.  Engine replica sets (`registry::ReplicaSet`) use this to
    /// give each replica its **own** packed copy of the weights — distinct
    /// cache keys build distinct `NativeModel`s, so a lane's dispatcher
    /// workers stop contending on one weight copy — and its own GEMM worker
    /// pool pinned to the replica's `--pin-cores` core set.  The PJRT engine
    /// cache is path-keyed and unaffected (replicas of a PJRT lane share the
    /// compiled executable).
    pub fn load_keyed(rt: &Runtime, manifest: &Manifest, task: &str,
                      variant: &str, tokenizer: Arc<BertTokenizer>,
                      native_key: Option<&str>, replica: usize)
                      -> Result<Pipeline> {
        let spec = manifest.model(task)?.clone();
        let vs = spec
            .variants
            .get(variant)
            .with_context(|| format!("task {task}: unknown variant {variant}"))?;
        let hlo = manifest.path(&vs.hlo);
        let plan = vs.plan(spec.layers)?;
        let (encoder, head, act_quant, kernel): (Arc<dyn Backend>,
                                                 Arc<dyn Backend>,
                                                 Vec<String>,
                                                 Option<KernelInfo>) =
            if hlo.exists() {
                let encoder: Arc<dyn Backend> = rt.load(&hlo)?;
                let head: Arc<dyn Backend> =
                    rt.load(manifest.path(&spec.head_hlo))?;
                // PJRT artifacts carry calibration scales as HLO constants
                (encoder, head, vec!["baked".to_string(); spec.layers], None)
            } else {
                let weights_path =
                    spec.weights.as_ref().map(|w| manifest.path(w));
                let model = rt.native_model_for_replica(
                    native_key.unwrap_or(task), replica, || {
                        NativeModel::for_spec(&spec, weights_path.as_deref(),
                                              manifest.vocab_size)
                    })?;
                let act_quant = model.act_quant_modes(&plan);
                let kernel = model.kernel_info();
                if plan.iter().any(|m| m.is_int8()) {
                    let pins: Vec<String> = kernel
                        .pinned
                        .iter()
                        .map(|p| match p {
                            Some(c) => c.to_string(),
                            None => "-".to_string(),
                        })
                        .collect();
                    eprintln!("[native] {task}/{variant}: {} INT8 layer(s), \
                               isa={} gemm_threads={} pinned=[{}], \
                               activation scales per layer: [{}]",
                              plan.iter().filter(|m| m.is_int8()).count(),
                              kernel.isa, kernel.threads, pins.join(","),
                              act_quant.join(", "));
                }
                let encoder: Arc<dyn Backend> =
                    Arc::new(NativeEncoder::new(model.clone(), plan.clone())?);
                let head: Arc<dyn Backend> = Arc::new(NativeHead::new(model));
                (encoder, head, act_quant, Some(kernel))
            };
        Ok(Pipeline {
            spec,
            variant: variant.to_string(),
            tokenizer,
            plan,
            act_quant,
            kernel,
            encoder,
            head,
        })
    }

    /// Native kernel identity (`None` when this pipeline runs on PJRT).
    pub fn kernel_info(&self) -> Option<&KernelInfo> {
        self.kernel.as_ref()
    }

    /// Whether this pipeline's native GEMM worker pool has been poisoned by
    /// a panicked job (always `false` on PJRT).  A poisoned pipeline rejects
    /// all further threaded GEMMs; the replica self-healing path
    /// (`registry::ReplicaSet::heal`) rebuilds it from scratch.
    pub fn is_poisoned(&self) -> bool {
        self.encoder.is_poisoned() || self.head.is_poisoned()
    }

    /// Which backend serves this pipeline: "pjrt" or "native".
    pub fn backend_name(&self) -> &'static str {
        self.encoder.backend_name()
    }

    /// The active per-layer precision plan of this pipeline's variant.
    pub fn plan(&self) -> &[LayerMode] {
        &self.plan
    }

    /// Per-layer activation-quantization source (see the `act_quant` field).
    pub fn act_quant(&self) -> &[String] {
        &self.act_quant
    }

    /// Tokenize one request text (tab separates sentence pairs).  Uses the
    /// lean encoding path: the serving hot path never reads surface-token
    /// strings, so they are not materialized.
    pub fn encode_text(&self, text: &str) -> Encoding {
        self.tokenizer.encode_request_lean(text, self.spec.seq_len)
    }

    /// Run one padded batch through encoder + head; returns logits.
    pub fn run_block(&self, block: &EncoderBatch) -> Result<Vec<f32>> {
        let hidden = self.encoder.run_encoder(block)?;
        self.head
            .run_head(&hidden, block.batch, block.seq, self.spec.hidden)
    }

    /// Decode one row of a batch's logits, independently of every other
    /// row.  This is the dispatcher's streaming-completion unit: a row's
    /// reply fires as soon as *its* decode finishes — NER rows walk their
    /// own BIO tags, classification/matching rows soft-max their own logits
    /// slice — so batch mates never gate each other past the engine call.
    pub fn decode_row(&self, logits: &[f32], block: &EncoderBatch, row: usize)
                      -> TaskOutput {
        assert!(row < block.batch, "row {row} out of batch {}", block.batch);
        let nl = self.spec.num_labels;
        let seq = block.seq;
        match self.spec.head_type.as_str() {
            "matching" => {
                let out = decode_matching(&logits[row * nl..(row + 1) * nl], nl)
                    .pop()
                    .expect("one row in, one row out");
                TaskOutput::Matching(out)
            }
            "ner" => {
                let lrow = &logits[row * seq * nl..(row + 1) * seq * nl];
                let mrow = &block.attention_mask[row * seq..(row + 1) * seq];
                TaskOutput::Ner(decode_ner_row(lrow, nl, mrow,
                                               &self.spec.ner_labels))
            }
            _ => {
                let out = decode_classification(
                    &logits[row * nl..(row + 1) * nl], nl, 3)
                    .pop()
                    .expect("one row in, one row out");
                TaskOutput::Classification(out)
            }
        }
    }

    /// Decode logits for `rows` real rows of a batch (row-by-row under the
    /// hood — see [`Pipeline::decode_row`]).
    pub fn decode(&self, logits: &[f32], block: &EncoderBatch, rows: usize)
                  -> Vec<TaskOutput> {
        (0..rows.min(block.batch))
            .map(|r| self.decode_row(logits, block, r))
            .collect()
    }

    /// Single-request convenience (tokenize, pad to a 1-row batch, decode).
    pub fn infer_text(&self, text: &str) -> Result<TaskOutput> {
        let enc = self.encode_text(text);
        let mut block = EncoderBatch::zeros(self.spec.batch, self.spec.seq_len);
        block.set_row(0, &enc.ids, &enc.segment_ids, &enc.attention_mask);
        let logits = self.run_block(&block)?;
        self.decode(&logits, &block, 1)
            .into_iter()
            .next()
            .context("empty decode")
    }

    /// Evaluate on the pre-tokenized dev set: the Table-2 accuracy column
    /// through the real compiled artifacts.  `limit` bounds examples (the
    /// full sweep over 14 variants is expensive on 1 CPU).
    pub fn evaluate(&self, ds: &Dataset, limit: Option<usize>) -> Result<EvalReport> {
        if ds.seq != self.spec.seq_len {
            bail!("dataset seq {} != model seq {}", ds.seq, self.spec.seq_len);
        }
        let n = limit.unwrap_or(ds.n).min(ds.n);
        let b = self.spec.batch;
        let batches = n / b;
        let mut preds: Vec<usize> = Vec::with_capacity(batches * b);
        let mut tok_pred: Vec<usize> = Vec::new();
        let mut tok_gold: Vec<i32> = Vec::new();
        let mut tok_mask: Vec<i32> = Vec::new();
        let mut total_ms = 0.0;
        for bi in 0..batches {
            let mut block = EncoderBatch::zeros(b, ds.seq);
            for r in 0..b {
                let i = bi * b + r;
                block.set_row(r, ds.row_ids(i), ds.row_segs(i), ds.row_mask(i));
            }
            let t = crate::util::Stopwatch::start();
            let logits = self.run_block(&block)?;
            total_ms += t.elapsed_ms();
            if self.spec.head_type == "ner" {
                let nl = self.spec.num_labels;
                for r in 0..b {
                    let i = bi * b + r;
                    for s in 0..ds.seq {
                        let row = &logits[(r * ds.seq + s) * nl
                            ..(r * ds.seq + s + 1) * nl];
                        tok_pred.push(crate::tasks::argmax(row));
                    }
                    tok_gold.extend_from_slice(ds.row_labels(i));
                    tok_mask.extend_from_slice(ds.row_mask(i));
                }
            } else {
                let nl = self.spec.num_labels;
                for r in 0..b {
                    let row = &logits[r * nl..(r + 1) * nl];
                    preds.push(crate::tasks::argmax(row));
                }
            }
        }
        let acc = if self.spec.head_type == "ner" {
            token_accuracy(&tok_pred, &tok_gold, &tok_mask)
        } else {
            let gold: Vec<i32> = (0..batches * b).map(|i| ds.label(i)).collect();
            accuracy(&preds, &gold)
        };
        Ok(EvalReport {
            task: self.spec.task.clone(),
            variant: self.variant.clone(),
            n: batches * b,
            accuracy: acc,
            mean_batch_ms: if batches > 0 { total_ms / batches as f64 } else { 0.0 },
        })
    }
}
