//! Dynamic batcher: collects per-request encodings into fixed-shape batches.
//!
//! The AOT executables have static [batch, seq] shapes, so the batcher's job
//! is the vLLM-router-style tradeoff: wait briefly to fill a batch (higher
//! throughput) vs dispatch a partial, padded batch (lower latency).  Policy:
//! dispatch when `batch` rows are waiting, or when the oldest row has waited
//! `timeout`; padding rows are zeros with an all-zero attention mask, which
//! the encoder treats as fully-masked no-ops.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::runtime::EncoderBatch;
use crate::tokenizer::Encoding;

/// One enqueued request.
#[derive(Debug)]
pub struct Pending<T> {
    pub encoding: Encoding,
    /// caller's completion handle (oneshot sender equivalent)
    pub reply: T,
    pub enqueued: Instant,
}

/// A formed batch: the padded tensor block + reply handles row by row.
pub struct FormedBatch<T> {
    pub block: EncoderBatch,
    /// reply handle + row index for each real (non-padding) row
    pub replies: Vec<T>,
    /// number of real rows (<= block.batch)
    pub rows: usize,
    /// queueing delay of the oldest member
    pub oldest_wait: Duration,
}

/// Thread-safe dynamic batching queue.
pub struct Batcher<T> {
    inner: Mutex<VecDeque<Pending<T>>>,
    cv: Condvar,
    pub batch: usize,
    pub seq: usize,
    pub timeout: Duration,
    closed: Mutex<bool>,
}

impl<T> Batcher<T> {
    pub fn new(batch: usize, seq: usize, timeout: Duration) -> Self {
        Batcher {
            inner: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            batch,
            seq,
            timeout,
            closed: Mutex::new(false),
        }
    }

    /// Enqueue one encoded request.
    pub fn push(&self, encoding: Encoding, reply: T) {
        assert_eq!(encoding.ids.len(), self.seq, "encoding seq mismatch");
        let mut q = self.inner.lock().unwrap();
        q.push_back(Pending { encoding, reply, enqueued: Instant::now() });
        self.cv.notify_one();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Shut down: wakes all waiters; `next_batch` returns None once drained.
    pub fn close(&self) {
        *self.closed.lock().unwrap() = true;
        self.cv.notify_all();
    }

    /// Worker loop call: block until a full batch or the timeout expires with
    /// at least one request; None after close() with an empty queue.
    pub fn next_batch(&self) -> Option<FormedBatch<T>> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if q.len() >= self.batch {
                return Some(self.form(&mut q));
            }
            if !q.is_empty() {
                let oldest = q.front().unwrap().enqueued;
                let elapsed = oldest.elapsed();
                if elapsed >= self.timeout {
                    return Some(self.form(&mut q));
                }
                // wait the residual timeout (or new arrivals)
                let (guard, _t) = self
                    .cv
                    .wait_timeout(q, self.timeout - elapsed)
                    .unwrap();
                q = guard;
            } else {
                if *self.closed.lock().unwrap() {
                    return None;
                }
                q = self.cv.wait(q).unwrap();
            }
        }
    }

    fn form(&self, q: &mut VecDeque<Pending<T>>) -> FormedBatch<T> {
        let rows = q.len().min(self.batch);
        let mut block = EncoderBatch::zeros(self.batch, self.seq);
        let mut replies = Vec::with_capacity(rows);
        let mut oldest = Duration::ZERO;
        for row in 0..rows {
            let p = q.pop_front().unwrap();
            block.set_row(row, &p.encoding.ids, &p.encoding.segment_ids,
                          &p.encoding.attention_mask);
            oldest = oldest.max(p.enqueued.elapsed());
            replies.push(p.reply);
        }
        FormedBatch { block, replies, rows, oldest_wait: oldest }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn enc(seq: usize, fill: i32) -> Encoding {
        Encoding {
            ids: vec![fill; seq],
            segment_ids: vec![0; seq],
            attention_mask: vec![1; seq],
            tokens: vec![],
        }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let b: Batcher<usize> = Batcher::new(2, 4, Duration::from_secs(10));
        b.push(enc(4, 1), 100);
        b.push(enc(4, 2), 200);
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 2);
        assert_eq!(fb.replies, vec![100, 200]);
        assert_eq!(&fb.block.ids[..4], &[1, 1, 1, 1]);
        assert_eq!(&fb.block.ids[4..], &[2, 2, 2, 2]);
    }

    #[test]
    fn timeout_dispatches_partial_batch() {
        let b: Batcher<usize> = Batcher::new(8, 4, Duration::from_millis(20));
        b.push(enc(4, 7), 1);
        let t0 = Instant::now();
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 1);
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // padding rows are fully masked
        assert!(fb.block.attention_mask[4..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn fifo_order_preserved() {
        let b: Batcher<usize> = Batcher::new(3, 2, Duration::from_millis(5));
        for i in 0..3 {
            b.push(enc(2, i), i as usize);
        }
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.replies, vec![0, 1, 2]);
    }

    #[test]
    fn close_unblocks_empty_queue() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(4, 2,
                                                           Duration::from_millis(5)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch().is_none());
        std::thread::sleep(Duration::from_millis(30));
        b.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(4, 2,
                                                           Duration::from_millis(2)));
        let n = 103usize;
        let prod = {
            let b = b.clone();
            std::thread::spawn(move || {
                for i in 0..n {
                    b.push(enc(2, i as i32), i);
                }
                b.close();
            })
        };
        let mut seen = Vec::new();
        while let Some(fb) = b.next_batch() {
            assert!(fb.rows >= 1 && fb.rows <= 4);
            seen.extend(fb.replies);
        }
        prod.join().unwrap();
        seen.sort();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
    }
}
