//! Dynamic batcher: collects per-request encodings into fixed-shape batches.
//!
//! The AOT executables have static [batch, seq] shapes, so the batcher's job
//! is the vLLM-router-style tradeoff: wait briefly to fill a batch (higher
//! throughput) vs dispatch a partial, padded batch (lower latency).  Policy:
//! dispatch when `batch` rows are waiting, or when the oldest row has waited
//! `timeout`; padding rows are zeros with an all-zero attention mask, which
//! the encoder treats as fully-masked no-ops.
//!
//! Hot-path discipline:
//!
//! * queue and `closed` flag live under a *single* mutex with one condvar, so
//!   a `push` racing `close` either lands before the close (and is drained)
//!   or fails fast, handing the reply handle back to the caller — a request
//!   can never be stranded in a closed queue;
//! * formed batches borrow their tensor block from a [`BlockPool`] instead of
//!   allocating; the dispatcher returns it via [`Batcher::recycle`] after the
//!   engine runs, making steady-state batch forming allocation-free;
//! * admission control: the queue depth is capped
//!   ([`Batcher::with_queue_depth`]); pushes beyond the cap are *shed* with
//!   a typed [`PushError::Overloaded`] the server maps to HTTP 429, so
//!   overload degrades into fast rejections instead of unbounded memory
//!   growth and ever-worse tail latency.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::pool::BlockPool;
use crate::runtime::EncoderBatch;
use crate::tokenizer::Encoding;

/// Why a `push` was rejected.  Either way the reply handle comes back so
/// the caller can answer the request itself instead of leaking a waiter.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// The batcher is shut down.
    Closed(T),
    /// The queue is at its admission-control depth cap; the request was
    /// shed.  Callers should answer 429 / retry-later.
    Overloaded(T),
}

impl<T> PushError<T> {
    /// Recover the reply handle.
    pub fn into_reply(self) -> T {
        match self {
            PushError::Closed(t) | PushError::Overloaded(t) => t,
        }
    }

    pub fn is_overloaded(&self) -> bool {
        matches!(self, PushError::Overloaded(_))
    }
}

/// One enqueued request.
#[derive(Debug)]
pub struct Pending<T> {
    pub encoding: Encoding,
    /// caller's completion handle (oneshot sender equivalent)
    pub reply: T,
    pub enqueued: Instant,
}

/// A formed batch: the padded tensor block + reply handles row by row.
/// The block is on loan from the batcher's pool — give it back with
/// [`Batcher::recycle`] once the engine is done with it.
pub struct FormedBatch<T> {
    pub block: EncoderBatch,
    /// reply handle + row index for each real (non-padding) row
    pub replies: Vec<T>,
    /// number of real rows (<= block.batch)
    pub rows: usize,
    /// queueing delay of the oldest member
    pub oldest_wait: Duration,
}

/// Queue state guarded by one mutex: folding `closed` in here is what makes
/// the close/push race benign.
struct Shared<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
}

/// Thread-safe dynamic batching queue.
pub struct Batcher<T> {
    state: Mutex<Shared<T>>,
    cv: Condvar,
    pub batch: usize,
    pub seq: usize,
    pub timeout: Duration,
    /// Admission-control cap on queued (not yet formed) requests.
    pub max_depth: usize,
    shed: AtomicU64,
    pool: BlockPool,
}

impl<T> Batcher<T> {
    /// Default queue-depth cap (see [`Batcher::with_queue_depth`]).
    pub const DEFAULT_QUEUE_DEPTH: usize = 1024;

    pub fn new(batch: usize, seq: usize, timeout: Duration) -> Self {
        Self::with_queue_depth(batch, seq, timeout, Self::DEFAULT_QUEUE_DEPTH)
    }

    /// Batcher with an explicit admission-control queue depth (config-driven
    /// on the serving path: `ServerConfig::max_queue_depth`).
    pub fn with_queue_depth(batch: usize, seq: usize, timeout: Duration,
                            max_depth: usize) -> Self {
        assert!(max_depth > 0, "queue depth cap must be positive");
        Batcher {
            state: Mutex::new(Shared { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            batch,
            seq,
            timeout,
            max_depth,
            shed: AtomicU64::new(0),
            pool: BlockPool::new(batch, seq, BlockPool::DEFAULT_CAPACITY),
        }
    }

    /// Enqueue one encoded request.  Rejections are typed and return the
    /// reply handle: [`PushError::Closed`] after `close()`,
    /// [`PushError::Overloaded`] when the queue is at its depth cap (the
    /// push is shed — counted in [`Batcher::shed_count`]).
    pub fn push(&self, encoding: Encoding, reply: T) -> Result<(), PushError<T>> {
        assert_eq!(encoding.ids.len(), self.seq, "encoding seq mismatch");
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(PushError::Closed(reply));
        }
        if s.queue.len() >= self.max_depth {
            drop(s);
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(PushError::Overloaded(reply));
        }
        s.queue.push_back(Pending { encoding, reply, enqueued: Instant::now() });
        self.cv.notify_one();
        Ok(())
    }

    /// Number of pushes shed by admission control since construction.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The block pool backing this batcher (stats surface for `/v1/stats`).
    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// Return a dispatched block for reuse by the next `form`.
    pub fn recycle(&self, block: EncoderBatch) {
        self.pool.put_back(block);
    }

    /// Shut down: wakes all waiters; `next_batch` returns None once drained.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Worker loop call: block until a full batch or the timeout expires with
    /// at least one request; None after close() with an empty queue.  Once
    /// closed, residual requests dispatch immediately (no more batch mates
    /// can arrive, so waiting out the timeout would only delay shutdown).
    pub fn next_batch(&self) -> Option<FormedBatch<T>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if s.queue.len() >= self.batch || (s.closed && !s.queue.is_empty()) {
                return Some(self.form(&mut s.queue));
            }
            if !s.queue.is_empty() {
                let oldest = s.queue.front().unwrap().enqueued;
                let elapsed = oldest.elapsed();
                if elapsed >= self.timeout {
                    return Some(self.form(&mut s.queue));
                }
                // wait the residual timeout (or new arrivals / close)
                let (guard, _t) = self
                    .cv
                    .wait_timeout(s, self.timeout - elapsed)
                    .unwrap();
                s = guard;
            } else {
                if s.closed {
                    return None;
                }
                s = self.cv.wait(s).unwrap();
            }
        }
    }

    fn form(&self, q: &mut VecDeque<Pending<T>>) -> FormedBatch<T> {
        let rows = q.len().min(self.batch);
        let mut block = self.pool.checkout();
        let mut replies = Vec::with_capacity(rows);
        let mut oldest = Duration::ZERO;
        for row in 0..rows {
            let p = q.pop_front().unwrap();
            // masks are prefix-ones: a trailing 1 means the row is full
            // length, so the constant-mask fast path applies
            if p.encoding.attention_mask.last() == Some(&1) {
                block.set_row_unmasked(row, &p.encoding.ids,
                                       &p.encoding.segment_ids);
            } else {
                block.set_row(row, &p.encoding.ids, &p.encoding.segment_ids,
                              &p.encoding.attention_mask);
            }
            oldest = oldest.max(p.enqueued.elapsed());
            replies.push(p.reply);
        }
        // scrub whatever the block's previous batch left beyond our rows
        block.reset_rows(rows);
        FormedBatch { block, replies, rows, oldest_wait: oldest }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn enc(seq: usize, fill: i32) -> Encoding {
        Encoding {
            ids: vec![fill; seq],
            segment_ids: vec![0; seq],
            attention_mask: vec![1; seq],
            tokens: vec![],
        }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let b: Batcher<usize> = Batcher::new(2, 4, Duration::from_secs(10));
        b.push(enc(4, 1), 100).unwrap();
        b.push(enc(4, 2), 200).unwrap();
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 2);
        assert_eq!(fb.replies, vec![100, 200]);
        assert_eq!(&fb.block.ids[..4], &[1, 1, 1, 1]);
        assert_eq!(&fb.block.ids[4..], &[2, 2, 2, 2]);
    }

    #[test]
    fn timeout_dispatches_partial_batch() {
        let b: Batcher<usize> = Batcher::new(8, 4, Duration::from_millis(20));
        b.push(enc(4, 7), 1).unwrap();
        let t0 = Instant::now();
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 1);
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // padding rows are fully masked
        assert!(fb.block.attention_mask[4..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn fifo_order_preserved() {
        let b: Batcher<usize> = Batcher::new(3, 2, Duration::from_millis(5));
        for i in 0..3 {
            b.push(enc(2, i), i as usize).unwrap();
        }
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.replies, vec![0, 1, 2]);
    }

    #[test]
    fn close_unblocks_empty_queue() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(4, 2,
                                                           Duration::from_millis(5)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch().is_none());
        std::thread::sleep(Duration::from_millis(30));
        b.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn push_after_close_returns_reply_handle() {
        let b: Batcher<usize> = Batcher::new(4, 2, Duration::from_millis(5));
        b.close();
        assert_eq!(b.push(enc(2, 1), 42), Err(PushError::Closed(42)));
        assert!(b.is_empty());
        assert!(b.next_batch().is_none());
    }

    /// Admission control: pushes beyond the depth cap are shed with a typed
    /// `Overloaded` rejection carrying the reply handle, counted, and the
    /// queue recovers as soon as a batch drains.
    #[test]
    fn overload_sheds_pushes_and_recovers_after_drain() {
        let b: Batcher<usize> =
            Batcher::with_queue_depth(2, 2, Duration::from_millis(1), 3);
        for i in 0..3 {
            b.push(enc(2, i), i as usize).unwrap();
        }
        // 4th push hits the cap
        let err = b.push(enc(2, 9), 99).unwrap_err();
        assert_eq!(err, PushError::Overloaded(99));
        assert!(err_is_overloaded_reply(err), "reply handle must come back");
        assert_eq!(b.shed_count(), 1);
        assert_eq!(b.len(), 3, "shed push must not enter the queue");
        // drain one 2-row batch -> room again
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 2);
        b.push(enc(2, 5), 100).unwrap();
        assert_eq!(b.shed_count(), 1, "accepted push must not count as shed");
    }

    fn err_is_overloaded_reply(e: PushError<usize>) -> bool {
        e.is_overloaded() && e.into_reply() == 99
    }

    /// Regression for the close/push race: `closed` used to live in its own
    /// mutex, so a push could slip in after close and strand its request.
    /// With the single lock, every accepted push is drained and every
    /// rejected push hands its reply handle back — nothing is lost.
    #[test]
    fn close_push_race_never_strands_a_request() {
        for round in 0..20 {
            let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(
                4, 2, Duration::from_millis(1)));
            let accepted = Arc::new(AtomicUsize::new(0));
            let prod = {
                let b = b.clone();
                let accepted = accepted.clone();
                std::thread::spawn(move || {
                    for i in 0..200usize {
                        if b.push(enc(2, i as i32), i).is_ok() {
                            accepted.fetch_add(1, Ordering::SeqCst);
                        }
                        if i == 50 + round {
                            std::thread::yield_now();
                        }
                    }
                })
            };
            let closer = {
                let b = b.clone();
                std::thread::spawn(move || {
                    std::thread::yield_now();
                    b.close();
                })
            };
            let mut drained = 0usize;
            while let Some(fb) = b.next_batch() {
                drained += fb.rows;
            }
            prod.join().unwrap();
            closer.join().unwrap();
            // late pushes raced ahead of our final next_batch? drain again
            while let Some(fb) = b.next_batch() {
                drained += fb.rows;
            }
            assert_eq!(drained, accepted.load(Ordering::SeqCst),
                       "round {round}: accepted requests must all be drained");
        }
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(4, 2,
                                                           Duration::from_millis(2)));
        let n = 103usize;
        let prod = {
            let b = b.clone();
            std::thread::spawn(move || {
                for i in 0..n {
                    b.push(enc(2, i as i32), i).unwrap();
                }
                b.close();
            })
        };
        let mut seen = Vec::new();
        while let Some(fb) = b.next_batch() {
            assert!(fb.rows >= 1 && fb.rows <= 4);
            seen.extend(fb.replies);
        }
        prod.join().unwrap();
        seen.sort();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn recycled_blocks_are_reused_without_stale_rows() {
        let b: Batcher<usize> = Batcher::new(4, 2, Duration::from_millis(1));
        for i in 0..4 {
            b.push(enc(2, 9), i).unwrap();
        }
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 4);
        b.recycle(fb.block);
        assert_eq!(b.pool().stats(), (0, 1));

        // a 1-row batch on the recycled block: rows 1.. must be clean padding
        b.push(enc(2, 5), 10).unwrap();
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 1);
        assert_eq!(b.pool().stats(), (1, 1), "second form must hit the pool");
        assert_eq!(&fb.block.ids[..2], &[5, 5]);
        assert!(fb.block.ids[2..].iter().all(|&x| x == 0),
                "stale ids leaked into padding rows");
        assert!(fb.block.attention_mask[2..].iter().all(|&m| m == 0.0));
    }
}
