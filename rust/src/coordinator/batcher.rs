//! Dynamic batcher: collects per-request encodings into engine batches.
//!
//! Two forming policies share one queue, one admission-control cap and one
//! block pool:
//!
//! * **fixed** ([`Batcher::new`]) — batches have the lane's static
//!   `[batch, seq]` shape (what AOT-compiled PJRT executables require).
//!   Dispatch when `batch` rows are waiting or the oldest row has waited
//!   `timeout`; padding rows are zeros with an all-zero attention mask.
//! * **continuous** ([`Batcher::continuous`]) — TurboTransformers-style
//!   variable-shape forming for backends without a static-shape constraint
//!   (the native backend).  Each request's *real* token count is rounded up
//!   to a seq-length bucket (multiples of a granularity), and workers form
//!   batches greedily by **token budget**: rows of one bucket pack into a
//!   `[rows, bucket_seq]` block until `rows × bucket_seq` reaches the lane's
//!   `batch × seq` cell budget.  Short rows stop paying for long rows'
//!   padding, and a bucket dispatches the moment it can fill its budget —
//!   no waiting for a fixed block to fill.
//!
//! Starvation-freedom: a ready bucket (budget's worth of rows) dispatches
//! immediately, but the *oldest* queued row's bucket always dispatches once
//! that row has waited `timeout`, so sparse buckets cannot be starved by a
//! busy one.  `next_batch` is safe to call from N dispatcher workers
//! concurrently (the per-lane shard set); forming happens under the queue
//! mutex, so each batch is handed to exactly one worker.
//!
//! Hot-path discipline:
//!
//! * queue and `closed` flag live under a *single* mutex with one condvar, so
//!   a `push` racing `close` either lands before the close (and is drained)
//!   or fails fast, handing the reply handle back to the caller — a request
//!   can never be stranded in a closed queue;
//! * formed batches borrow their tensor block from a [`BlockPool`] instead of
//!   allocating; the dispatcher returns it via [`Batcher::recycle`] after the
//!   engine runs.  Continuous batches reuse the same storage under different
//!   geometries ([`BlockPool::checkout_shaped`]);
//! * admission control: the queue depth is capped
//!   ([`Batcher::with_queue_depth`]); pushes beyond the cap are *shed* with
//!   a typed [`PushError::Overloaded`] the server maps to HTTP 429, so
//!   overload degrades into fast rejections instead of unbounded memory
//!   growth and ever-worse tail latency.  Sheds (and pool traffic) also
//!   report into an optional server-wide [`Counters`] sink
//!   ([`Batcher::with_counters`]) whose totals stay monotonic across lane
//!   rebuilds.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::pool::BlockPool;
use crate::metrics::Counters;
use crate::runtime::EncoderBatch;
use crate::tokenizer::Encoding;

/// Why a `push` was rejected.  Either way the reply handle comes back so
/// the caller can answer the request itself instead of leaking a waiter.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// The batcher is shut down.
    Closed(T),
    /// The queue is at its admission-control depth cap; the request was
    /// shed.  Callers should answer 429 / retry-later.
    Overloaded(T),
}

impl<T> PushError<T> {
    /// Recover the reply handle.
    pub fn into_reply(self) -> T {
        match self {
            PushError::Closed(t) | PushError::Overloaded(t) => t,
        }
    }

    pub fn is_overloaded(&self) -> bool {
        matches!(self, PushError::Overloaded(_))
    }
}

/// One enqueued request.
#[derive(Debug)]
pub struct Pending<T> {
    pub encoding: Encoding,
    /// caller's completion handle (oneshot sender equivalent)
    pub reply: T,
    pub enqueued: Instant,
    /// Real token count (position of the last unmasked token + 1) — the
    /// continuous policy's bucketing key.
    pub len: usize,
    /// Absolute completion deadline; rows past it at form time are dropped
    /// *before* the forward pass and their reply handles surface in
    /// [`FormedBatch::expired`] (the server answers them 504 per-row).
    pub deadline: Option<Instant>,
}

/// A formed batch: the padded tensor block + reply handles row by row.
/// The block is on loan from the batcher's pool — give it back with
/// [`Batcher::recycle`] once the engine is done with it.
///
/// Under the continuous policy the block's shape is `[rows, bucket_seq]`
/// (every row real, no padding rows); under the fixed policy it is the
/// lane's static `[batch, seq]` with `rows` real rows up front.
pub struct FormedBatch<T> {
    pub block: EncoderBatch,
    /// reply handle + row index for each real (non-padding) row
    pub replies: Vec<T>,
    /// number of real rows (<= block.batch)
    pub rows: usize,
    /// queueing delay of the oldest member
    pub oldest_wait: Duration,
    /// per-row queueing delay, parallel to `replies` (stage tracing)
    pub waits: Vec<Duration>,
    /// wall time `form()` spent assembling this block (stage tracing;
    /// shared by every row of the batch)
    pub form_time: Duration,
    /// Reply handles of rows whose deadline expired while queued: they are
    /// **not** in the block (no batch slot, no forward cost) and must be
    /// answered with a deadline-exceeded error.  A batch may consist solely
    /// of expired rows (`rows == 0`) — dispatchers skip the engine then.
    pub expired: Vec<T>,
}

/// Outcome of one bounded wait on the queue ([`Batcher::next_batch_timeout`]):
/// either a batch formed, the wait elapsed with nothing formable (the
/// caller's cue to go look for stealable work elsewhere), or the batcher is
/// closed *and* drained.
pub enum BatchWait<T> {
    Formed(FormedBatch<T>),
    Idle,
    Closed,
}

/// Queue state guarded by one mutex: folding `closed` in here is what makes
/// the close/push race benign.
struct Shared<T> {
    queue: VecDeque<Pending<T>>,
    /// Queued rows per seq-length bucket (continuous mode only; indexed by
    /// `(bucket_seq - 1) / granularity`).  Maintained incrementally on
    /// push/form so readiness checks are O(#buckets) with no allocation and
    /// no queue rescan under the lock.
    bucket_counts: Vec<usize>,
    closed: bool,
}

/// Thread-safe dynamic batching queue.
pub struct Batcher<T> {
    state: Mutex<Shared<T>>,
    cv: Condvar,
    pub batch: usize,
    pub seq: usize,
    pub timeout: Duration,
    /// Admission-control cap on queued (not yet formed) requests.
    pub max_depth: usize,
    /// Continuous-batching seq-length bucket granularity; `None` = fixed
    /// `[batch, seq]` forming.
    bucket: Option<usize>,
    shed: AtomicU64,
    /// Server-wide aggregate counters (sheds; the pool reports its own
    /// hits/misses through the same sink).
    counters: Option<Arc<Counters>>,
    pool: BlockPool,
}

impl<T> Batcher<T> {
    /// Default queue-depth cap (see [`Batcher::with_queue_depth`]).
    pub const DEFAULT_QUEUE_DEPTH: usize = 1024;

    pub fn new(batch: usize, seq: usize, timeout: Duration) -> Self {
        Self::with_queue_depth(batch, seq, timeout, Self::DEFAULT_QUEUE_DEPTH)
    }

    /// Fixed-shape batcher with an explicit admission-control queue depth
    /// (config-driven on the serving path: `ServerConfig::max_queue_depth`).
    pub fn with_queue_depth(batch: usize, seq: usize, timeout: Duration,
                            max_depth: usize) -> Self {
        assert!(max_depth > 0, "queue depth cap must be positive");
        Batcher {
            state: Mutex::new(Shared {
                queue: VecDeque::new(),
                bucket_counts: Vec::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            batch,
            seq,
            timeout,
            max_depth,
            bucket: None,
            shed: AtomicU64::new(0),
            counters: None,
            pool: BlockPool::new(batch, seq, BlockPool::DEFAULT_CAPACITY),
        }
    }

    /// Continuous batcher: token-budget forming over seq-length buckets of
    /// `granularity` tokens (clamped to `[1, seq]`).  `batch * seq` is the
    /// per-batch *cell* budget, not a row count — a bucket of short rows
    /// packs more rows than `batch`.
    pub fn continuous(batch: usize, seq: usize, timeout: Duration,
                      max_depth: usize, granularity: usize) -> Self {
        let mut b = Self::with_queue_depth(batch, seq, timeout, max_depth);
        let g = granularity.clamp(1, seq.max(1));
        b.bucket = Some(g);
        b.state.get_mut().unwrap().bucket_counts =
            vec![0; seq.max(1).div_ceil(g)];
        b
    }

    /// Default bucket granularity for a lane of `seq`: eight buckets across
    /// the sequence range (at least 1 token).
    pub fn default_granularity(seq: usize) -> usize {
        (seq / 8).max(1)
    }

    /// Report sheds and pool traffic into a server-wide [`Counters`]
    /// aggregate as well as this batcher's local stats.
    pub fn with_counters(mut self, counters: Arc<Counters>) -> Self {
        self.pool.set_sink(counters.clone());
        self.counters = Some(counters);
        self
    }

    /// Whether this batcher forms variable-shape token-budget batches.
    pub fn is_continuous(&self) -> bool {
        self.bucket.is_some()
    }

    /// Seq-length bucket a row of `len` real tokens lands in: `len` rounded
    /// up to the granularity, capped at the lane seq.  Fixed mode has a
    /// single bucket — the full seq.
    fn bucket_seq(&self, len: usize) -> usize {
        match self.bucket {
            None => self.seq,
            Some(g) => len.max(1).div_ceil(g).saturating_mul(g).min(self.seq),
        }
    }

    /// `bucket_counts` slot of bucket width `bs` (continuous mode; the
    /// mapping is bijective on realizable widths, including the capped
    /// `seq` bucket when `seq` is not a granularity multiple).
    fn bucket_index(&self, bs: usize, g: usize) -> usize {
        debug_assert_eq!(bs, self.bucket_seq(bs));
        (bs - 1) / g
    }

    /// Inverse of [`Batcher::bucket_index`].
    fn index_bucket(&self, idx: usize, g: usize) -> usize {
        ((idx + 1) * g).min(self.seq)
    }

    /// Rows a `[*, bucket_seq]` batch may pack under the cell budget.
    fn budget_rows(&self, bucket_seq: usize) -> usize {
        ((self.batch * self.seq) / bucket_seq.max(1)).max(1)
    }

    /// Enqueue one encoded request.  Rejections are typed and return the
    /// reply handle: [`PushError::Closed`] after `close()`,
    /// [`PushError::Overloaded`] when the queue is at its depth cap (the
    /// push is shed — counted in [`Batcher::shed_count`]).
    pub fn push(&self, encoding: Encoding, reply: T) -> Result<(), PushError<T>> {
        self.push_with_deadline(encoding, reply, None)
    }

    /// [`Batcher::push`] with an absolute completion deadline: if the row is
    /// still queued when its bucket forms past `deadline`, it is dropped
    /// before the forward pass and its handle lands in
    /// [`FormedBatch::expired`].
    pub fn push_with_deadline(&self, encoding: Encoding, reply: T,
                              deadline: Option<Instant>)
                              -> Result<(), PushError<T>> {
        assert_eq!(encoding.ids.len(), self.seq, "encoding seq mismatch");
        let len = encoding
            .attention_mask
            .iter()
            .rposition(|&m| m != 0)
            .map_or(1, |p| p + 1);
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(PushError::Closed(reply));
        }
        if s.queue.len() >= self.max_depth {
            drop(s);
            self.shed.fetch_add(1, Ordering::Relaxed);
            if let Some(c) = &self.counters {
                c.inc_shed();
            }
            return Err(PushError::Overloaded(reply));
        }
        if let Some(g) = self.bucket {
            let idx = self.bucket_index(self.bucket_seq(len), g);
            s.bucket_counts[idx] += 1;
        }
        s.queue.push_back(Pending {
            encoding,
            reply,
            enqueued: Instant::now(),
            len,
            deadline,
        });
        self.cv.notify_one();
        Ok(())
    }

    /// Number of pushes shed by admission control since construction.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The block pool backing this batcher (stats surface for `/v1/stats`).
    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// Return a dispatched block for reuse by the next `form`.
    pub fn recycle(&self, block: EncoderBatch) {
        self.pool.put_back(block);
    }

    /// Shut down: wakes all waiters; `next_batch` returns None once drained.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Whether `close()` has been called (lane controllers poll this to
    /// know when to exit).
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// The narrowest bucket that can fill its row budget right now, from
    /// the incrementally-maintained per-bucket counts (O(#buckets), no
    /// allocation, no queue rescan).  Fixed mode: the full seq, once
    /// `batch` rows wait.
    fn ready_bucket(&self, s: &Shared<T>) -> Option<usize> {
        match self.bucket {
            None => (s.queue.len() >= self.batch).then_some(self.seq),
            Some(g) => {
                for (idx, &n) in s.bucket_counts.iter().enumerate() {
                    let bs = self.index_bucket(idx, g);
                    if n >= self.budget_rows(bs) {
                        return Some(bs);
                    }
                }
                None
            }
        }
    }

    /// Worker loop call: block until some bucket fills its budget or the
    /// oldest row's wait expires with at least one request; None after
    /// close() with an empty queue.  Once closed, residual requests dispatch
    /// immediately (no more batch mates can arrive, so waiting out the
    /// timeout would only delay shutdown).  Safe to call from N workers
    /// concurrently — each formed batch goes to exactly one caller.
    pub fn next_batch(&self) -> Option<FormedBatch<T>> {
        let mut s = self.state.lock().unwrap();
        loop {
            let bucket = if s.closed && !s.queue.is_empty() {
                // drain: oldest row's bucket
                Some(self.bucket_seq(s.queue.front().unwrap().len))
            } else {
                self.ready_bucket(&s)
            };
            if let Some(bs) = bucket {
                let fb = self.form(&mut s, bs);
                // more ready work? hand it to a sibling worker right away
                if self.ready_bucket(&s).is_some() {
                    self.cv.notify_one();
                }
                return Some(fb);
            }
            if !s.queue.is_empty() {
                let oldest = s.queue.front().unwrap().enqueued;
                let elapsed = oldest.elapsed();
                if elapsed >= self.timeout {
                    // timeout: dispatch the oldest row's bucket, partial
                    let bs = self.bucket_seq(s.queue.front().unwrap().len);
                    return Some(self.form(&mut s, bs));
                }
                // wait the residual timeout (or new arrivals / close)
                let (guard, _t) = self
                    .cv
                    .wait_timeout(s, self.timeout - elapsed)
                    .unwrap();
                s = guard;
            } else {
                if s.closed {
                    return None;
                }
                s = self.cv.wait(s).unwrap();
            }
        }
    }

    /// Bounded-wait variant of [`Batcher::next_batch`] for elastic (work-
    /// stealing) dispatch loops: identical forming semantics — ready
    /// buckets dispatch immediately, the oldest row's bucket dispatches
    /// partial at `timeout`, residual rows drain after `close()` — but the
    /// call returns [`BatchWait::Idle`] once `wait` elapses with nothing
    /// formable, instead of blocking until work arrives.
    pub fn next_batch_timeout(&self, wait: Duration) -> BatchWait<T> {
        let deadline = Instant::now() + wait;
        let mut s = self.state.lock().unwrap();
        loop {
            let bucket = if s.closed && !s.queue.is_empty() {
                Some(self.bucket_seq(s.queue.front().unwrap().len))
            } else {
                self.ready_bucket(&s)
            };
            if let Some(bs) = bucket {
                let fb = self.form(&mut s, bs);
                if self.ready_bucket(&s).is_some() {
                    self.cv.notify_one();
                }
                return BatchWait::Formed(fb);
            }
            if s.closed && s.queue.is_empty() {
                return BatchWait::Closed;
            }
            let mut bound = deadline.saturating_duration_since(Instant::now());
            if !s.queue.is_empty() {
                let elapsed = s.queue.front().unwrap().enqueued.elapsed();
                if elapsed >= self.timeout {
                    // timeout: dispatch the oldest row's bucket, partial
                    let bs = self.bucket_seq(s.queue.front().unwrap().len);
                    return BatchWait::Formed(self.form(&mut s, bs));
                }
                bound = bound.min(self.timeout - elapsed);
            }
            if bound.is_zero() {
                return BatchWait::Idle;
            }
            let (guard, _t) = self.cv.wait_timeout(s, bound).unwrap();
            s = guard;
        }
    }

    /// Whether a dispatcher worker of this lane has nothing worth waiting
    /// for — the *steal-hungry* test: the queue is empty, or no bucket has
    /// reached even half its row budget and the oldest row is still far
    /// (under half the forming timeout) from a partial dispatch.  A closed
    /// batcher is never hungry: its workers must drain residual rows, not
    /// wander off stealing.
    pub fn is_hungry(&self) -> bool {
        let s = self.state.lock().unwrap();
        if s.closed {
            return false;
        }
        if s.queue.is_empty() {
            return true;
        }
        let oldest = s.queue.front().unwrap().enqueued.elapsed();
        if oldest * 2 >= self.timeout {
            return false;
        }
        match self.bucket {
            None => s.queue.len() * 2 < self.batch,
            Some(g) => s.bucket_counts.iter().enumerate().all(|(idx, &n)| {
                n * 2 < self.budget_rows(self.index_bucket(idx, g))
            }),
        }
    }

    /// Steal one formed batch off this (victim) queue for a *foreign*
    /// dispatcher worker: the oldest ready bucket, or — since the victim
    /// was picked as the most backlogged lane — the oldest row's bucket
    /// once that row has waited at least half the forming timeout, partial.
    /// Forming runs under the same mutex as [`Batcher::next_batch`], so a
    /// stolen batch goes to exactly one thief and FIFO order among the
    /// remaining rows is untouched.  Returns `None` once the batcher is
    /// closed: a draining lane's residual rows belong to its own workers
    /// (and the reaper that joins them), never to a thief.
    pub fn steal_bucket(&self) -> Option<FormedBatch<T>> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return None;
        }
        let bs = match self.ready_bucket(&s) {
            Some(bs) => bs,
            None => {
                let p = s.queue.front()?;
                if p.enqueued.elapsed() * 2 < self.timeout {
                    return None;
                }
                self.bucket_seq(p.len)
            }
        };
        let fb = self.form(&mut s, bs);
        if self.ready_bucket(&s).is_some() {
            self.cv.notify_one();
        }
        Some(fb)
    }

    /// Form one batch for `bucket_seq`, taking queued rows of that bucket in
    /// FIFO order up to the budget.  Fixed mode takes any row (single
    /// bucket, row budget = `batch`); continuous mode leaves other buckets'
    /// rows queued in their original relative order and keeps the
    /// per-bucket counts in sync.
    ///
    /// Rows of the selected bucket whose deadline has already passed are
    /// extracted into [`FormedBatch::expired`] instead of the block: they
    /// consume no batch slot and no budget, so one slow bucket full of
    /// expired rows cannot displace live work.
    fn form(&self, s: &mut Shared<T>, bucket_seq: usize) -> FormedBatch<T> {
        let q = &mut s.queue;
        let now = Instant::now();
        let budget = match self.bucket {
            None => self.batch,
            Some(_) => self.budget_rows(bucket_seq),
        };
        let mut taken: Vec<Pending<T>> = Vec::with_capacity(budget.min(q.len()));
        let mut expired: Vec<T> = Vec::new();
        if let Some(g) = self.bucket {
            // single pass over the whole queue: non-matching (or over-budget)
            // rows rotate to the back, which restores their relative order
            // once every element has been visited exactly once
            for _ in 0..q.len() {
                let p = q.pop_front().unwrap();
                if self.bucket_seq(p.len) != bucket_seq {
                    q.push_back(p);
                } else if p.deadline.is_some_and(|d| now >= d) {
                    expired.push(p.reply);
                } else if taken.len() < budget {
                    taken.push(p);
                } else {
                    q.push_back(p);
                }
            }
            s.bucket_counts[self.bucket_index(bucket_seq, g)] -=
                taken.len() + expired.len();
        } else {
            while taken.len() < budget && !q.is_empty() {
                let p = q.pop_front().unwrap();
                if p.deadline.is_some_and(|d| now >= d) {
                    expired.push(p.reply);
                } else {
                    taken.push(p);
                }
            }
        }
        debug_assert!(!taken.is_empty() || !expired.is_empty(),
                      "form() on a queue with no row of bucket {bucket_seq}");
        let rows = taken.len();
        // an all-expired form still checks out a (minimal) block so the
        // recycle contract stays uniform for the dispatcher
        let (block_rows, block_seq) = match self.bucket {
            None => (self.batch, self.seq),
            Some(_) => (rows.max(1), bucket_seq),
        };
        let form_start = Instant::now();
        let mut block = self.pool.checkout_shaped(block_rows, block_seq);
        let mut replies = Vec::with_capacity(rows);
        let mut waits = Vec::with_capacity(rows);
        let mut oldest = Duration::ZERO;
        for (row, p) in taken.into_iter().enumerate() {
            let ids = &p.encoding.ids[..block_seq];
            let segs = &p.encoding.segment_ids[..block_seq];
            let mask = &p.encoding.attention_mask[..block_seq];
            // masks are prefix-ones: a trailing 1 means the row fills the
            // block width, so the constant-mask fast path applies
            if mask.last() == Some(&1) {
                block.set_row_unmasked(row, ids, segs);
            } else {
                block.set_row(row, ids, segs, mask);
            }
            let wait = p.enqueued.elapsed();
            oldest = oldest.max(wait);
            waits.push(wait);
            replies.push(p.reply);
        }
        // scrub whatever the block's previous batch left beyond our rows
        block.reset_rows(rows);
        FormedBatch {
            block,
            replies,
            rows,
            oldest_wait: oldest,
            waits,
            form_time: form_start.elapsed(),
            expired,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn enc(seq: usize, fill: i32) -> Encoding {
        Encoding {
            ids: vec![fill; seq],
            segment_ids: vec![0; seq],
            attention_mask: vec![1; seq],
            tokens: vec![],
        }
    }

    /// Encoding padded to `seq` with `len` real tokens (prefix mask).
    fn enc_len(seq: usize, len: usize, fill: i32) -> Encoding {
        let mut ids = vec![0; seq];
        let mut mask = vec![0; seq];
        for i in 0..len {
            ids[i] = fill;
            mask[i] = 1;
        }
        Encoding {
            ids,
            segment_ids: vec![0; seq],
            attention_mask: mask,
            tokens: vec![],
        }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let b: Batcher<usize> = Batcher::new(2, 4, Duration::from_secs(10));
        b.push(enc(4, 1), 100).unwrap();
        b.push(enc(4, 2), 200).unwrap();
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 2);
        assert_eq!(fb.replies, vec![100, 200]);
        assert_eq!(&fb.block.ids[..4], &[1, 1, 1, 1]);
        assert_eq!(&fb.block.ids[4..], &[2, 2, 2, 2]);
    }

    #[test]
    fn timeout_dispatches_partial_batch() {
        let b: Batcher<usize> = Batcher::new(8, 4, Duration::from_millis(20));
        b.push(enc(4, 7), 1).unwrap();
        let t0 = Instant::now();
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 1);
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // padding rows are fully masked
        assert!(fb.block.attention_mask[4..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn fifo_order_preserved() {
        let b: Batcher<usize> = Batcher::new(3, 2, Duration::from_millis(5));
        for i in 0..3 {
            b.push(enc(2, i), i as usize).unwrap();
        }
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.replies, vec![0, 1, 2]);
    }

    #[test]
    fn close_unblocks_empty_queue() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(4, 2,
                                                           Duration::from_millis(5)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch().is_none());
        std::thread::sleep(Duration::from_millis(30));
        b.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn push_after_close_returns_reply_handle() {
        let b: Batcher<usize> = Batcher::new(4, 2, Duration::from_millis(5));
        b.close();
        assert_eq!(b.push(enc(2, 1), 42), Err(PushError::Closed(42)));
        assert!(b.is_empty());
        assert!(b.next_batch().is_none());
    }

    /// Admission control: pushes beyond the depth cap are shed with a typed
    /// `Overloaded` rejection carrying the reply handle, counted, and the
    /// queue recovers as soon as a batch drains.
    #[test]
    fn overload_sheds_pushes_and_recovers_after_drain() {
        let b: Batcher<usize> =
            Batcher::with_queue_depth(2, 2, Duration::from_millis(1), 3);
        for i in 0..3 {
            b.push(enc(2, i), i as usize).unwrap();
        }
        // 4th push hits the cap
        let err = b.push(enc(2, 9), 99).unwrap_err();
        assert_eq!(err, PushError::Overloaded(99));
        assert!(err_is_overloaded_reply(err), "reply handle must come back");
        assert_eq!(b.shed_count(), 1);
        assert_eq!(b.len(), 3, "shed push must not enter the queue");
        // drain one 2-row batch -> room again
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 2);
        b.push(enc(2, 5), 100).unwrap();
        assert_eq!(b.shed_count(), 1, "accepted push must not count as shed");
    }

    fn err_is_overloaded_reply(e: PushError<usize>) -> bool {
        e.is_overloaded() && e.into_reply() == 99
    }

    #[test]
    fn shed_reports_into_counters_sink() {
        let c = Arc::new(Counters::default());
        let b: Batcher<usize> =
            Batcher::with_queue_depth(2, 2, Duration::from_millis(1), 1)
                .with_counters(c.clone());
        b.push(enc(2, 0), 0).unwrap();
        assert!(b.push(enc(2, 1), 1).is_err());
        assert_eq!(c.shed.load(Ordering::Relaxed), 1);
        // pool traffic flows through the same sink
        let fb = b.next_batch().unwrap();
        b.recycle(fb.block);
        assert_eq!(c.pool_misses.load(Ordering::Relaxed), 1);
    }

    /// Regression for the close/push race: `closed` used to live in its own
    /// mutex, so a push could slip in after close and strand its request.
    /// With the single lock, every accepted push is drained and every
    /// rejected push hands its reply handle back — nothing is lost.
    #[test]
    fn close_push_race_never_strands_a_request() {
        for round in 0..20 {
            let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(
                4, 2, Duration::from_millis(1)));
            let accepted = Arc::new(AtomicUsize::new(0));
            let prod = {
                let b = b.clone();
                let accepted = accepted.clone();
                std::thread::spawn(move || {
                    for i in 0..200usize {
                        if b.push(enc(2, i as i32), i).is_ok() {
                            accepted.fetch_add(1, Ordering::SeqCst);
                        }
                        if i == 50 + round {
                            std::thread::yield_now();
                        }
                    }
                })
            };
            let closer = {
                let b = b.clone();
                std::thread::spawn(move || {
                    std::thread::yield_now();
                    b.close();
                })
            };
            let mut drained = 0usize;
            while let Some(fb) = b.next_batch() {
                drained += fb.rows;
            }
            prod.join().unwrap();
            closer.join().unwrap();
            // late pushes raced ahead of our final next_batch? drain again
            while let Some(fb) = b.next_batch() {
                drained += fb.rows;
            }
            assert_eq!(drained, accepted.load(Ordering::SeqCst),
                       "round {round}: accepted requests must all be drained");
        }
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        let b: Arc<Batcher<usize>> = Arc::new(Batcher::new(4, 2,
                                                           Duration::from_millis(2)));
        let n = 103usize;
        let prod = {
            let b = b.clone();
            std::thread::spawn(move || {
                for i in 0..n {
                    b.push(enc(2, i as i32), i).unwrap();
                }
                b.close();
            })
        };
        let mut seen = Vec::new();
        while let Some(fb) = b.next_batch() {
            assert!(fb.rows >= 1 && fb.rows <= 4);
            seen.extend(fb.replies);
        }
        prod.join().unwrap();
        seen.sort();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn recycled_blocks_are_reused_without_stale_rows() {
        let b: Batcher<usize> = Batcher::new(4, 2, Duration::from_millis(1));
        for i in 0..4 {
            b.push(enc(2, 9), i).unwrap();
        }
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 4);
        b.recycle(fb.block);
        assert_eq!(b.pool().stats(), (0, 1));

        // a 1-row batch on the recycled block: rows 1.. must be clean padding
        b.push(enc(2, 5), 10).unwrap();
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 1);
        assert_eq!(b.pool().stats(), (1, 1), "second form must hit the pool");
        assert_eq!(&fb.block.ids[..2], &[5, 5]);
        assert!(fb.block.ids[2..].iter().all(|&x| x == 0),
                "stale ids leaked into padding rows");
        assert!(fb.block.attention_mask[2..].iter().all(|&m| m == 0.0));
    }

    /// Continuous forming: short rows pack into a narrow block up to the
    /// cell budget — more rows than the nominal `batch` row count.
    #[test]
    fn continuous_packs_short_rows_by_token_budget() {
        // cells = 2 * 8 = 16; len-2 rows bucket at 2 -> budget 8 rows
        let b: Batcher<usize> =
            Batcher::continuous(2, 8, Duration::from_secs(5), 1024, 2);
        assert!(b.is_continuous());
        for i in 0..8 {
            b.push(enc_len(8, 2, 10 + i), i as usize).unwrap();
        }
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 8, "token budget must admit 8 two-token rows");
        assert_eq!((fb.block.batch, fb.block.seq), (8, 2));
        assert_eq!(fb.replies, (0..8).collect::<Vec<_>>());
        for row in 0..8 {
            assert_eq!(&fb.block.ids[row * 2..(row + 1) * 2],
                       &[10 + row as i32; 2]);
        }
    }

    /// Rows of different buckets never share a block; each bucket forms its
    /// own batch, oldest bucket first on timeout.
    #[test]
    fn continuous_buckets_do_not_mix() {
        let b: Batcher<usize> =
            Batcher::continuous(2, 8, Duration::from_millis(10), 1024, 2);
        b.push(enc_len(8, 8, 1), 0).unwrap(); // bucket 8
        b.push(enc_len(8, 2, 2), 1).unwrap(); // bucket 2
        b.push(enc_len(8, 8, 3), 2).unwrap(); // bucket 8 -> budget 2: ready
        // bucket 8 fills its budget (16 cells / 8 = 2 rows) first
        let fb = b.next_batch().unwrap();
        assert_eq!((fb.block.seq, fb.rows), (8, 2));
        assert_eq!(fb.replies, vec![0, 2]);
        // the len-2 row forms its own narrow batch at timeout
        let fb = b.next_batch().unwrap();
        assert_eq!((fb.block.seq, fb.rows), (2, 1));
        assert_eq!(fb.replies, vec![1]);
        assert_eq!(&fb.block.ids[..], &[2, 2]);
    }

    /// A ready bucket dispatches even when an older, sparser bucket is
    /// still waiting — and the old bucket keeps its place (FIFO among the
    /// remaining queue), dispatching on its own timeout.
    #[test]
    fn continuous_ready_bucket_overtakes_without_starving_the_oldest() {
        let b: Batcher<usize> =
            Batcher::continuous(2, 8, Duration::from_millis(30), 1024, 2);
        b.push(enc_len(8, 7, 9), 0).unwrap(); // bucket 8, alone
        for i in 0..4 {
            b.push(enc_len(8, 2, i), 10 + i as usize).unwrap(); // bucket 2
        }
        // bucket 2's budget is 16 / 2 = 8 rows -> 4 rows is NOT ready; the
        // oldest (bucket 8) is not ready either -> timeout drains oldest
        let t0 = Instant::now();
        let fb = b.next_batch().unwrap();
        assert_eq!((fb.block.seq, fb.rows), (8, 1));
        assert_eq!(fb.replies, vec![0]);
        assert!(t0.elapsed() >= Duration::from_millis(25));
        // now fill bucket 2 to its budget: dispatches immediately
        for i in 4..8 {
            b.push(enc_len(8, 2, i), 10 + i as usize).unwrap();
        }
        let t0 = Instant::now();
        let fb = b.next_batch().unwrap();
        assert_eq!((fb.block.seq, fb.rows), (2, 8));
        assert!(t0.elapsed() < Duration::from_millis(25),
                "a full bucket must not wait for the timeout");
        assert_eq!(fb.replies, (10..18).collect::<Vec<_>>());
    }

    /// Variable-fill blocks recycle across geometries without stale leaks.
    #[test]
    fn continuous_recycle_across_buckets_is_clean() {
        let b: Batcher<usize> =
            Batcher::continuous(2, 8, Duration::from_millis(1), 1024, 2);
        // wide batch taints the storage
        b.push(enc_len(8, 8, 7), 0).unwrap();
        b.push(enc_len(8, 8, 7), 1).unwrap();
        let fb = b.next_batch().unwrap();
        assert_eq!((fb.block.batch, fb.block.seq), (2, 8));
        b.recycle(fb.block);
        // narrow batch on the recycled storage
        b.push(enc_len(8, 3, 5), 2).unwrap();
        let fb = b.next_batch().unwrap();
        assert_eq!(b.pool().stats(), (1, 1), "must reuse the pooled block");
        assert_eq!((fb.block.batch, fb.block.seq), (1, 4));
        assert_eq!(&fb.block.ids[..], &[5, 5, 5, 0]);
        assert_eq!(&fb.block.attention_mask[..], &[1.0, 1.0, 1.0, 0.0]);
    }

    /// Rows past their deadline at form time are diverted into
    /// `FormedBatch::expired` — no batch slot, no forward cost — while live
    /// rows still form normally.
    #[test]
    fn expired_rows_are_extracted_before_forming() {
        let b: Batcher<usize> = Batcher::new(2, 2, Duration::from_millis(1));
        // a deadline of "now" is guaranteed past by form time
        b.push_with_deadline(enc(2, 1), 7, Some(Instant::now())).unwrap();
        b.push(enc(2, 2), 8).unwrap();
        b.push(enc(2, 3), 9).unwrap();
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.expired, vec![7], "expired row must not enter the block");
        assert_eq!(fb.replies, vec![8, 9]);
        assert_eq!(fb.rows, 2, "expired row must not consume the row budget");
        assert_eq!(&fb.block.ids[..2], &[2, 2],
                   "first block row must be the first live row");
    }

    /// A batch may consist solely of expired rows: `rows == 0`, every handle
    /// in `expired`, and the bucket accounting stays in sync so the batcher
    /// drains cleanly afterwards.
    #[test]
    fn all_expired_batch_forms_with_zero_rows() {
        let b: Batcher<usize> =
            Batcher::continuous(2, 8, Duration::from_millis(5), 1024, 2);
        let d = Some(Instant::now());
        b.push_with_deadline(enc_len(8, 2, 1), 0, d).unwrap();
        b.push_with_deadline(enc_len(8, 2, 2), 1, d).unwrap();
        // bucket 2 is not ready (budget 8 rows), so this dispatches on the
        // oldest row's timeout — by then both deadlines have passed
        let fb = b.next_batch().unwrap();
        assert_eq!(fb.rows, 0);
        assert!(fb.replies.is_empty());
        assert_eq!(fb.expired, vec![0, 1], "FIFO order among expired rows");
        b.recycle(fb.block);
        b.close();
        assert!(b.next_batch().is_none(),
                "bucket counts must be in sync after an all-expired form");
    }

    /// `next_batch_timeout` forms exactly like `next_batch` when work is
    /// ready, and reports Idle / Closed instead of blocking forever.
    #[test]
    fn next_batch_timeout_forms_idles_and_closes() {
        let b: Batcher<usize> = Batcher::new(2, 2, Duration::from_secs(10));
        // nothing queued: the bounded wait comes back Idle, promptly
        let t0 = Instant::now();
        assert!(matches!(b.next_batch_timeout(Duration::from_millis(5)),
                         BatchWait::Idle));
        assert!(t0.elapsed() < Duration::from_secs(1));
        // a full batch forms immediately, same as next_batch
        b.push(enc(2, 1), 0).unwrap();
        b.push(enc(2, 2), 1).unwrap();
        match b.next_batch_timeout(Duration::from_millis(5)) {
            BatchWait::Formed(fb) => assert_eq!(fb.replies, vec![0, 1]),
            _ => panic!("ready work must form, not idle"),
        }
        // closed + drained reports Closed
        b.close();
        assert!(matches!(b.next_batch_timeout(Duration::from_millis(5)),
                         BatchWait::Closed));
    }

    /// The oldest row's forming timeout still fires inside a bounded wait
    /// (the elastic loop must not starve a sparse bucket while polling).
    #[test]
    fn next_batch_timeout_honors_forming_timeout() {
        let b: Batcher<usize> = Batcher::new(8, 2, Duration::from_millis(20));
        b.push(enc(2, 7), 1).unwrap();
        let mut formed = None;
        for _ in 0..50 {
            match b.next_batch_timeout(Duration::from_millis(5)) {
                BatchWait::Formed(fb) => {
                    formed = Some(fb);
                    break;
                }
                BatchWait::Idle => continue,
                BatchWait::Closed => panic!("not closed"),
            }
        }
        let fb = formed.expect("partial batch must form at the timeout");
        assert_eq!(fb.rows, 1);
    }

    /// Steal-hunger: empty queue is hungry; a half-full bucket or an
    /// old-enough row is not; a closed batcher never is.
    #[test]
    fn is_hungry_tracks_queue_state() {
        let b: Batcher<usize> =
            Batcher::continuous(2, 8, Duration::from_secs(10), 1024, 2);
        assert!(b.is_hungry(), "empty queue is hungry");
        // bucket 2's budget is 16 / 2 = 8 rows; 3 rows < half
        for i in 0..3 {
            b.push(enc_len(8, 2, i), i as usize).unwrap();
        }
        assert!(b.is_hungry(), "below half a formable batch stays hungry");
        b.push(enc_len(8, 2, 3), 3).unwrap();
        assert!(!b.is_hungry(), "half a formable batch is worth waiting for");
        b.close();
        assert!(!b.is_hungry(), "a draining lane keeps its workers");
    }

    /// `steal_bucket` takes a ready bucket off a foreign queue — but never
    /// from a closed (draining) batcher, whose rows belong to its own
    /// workers.
    #[test]
    fn steal_bucket_takes_ready_work_but_not_from_a_draining_queue() {
        let b: Batcher<usize> =
            Batcher::continuous(2, 8, Duration::from_secs(10), 1024, 2);
        // a lone fresh row: not ready, not aged -> nothing to steal yet
        b.push(enc_len(8, 2, 9), 99).unwrap();
        assert!(b.steal_bucket().is_none(),
                "a fresh partial bucket must not be stolen");
        // fill bucket 2 to its 8-row budget: ready, stealable
        for i in 0..7 {
            b.push(enc_len(8, 2, i), i as usize).unwrap();
        }
        let fb = b.steal_bucket().expect("ready bucket must be stealable");
        assert_eq!(fb.rows, 8);
        assert_eq!(fb.block.seq, 2);
        b.recycle(fb.block);
        // re-fill, then close: the same ready work is now off limits
        for i in 0..8 {
            b.push(enc_len(8, 2, i), i as usize).unwrap();
        }
        b.close();
        assert!(b.steal_bucket().is_none(),
                "a draining queue is never stolen from");
        // ...and the victim's own drain still sees every row
        let mut drained = 0;
        while let Some(fb) = b.next_batch() {
            drained += fb.rows;
        }
        assert_eq!(drained, 8);
    }

    /// An aged partial bucket (oldest row past half the forming timeout)
    /// is stealable even though it never filled its budget.
    #[test]
    fn steal_bucket_takes_an_aged_partial_bucket() {
        let b: Batcher<usize> =
            Batcher::continuous(2, 8, Duration::from_millis(10), 1024, 2);
        b.push(enc_len(8, 2, 5), 0).unwrap();
        std::thread::sleep(Duration::from_millis(8));
        let fb = b.steal_bucket().expect("aged bucket must be stealable");
        assert_eq!(fb.rows, 1);
        assert_eq!(fb.replies, vec![0]);
    }

    /// Closing a continuous batcher drains every bucket.
    #[test]
    fn continuous_close_drains_all_buckets() {
        let b: Batcher<usize> =
            Batcher::continuous(2, 8, Duration::from_secs(10), 1024, 2);
        b.push(enc_len(8, 2, 1), 0).unwrap();
        b.push(enc_len(8, 8, 2), 1).unwrap();
        b.push(enc_len(8, 4, 3), 2).unwrap();
        b.close();
        let mut seen = Vec::new();
        while let Some(fb) = b.next_batch() {
            assert_eq!(fb.rows, fb.replies.len());
            seen.extend(fb.replies);
        }
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2]);
    }
}
