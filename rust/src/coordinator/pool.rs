//! Reusable [`EncoderBatch`] blocks for the serving hot path.
//!
//! `Batcher::form` used to allocate a fresh zeroed tensor block per formed
//! batch — three `vec![0; batch*seq]` allocations on every dispatch.  The
//! pool makes the steady state allocation-free: the dispatcher returns each
//! block after `run_block`, and the next `form` checks it out again, scrubbing
//! only the rows the previous batch actually wrote
//! ([`EncoderBatch::reset_rows`]).
//!
//! Contract for checked-out blocks: the contents are *stale* (whatever the
//! previous batch left behind).  The caller must `set_row` every row it uses
//! and then call `reset_rows(n)` to scrub the dirty tail before handing the
//! block to an engine.
//!
//! Continuous batching adds *variable-fill* reuse: the pool is sized by a
//! cell capacity (`batch × seq`) and [`BlockPool::checkout_shaped`] hands the
//! same storage back under any `[rows, bucket_seq]` geometry that fits it
//! ([`EncoderBatch::reshape`]), so token-budget batches of short rows and
//! full-width batches of long rows recycle one set of blocks.
//!
//! Hit/miss counters are exposed through `/v1/stats` (`pool_hits`,
//! `pool_misses`); wiring a [`Counters`] sink ([`BlockPool::set_sink`])
//! additionally reports every checkout into the server-wide aggregate, which
//! stays monotonic across lane rebuilds.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::Counters;
use crate::runtime::EncoderBatch;

/// Pool of `EncoderBatch` blocks sharing one cell capacity (`batch * seq` at
/// construction).  Bounded: returning a block to a full pool drops it (the
/// allocator handles bursts; the bound caps idle memory).
#[derive(Debug)]
pub struct BlockPool {
    batch: usize,
    seq: usize,
    capacity: usize,
    free: Mutex<Vec<EncoderBatch>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Server-wide aggregate counters (monotonic across lane rebuilds).
    sink: Option<Arc<Counters>>,
}

impl BlockPool {
    /// A lane needs one block in flight per dispatcher worker plus one being
    /// formed; the default capacity leaves headroom for a small shard set
    /// and shutdown races.
    pub const DEFAULT_CAPACITY: usize = 8;

    pub fn new(batch: usize, seq: usize, capacity: usize) -> BlockPool {
        assert!(capacity > 0, "pool capacity must be positive");
        BlockPool {
            batch,
            seq,
            capacity,
            free: Mutex::new(Vec::with_capacity(capacity)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            sink: None,
        }
    }

    /// Report every checkout into a server-wide [`Counters`] aggregate as
    /// well as this pool's local stats.
    pub fn set_sink(&mut self, counters: Arc<Counters>) {
        self.sink = Some(counters);
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    /// Cell capacity every pooled block shares.
    pub fn cells(&self) -> usize {
        self.batch * self.seq
    }

    /// Take a block at the pool's full `[batch, seq]` shape (stale contents —
    /// see the module contract) or allocate a zeroed one on a miss.
    pub fn checkout(&self) -> EncoderBatch {
        self.checkout_shaped(self.batch, self.seq)
    }

    /// Take a block reshaped to `[rows, seq]` (must fit the pool's cell
    /// capacity).  The storage is recycled across geometries; contents are
    /// stale and *every* row counts as dirty after a reshape, so callers
    /// must `set_row` + `reset_rows` as usual.
    pub fn checkout_shaped(&self, rows: usize, seq: usize) -> EncoderBatch {
        assert!(
            rows * seq <= self.cells(),
            "requested shape [{rows}, {seq}] exceeds pool cell capacity \
             [{}, {}]",
            self.batch, self.seq
        );
        let reused = self.free.lock().unwrap().pop();
        let mut block = match reused {
            Some(b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                if let Some(c) = &self.sink {
                    c.inc_pool_hit();
                }
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                if let Some(c) = &self.sink {
                    c.inc_pool_miss();
                }
                // allocate at full capacity so later reshapes never grow
                // beyond the initial allocation
                EncoderBatch::zeros(self.batch, self.seq)
            }
        };
        block.reshape(rows, seq);
        block
    }

    /// Return a block for reuse.  Cell-capacity-checked: recycling a block
    /// from a bigger pool is a logic error, not a tolerable input.
    pub fn put_back(&self, block: EncoderBatch) {
        assert!(
            block.batch * block.seq <= self.cells(),
            "block shape [{}, {}] exceeds pool cell capacity [{}, {}]",
            block.batch, block.seq, self.batch, self.seq
        );
        let mut free = self.free.lock().unwrap();
        if free.len() < self.capacity {
            free.push(block);
        }
        // else: drop — the pool is already holding its bounded working set
    }

    /// (hits, misses) since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Fraction of checkouts served from the pool (0.0 when none yet).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = self.stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Blocks currently idle in the pool.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_miss_then_hit() {
        let pool = BlockPool::new(2, 4, 4);
        let b = pool.checkout();
        assert_eq!(pool.stats(), (0, 1));
        pool.put_back(b);
        assert_eq!(pool.idle(), 1);
        let _b = pool.checkout();
        assert_eq!(pool.stats(), (1, 1));
        assert!((pool.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reuse_does_not_leak_stale_rows() {
        let pool = BlockPool::new(4, 2, 4);
        let mut b = pool.checkout();
        for row in 0..4 {
            b.set_row(row, &[7, 7], &[1, 1], &[1, 1]);
        }
        b.reset_rows(4);
        pool.put_back(b);

        // second checkout reuses the same storage; after the caller writes
        // one row and scrubs, nothing of the previous batch may remain
        let mut b = pool.checkout();
        assert_eq!(pool.stats().0, 1, "second checkout must be a pool hit");
        b.set_row(0, &[1, 2], &[0, 0], &[1, 1]);
        b.reset_rows(1);
        let mut fresh = EncoderBatch::zeros(4, 2);
        fresh.set_row(0, &[1, 2], &[0, 0], &[1, 1]);
        assert_eq!(b, fresh, "stale ids leaked through the pool");
    }

    #[test]
    fn shaped_checkout_recycles_storage_across_geometries() {
        // taint a [2, 8] block, recycle it as [4, 4]: same storage (hit),
        // and after the usual write+scrub it must equal a fresh block
        let pool = BlockPool::new(2, 8, 4);
        let mut b = pool.checkout();
        b.set_row_unmasked(0, &[9; 8], &[1; 8]);
        b.set_row_unmasked(1, &[9; 8], &[1; 8]);
        pool.put_back(b);

        let mut b = pool.checkout_shaped(4, 4);
        assert_eq!(pool.stats(), (1, 1), "reshape must reuse pooled storage");
        assert_eq!((b.batch, b.seq), (4, 4));
        b.set_row(0, &[1, 2, 3, 4], &[0; 4], &[1, 1, 1, 1]);
        b.reset_rows(1);
        let mut fresh = EncoderBatch::zeros(4, 4);
        fresh.set_row(0, &[1, 2, 3, 4], &[0; 4], &[1, 1, 1, 1]);
        assert_eq!(b, fresh, "stale cells leaked across the reshape");
        pool.put_back(b);
        // and back to the full shape again
        let b = pool.checkout_shaped(2, 8);
        assert_eq!((b.batch, b.seq), (2, 8));
        assert_eq!(b.ids.len(), 16);
    }

    #[test]
    #[should_panic]
    fn checkout_shaped_rejects_over_capacity() {
        let pool = BlockPool::new(2, 4, 4);
        let _ = pool.checkout_shaped(3, 4);
    }

    #[test]
    fn capacity_bounds_idle_blocks() {
        let pool = BlockPool::new(1, 1, 2);
        let (a, b, c) = (pool.checkout(), pool.checkout(), pool.checkout());
        pool.put_back(a);
        pool.put_back(b);
        pool.put_back(c); // dropped
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    #[should_panic]
    fn put_back_rejects_foreign_shape() {
        let pool = BlockPool::new(2, 4, 4);
        pool.put_back(EncoderBatch::zeros(2, 8));
    }

    #[test]
    fn sink_receives_aggregate_hit_miss() {
        let c = Arc::new(Counters::default());
        let mut pool = BlockPool::new(2, 4, 4);
        pool.set_sink(c.clone());
        let b = pool.checkout();
        pool.put_back(b);
        let _b = pool.checkout();
        assert_eq!(c.pool_hits.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(c.pool_misses.load(std::sync::atomic::Ordering::Relaxed), 1);
    }
}
