//! Reusable [`EncoderBatch`] blocks for the serving hot path.
//!
//! `Batcher::form` used to allocate a fresh zeroed tensor block per formed
//! batch — three `vec![0; batch*seq]` allocations on every dispatch.  The
//! pool makes the steady state allocation-free: the dispatcher returns each
//! block after `run_block`, and the next `form` checks it out again, scrubbing
//! only the rows the previous batch actually wrote
//! ([`EncoderBatch::reset_rows`]).
//!
//! Contract for checked-out blocks: the contents are *stale* (whatever the
//! previous batch left behind).  The caller must `set_row` every row it uses
//! and then call `reset_rows(n)` to scrub the dirty tail before handing the
//! block to an engine.
//!
//! Hit/miss counters are exposed through `/v1/stats` (`pool_hits`,
//! `pool_misses`) so load tests can assert the steady state really stopped
//! allocating.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::runtime::EncoderBatch;

/// Pool of same-shaped `EncoderBatch` blocks, keyed by (batch, seq) at
/// construction.  Bounded: returning a block to a full pool drops it (the
/// allocator handles bursts; the bound caps idle memory).
#[derive(Debug)]
pub struct BlockPool {
    batch: usize,
    seq: usize,
    capacity: usize,
    free: Mutex<Vec<EncoderBatch>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl BlockPool {
    /// A lane needs one block in flight (dispatcher) plus one being formed;
    /// the default capacity leaves headroom for shutdown races.
    pub const DEFAULT_CAPACITY: usize = 4;

    pub fn new(batch: usize, seq: usize, capacity: usize) -> BlockPool {
        assert!(capacity > 0, "pool capacity must be positive");
        BlockPool {
            batch,
            seq,
            capacity,
            free: Mutex::new(Vec::with_capacity(capacity)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    /// Take a block (stale contents — see the module contract) or allocate a
    /// zeroed one on a miss.
    pub fn checkout(&self) -> EncoderBatch {
        if let Some(b) = self.free.lock().unwrap().pop() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            b
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            EncoderBatch::zeros(self.batch, self.seq)
        }
    }

    /// Return a block for reuse.  Shape-checked: recycling a foreign block is
    /// a logic error, not a tolerable input.
    pub fn put_back(&self, block: EncoderBatch) {
        assert!(
            block.batch == self.batch && block.seq == self.seq,
            "block shape [{}, {}] does not match pool [{}, {}]",
            block.batch, block.seq, self.batch, self.seq
        );
        let mut free = self.free.lock().unwrap();
        if free.len() < self.capacity {
            free.push(block);
        }
        // else: drop — the pool is already holding its bounded working set
    }

    /// (hits, misses) since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Fraction of checkouts served from the pool (0.0 when none yet).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = self.stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Blocks currently idle in the pool.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_miss_then_hit() {
        let pool = BlockPool::new(2, 4, 4);
        let b = pool.checkout();
        assert_eq!(pool.stats(), (0, 1));
        pool.put_back(b);
        assert_eq!(pool.idle(), 1);
        let _b = pool.checkout();
        assert_eq!(pool.stats(), (1, 1));
        assert!((pool.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reuse_does_not_leak_stale_rows() {
        let pool = BlockPool::new(4, 2, 4);
        let mut b = pool.checkout();
        for row in 0..4 {
            b.set_row(row, &[7, 7], &[1, 1], &[1, 1]);
        }
        b.reset_rows(4);
        pool.put_back(b);

        // second checkout reuses the same storage; after the caller writes
        // one row and scrubs, nothing of the previous batch may remain
        let mut b = pool.checkout();
        assert_eq!(pool.stats().0, 1, "second checkout must be a pool hit");
        b.set_row(0, &[1, 2], &[0, 0], &[1, 1]);
        b.reset_rows(1);
        let mut fresh = EncoderBatch::zeros(4, 2);
        fresh.set_row(0, &[1, 2], &[0, 0], &[1, 1]);
        assert_eq!(b, fresh, "stale ids leaked through the pool");
    }

    #[test]
    fn capacity_bounds_idle_blocks() {
        let pool = BlockPool::new(1, 1, 2);
        let (a, b, c) = (pool.checkout(), pool.checkout(), pool.checkout());
        pool.put_back(a);
        pool.put_back(b);
        pool.put_back(c); // dropped
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    #[should_panic]
    fn put_back_rejects_foreign_shape() {
        let pool = BlockPool::new(2, 4, 4);
        pool.put_back(EncoderBatch::zeros(2, 8));
    }
}
