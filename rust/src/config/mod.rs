//! Config system: the engine manifest (written by python/compile/aot.py) and
//! the server configuration.
//!
//! The manifest is the contract between the build path (Python, runs once)
//! and the request path (Rust, forever): model geometry, static shapes,
//! precision variants with their HLO artifact paths, calibration scales, and
//! dataset locations.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::latency::LayerMode;
use crate::util::json::Json;

/// One precision variant of one model (one AOT-compiled executable).
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    /// HLO text path relative to the artifacts dir.
    pub hlo: String,
    /// Per-layer modes, e.g. ["int8_full", ..., "fp16"].
    pub layer_modes: Vec<String>,
    pub n_full_quant: usize,
    pub n_ffn_only: usize,
    /// Golden-logits JSON (runtime parity tests), relative path.
    pub golden: Option<String>,
}

impl VariantSpec {
    /// Number of quantized layers (either mode) — the Table-2 x axis.
    pub fn quantized_layers(&self) -> usize {
        self.n_full_quant + self.n_ffn_only
    }

    /// The per-layer precision plan of this variant.  Explicit
    /// `layer_modes` win; otherwise the paper's prefix plan is
    /// reconstructed from `n_full_quant`/`n_ffn_only` (the fp32 variant is
    /// uniformly fp32).  Shared by the latency cost model and the native
    /// backend, so both always agree on what a variant means.
    pub fn plan(&self, layers: usize) -> Result<Vec<LayerMode>> {
        if self.layer_modes.len() == layers {
            return self
                .layer_modes
                .iter()
                .map(|m| {
                    LayerMode::parse(m).with_context(|| {
                        format!("variant {}: bad layer mode `{m}`", self.name)
                    })
                })
                .collect();
        }
        if self.name == "fp32" {
            return Ok(vec![LayerMode::Fp32; layers]);
        }
        let mut p = vec![LayerMode::Fp16; layers];
        for m in p.iter_mut().take(self.n_full_quant) {
            *m = LayerMode::Int8Full;
        }
        for m in p.iter_mut().take(self.n_ffn_only) {
            *m = LayerMode::Int8Ffn;
        }
        Ok(p)
    }
}

/// One task model (encoder variants + head + data).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub task: String,
    pub kind: String, // classification | matching | ner
    pub num_labels: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub head_hlo: String,
    pub head_type: String,
    /// Native-backend weights file (`SAMPNATW`), relative path.  Used when
    /// the HLO artifacts are absent; missing or absent file falls back to
    /// deterministic synthetic weights.
    pub weights: Option<String>,
    pub dev_accuracy_fp32: Option<f64>,
    pub calibrator: String,
    pub scales: BTreeMap<String, f64>,
    pub variants: BTreeMap<String, VariantSpec>,
    pub dev_data: String,
    pub dev_jsonl: String,
    pub ner_labels: Vec<String>,
}

impl ModelSpec {
    /// Variants of the Table-2 sweep for one mode prefix, ordered by k.
    /// Includes k=0 (the fp16 baseline) first.
    pub fn sweep(&self, mode_prefix: &str) -> Vec<&VariantSpec> {
        let mut v: Vec<&VariantSpec> = self
            .variants
            .values()
            .filter(|v| v.name.starts_with(mode_prefix))
            .collect();
        v.sort_by_key(|v| v.quantized_layers());
        let mut out = Vec::new();
        if let Some(base) = self.variants.get("fp16") {
            out.push(base);
        }
        out.extend(v);
        out
    }
}

/// The whole artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub serve_batch: usize,
    pub vocab: String,
    pub vocab_size: usize,
    pub models: Vec<ModelSpec>,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let mpath = root.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading manifest {}", mpath.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(root, &j)
    }

    pub fn from_json(root: PathBuf, j: &Json) -> Result<Manifest> {
        let models_json = j
            .get("models")
            .as_arr()
            .context("manifest: missing models[]")?;
        let mut models = Vec::new();
        for m in models_json {
            models.push(Self::model_from_json(m)?);
        }
        Ok(Manifest {
            root,
            serve_batch: j.get("serve_batch").as_usize().unwrap_or(8),
            vocab: j.get("vocab").as_str().unwrap_or("vocab.txt").to_string(),
            vocab_size: j.get("vocab_size").as_usize().unwrap_or(0),
            models,
        })
    }

    fn model_from_json(m: &Json) -> Result<ModelSpec> {
        let task = m
            .get("task")
            .as_str()
            .context("model: missing task")?
            .to_string();
        let mut variants = BTreeMap::new();
        if let Some(vo) = m.get("variants").as_obj() {
            for (name, v) in vo {
                let layer_modes = v
                    .get("layer_modes")
                    .as_arr()
                    .map(|a| {
                        a.iter()
                            .filter_map(|x| x.as_str().map(|s| s.to_string()))
                            .collect()
                    })
                    .unwrap_or_default();
                variants.insert(
                    name.clone(),
                    VariantSpec {
                        name: name.clone(),
                        hlo: v
                            .get("hlo")
                            .as_str()
                            .with_context(|| format!("variant {name}: missing hlo"))?
                            .to_string(),
                        layer_modes,
                        n_full_quant: v.get("n_full_quant").as_usize().unwrap_or(0),
                        n_ffn_only: v.get("n_ffn_only").as_usize().unwrap_or(0),
                        golden: v.get("golden").as_str().map(|s| s.to_string()),
                    },
                );
            }
        }
        if variants.is_empty() {
            bail!("model {task}: no variants");
        }
        let scales = m
            .get("scales")
            .as_obj()
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
                    .collect()
            })
            .unwrap_or_default();
        let ner_labels = m
            .get("ner_labels")
            .as_arr()
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(|s| s.to_string()))
                    .collect()
            })
            .unwrap_or_default();
        Ok(ModelSpec {
            kind: m.get("kind").as_str().unwrap_or("classification").to_string(),
            num_labels: m.get("num_labels").as_usize().context("num_labels")?,
            seq_len: m.get("seq_len").as_usize().context("seq_len")?,
            batch: m.get("batch").as_usize().unwrap_or(8),
            hidden: m.get("hidden").as_usize().unwrap_or(64),
            layers: m.get("layers").as_usize().unwrap_or(12),
            heads: m.get("heads").as_usize().unwrap_or(4),
            ffn: m.get("ffn").as_usize().unwrap_or(256),
            head_hlo: m.get("head_hlo").as_str().context("head_hlo")?.to_string(),
            head_type: m.get("head_type").as_str().unwrap_or("classification").to_string(),
            weights: m.get("weights").as_str().map(|s| s.to_string()),
            dev_accuracy_fp32: m.get("dev_accuracy_fp32").as_f64(),
            calibrator: m.get("calibrator").as_str().unwrap_or("minmax").to_string(),
            scales,
            variants,
            dev_data: m.get("dev_data").as_str().unwrap_or("").to_string(),
            dev_jsonl: m.get("dev_jsonl").as_str().unwrap_or("").to_string(),
            ner_labels,
            task,
        })
    }

    pub fn model(&self, task: &str) -> Result<&ModelSpec> {
        self.models
            .iter()
            .find(|m| m.task == task)
            .with_context(|| format!("task `{task}` not in manifest"))
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }
}

/// Persist a planner-produced precision variant into an on-disk
/// `manifest.json`: upsert `variants[variant]` (explicit `layer_modes`, so
/// [`VariantSpec::plan`] reproduces the plan exactly) and merge the
/// calibrated activation `scales` into the model's scales map.  Every other
/// field of the manifest — including keys this loader does not model — is
/// preserved, and the write is atomic (temp file + rename), so a crash can
/// never leave a half-written manifest behind.
///
/// The variant's `hlo` path follows the `aot.py` naming convention but is
/// not required to exist: an absent artifact is exactly what routes the
/// variant onto the native backend.
pub fn upsert_planned_variant(artifacts_dir: impl AsRef<Path>, task: &str,
                              variant: &str, plan: &[LayerMode],
                              scales: &BTreeMap<String, f64>)
                              -> Result<PathBuf> {
    let mpath = artifacts_dir.as_ref().join("manifest.json");
    let text = std::fs::read_to_string(&mpath)
        .with_context(|| format!("reading manifest {}", mpath.display()))?;
    let mut j = Json::parse(&text).context("parsing manifest.json")?;
    let Json::Obj(root) = &mut j else {
        bail!("manifest.json: top level is not an object");
    };
    let models = match root.get_mut("models") {
        Some(Json::Arr(a)) => a,
        _ => bail!("manifest.json: missing models[]"),
    };
    let model = models
        .iter_mut()
        .find(|m| m.get("task").as_str() == Some(task))
        .with_context(|| format!("task `{task}` not in manifest"))?;
    let Json::Obj(mobj) = model else {
        bail!("manifest.json: model entry is not an object");
    };

    // A planned variant is served by the native backend *because* its hlo
    // path does not exist.  If an AOT artifact already sits at the
    // convention path (e.g. --name fp16 in a compiled artifacts dir),
    // Pipeline::load would silently execute that stale HLO instead of this
    // plan — refuse the name instead.
    let hlo_rel = format!("hlo/{task}/encoder_{variant}.hlo.txt");
    ensure!(!artifacts_dir.as_ref().join(&hlo_rel).exists(),
            "variant name `{variant}` collides with an existing AOT artifact \
             {hlo_rel} — it would shadow the planned layer modes; pick a \
             different --name");
    let n_full = plan.iter().filter(|m| **m == LayerMode::Int8Full).count();
    let n_ffn = plan.iter().filter(|m| **m == LayerMode::Int8Ffn).count();
    let vjson = Json::obj(vec![
        ("hlo", Json::str(hlo_rel)),
        ("layer_modes",
         Json::arr(plan.iter().map(|m| Json::str(m.as_str())))),
        ("n_full_quant", Json::num(n_full as f64)),
        ("n_ffn_only", Json::num(n_ffn as f64)),
    ]);
    let vslot = mobj
        .entry("variants".to_string())
        .or_insert_with(|| Json::Obj(BTreeMap::new()));
    if let Json::Obj(vs) = vslot {
        vs.insert(variant.to_string(), vjson);
    } else {
        bail!("manifest.json: `variants` is not an object");
    }
    let sslot = mobj
        .entry("scales".to_string())
        .or_insert_with(|| Json::Obj(BTreeMap::new()));
    if let Json::Obj(sm) = sslot {
        for (k, v) in scales {
            sm.insert(k.clone(), Json::num(*v));
        }
    } else {
        bail!("manifest.json: `scales` is not an object");
    }

    let tmp = mpath.with_extension("json.tmp");
    std::fs::write(&tmp, j.to_string())
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, &mpath)
        .with_context(|| format!("renaming over {}", mpath.display()))?;
    Ok(mpath)
}

/// Server configuration (CLI flags or JSON config file).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub artifacts_dir: PathBuf,
    /// Max time a request waits for batch mates before a partial batch runs.
    pub batch_timeout_ms: u64,
    /// Worker threads for request handling.
    pub workers: usize,
    /// Dispatcher workers per task lane (the shard set draining one shared
    /// batcher queue).  `0` = auto: `min(4, available cores)`.
    pub workers_per_lane: usize,
    /// Default variant per task (None = allocator-recommended or fp16).
    pub default_variant: Option<String>,
    /// Admission control: max requests waiting in one task's batcher queue.
    /// Pushes beyond this are shed with a typed `Overloaded` rejection
    /// (HTTP 429) so overload degrades predictably instead of growing an
    /// unbounded queue.
    pub max_queue_depth: usize,
    /// Engine replicas per task lane: each replica packs its **own** copy of
    /// the native weights and dispatcher workers pick the least-loaded
    /// replica per batch, so memory-bandwidth-bound INT8 GEMMs stop
    /// contending on one weight copy.  1 = a single shared engine (the
    /// pre-replica behavior); PJRT engines are artifact-cached and always
    /// shared.
    pub replicas_per_lane: usize,
    /// Poll each model's `manifest.json` mtime and hot-reload the model when
    /// it changes on disk (`samp serve --watch-manifest`) — makes a
    /// `samp plan` run into a live artifacts directory deployable without a
    /// restart.
    pub watch_manifest: bool,
    /// Poll period for `watch_manifest`, in milliseconds.
    pub watch_interval_ms: u64,
    /// Model registry entries as `(model_id, artifacts_dir)` pairs
    /// (`--artifacts id=dir`, repeatable).  Empty = one `default` model from
    /// `artifacts_dir`.
    pub models: Vec<(String, PathBuf)>,
    /// Threads one native GEMM is split across (`--gemm-threads`, batch-row
    /// partitioning).  `0` = auto: `min(4, available cores)`.
    pub gemm_threads: usize,
    /// Core sets from `--pin-cores A-B[,C-D]` (repeatable, one set per
    /// flag).  Replica `r` pins its GEMM pool to set `r % len`; dispatcher
    /// workers pin round-robin over the flattened union.  Empty = unpinned.
    pub pin_cores: Vec<Vec<usize>>,
    /// Run the SLO-aware precision degradation ladder (`--ladder`): a
    /// per-lane controller shifts native lanes toward deeper-INT8 planner
    /// variants while the lane is under pressure (queue depth past half its
    /// cap, or rolling p99 past `slo_p99_ms`) and back up once clear.
    pub ladder: bool,
    /// Rolling-p99 latency SLO in milliseconds for the ladder's pressure
    /// signal (`--slo-p99-ms`; 0 = queue-depth pressure only).
    pub slo_p99_ms: u64,
    /// Default end-to-end deadline applied to every request that doesn't
    /// send `X-SAMP-Deadline-Ms` (`--default-deadline-ms`; 0 = none).  Rows
    /// still queued past their deadline are dropped before the forward pass
    /// and answered HTTP 504.
    pub default_deadline_ms: u64,
    /// Echo per-row stage timings (`"timings"`: tokenize / queue / form /
    /// forward / gemm / decode, microseconds) on every infer response
    /// (`--trace-responses`).  Off by default; individual requests can
    /// opt in (or out) with the `X-SAMP-Trace` header.
    pub trace_responses: bool,
    /// Per-model lane weights as `(model_id, weight)` pairs
    /// (`--lane-weight ID=W`, repeatable).  The global dispatcher/queue
    /// budget (`workers_per_lane` x models, `max_queue_depth` x models) is
    /// apportioned by weight share, so a hot model can out-provision a cold
    /// one.  Models not listed weigh 1.0; empty = equal split (exactly the
    /// pre-weight behavior).
    pub lane_weights: Vec<(String, f64)>,
    /// Cross-lane work stealing (`--no-steal` disables): a dispatcher whose
    /// own lane is empty (or below half a formable batch) forms and runs
    /// the oldest ready bucket of the most-backlogged sibling lane of the
    /// same backend kind, on the *victim's* replicas.
    pub steal: bool,
    /// Close the budget loop (`--learn-weights`): periodically re-derive
    /// the per-model lane-budget shares from the signal hub's observed
    /// arrival rates and queue waits, instead of keeping the static
    /// `--lane-weight` split for the life of the process.
    pub learn_weights: bool,
    /// Record batch/row lifecycle events into the per-lane flight recorder
    /// (`--no-flight-recorder` disables); dumped by `GET /v1/debug/trace`
    /// as Chrome trace-event JSON.
    pub flight_recorder: bool,
    /// Flight-recorder ring capacity, in events per lane
    /// (`--flight-cap N`; oldest events drop first).
    pub flight_cap: usize,
}

impl ServerConfig {
    /// Dispatcher shard size per lane with the `0 = auto` default resolved.
    pub fn resolved_workers_per_lane(&self) -> usize {
        if self.workers_per_lane > 0 {
            return self.workers_per_lane;
        }
        auto_threads()
    }

    /// Per-GEMM parallelism with the `0 = auto` default resolved.
    pub fn resolved_gemm_threads(&self) -> usize {
        if self.gemm_threads > 0 {
            return self.gemm_threads;
        }
        auto_threads()
    }
}

/// The `0 = auto` thread default shared by `--workers-per-lane` and
/// `--gemm-threads`: `min(4, available cores)`, at least 1.
pub fn auto_threads() -> usize {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    cores.min(4).max(1)
}

/// Parse one `--pin-cores` value: comma-separated cores and inclusive
/// ranges (`"2"`, `"0-3"`, `"0-3,8-11"`), returning a sorted, deduplicated
/// core set.
pub fn parse_core_list(s: &str) -> Result<Vec<usize>> {
    let mut cores = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        ensure!(!part.is_empty(), "empty entry in core list `{s}`");
        let (lo, hi) = match part.split_once('-') {
            Some((a, b)) => (a.trim(), b.trim()),
            None => (part, part),
        };
        let lo: usize = lo.parse()
            .with_context(|| format!("bad core id `{lo}` in `{s}`"))?;
        let hi: usize = hi.parse()
            .with_context(|| format!("bad core id `{hi}` in `{s}`"))?;
        ensure!(lo <= hi, "inverted core range `{part}` in `{s}`");
        cores.extend(lo..=hi);
    }
    cores.sort_unstable();
    cores.dedup();
    Ok(cores)
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8117".to_string(),
            artifacts_dir: PathBuf::from("artifacts"),
            batch_timeout_ms: 5,
            workers: 2,
            workers_per_lane: 0,
            default_variant: None,
            max_queue_depth: 1024,
            replicas_per_lane: 1,
            watch_manifest: false,
            watch_interval_ms: 500,
            models: Vec::new(),
            gemm_threads: 0,
            pin_cores: Vec::new(),
            ladder: false,
            slo_p99_ms: 0,
            default_deadline_ms: 0,
            trace_responses: false,
            lane_weights: Vec::new(),
            steal: true,
            learn_weights: false,
            flight_recorder: true,
            flight_cap: 4096,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> &'static str {
        r#"{
          "format": 1, "serve_batch": 8, "vocab": "vocab.txt", "vocab_size": 2048,
          "models": [{
            "task": "tnews", "kind": "classification", "num_labels": 15,
            "seq_len": 32, "batch": 8, "hidden": 64, "layers": 12, "heads": 4,
            "ffn": 256, "head_hlo": "hlo/tnews/head.hlo.txt",
            "head_type": "classification", "dev_accuracy_fp32": 0.55,
            "calibrator": "minmax",
            "scales": {"emb_out": 0.11, "l0/ffn_in": 0.2},
            "variants": {
              "fp16": {"hlo": "hlo/tnews/encoder_fp16.hlo.txt",
                        "layer_modes": ["fp16"], "n_full_quant": 0, "n_ffn_only": 0},
              "ffn_only_2": {"hlo": "hlo/tnews/encoder_ffn_only_2.hlo.txt",
                        "layer_modes": ["int8_ffn","int8_ffn","fp16"],
                        "n_full_quant": 0, "n_ffn_only": 2},
              "ffn_only_4": {"hlo": "hlo/tnews/encoder_ffn_only_4.hlo.txt",
                        "layer_modes": [], "n_full_quant": 0, "n_ffn_only": 4},
              "full_quant_2": {"hlo": "hlo/tnews/encoder_full_quant_2.hlo.txt",
                        "layer_modes": [], "n_full_quant": 2, "n_ffn_only": 0}
            },
            "dev_data": "data/tnews_dev.bin", "dev_jsonl": "data/tnews_dev.jsonl",
            "ner_labels": null
          }]
        }"#
    }

    #[test]
    fn parses_manifest() {
        let j = Json::parse(sample_manifest_json()).unwrap();
        let m = Manifest::from_json(PathBuf::from("/tmp/x"), &j).unwrap();
        assert_eq!(m.serve_batch, 8);
        let t = m.model("tnews").unwrap();
        assert_eq!(t.num_labels, 15);
        assert_eq!(t.variants.len(), 4);
        assert_eq!(t.variants["ffn_only_2"].quantized_layers(), 2);
        assert!((t.scales["emb_out"] - 0.11).abs() < 1e-12);
    }

    #[test]
    fn sweep_is_ordered_and_prefixed_with_baseline() {
        let j = Json::parse(sample_manifest_json()).unwrap();
        let m = Manifest::from_json(PathBuf::from("/tmp/x"), &j).unwrap();
        let t = m.model("tnews").unwrap();
        let sweep = t.sweep("ffn_only");
        let names: Vec<&str> = sweep.iter().map(|v| v.name.as_str()).collect();
        assert_eq!(names, vec!["fp16", "ffn_only_2", "ffn_only_4"]);
    }

    #[test]
    fn variant_plan_explicit_and_reconstructed() {
        use crate::latency::LayerMode;
        let j = Json::parse(sample_manifest_json()).unwrap();
        let m = Manifest::from_json(PathBuf::from("/tmp/x"), &j).unwrap();
        let t = m.model("tnews").unwrap();
        // explicit layer_modes (3 entries for a 3-layer interpretation)
        let p = t.variants["ffn_only_2"].plan(3).unwrap();
        assert_eq!(p, vec![LayerMode::Int8Ffn, LayerMode::Int8Ffn,
                           LayerMode::Fp16]);
        // reconstructed prefix plan from counts
        let p = t.variants["full_quant_2"].plan(12).unwrap();
        assert_eq!(p.iter().filter(|m| **m == LayerMode::Int8Full).count(), 2);
        assert_eq!(p[0], LayerMode::Int8Full);
        assert_eq!(p[11], LayerMode::Fp16);
        // fp16 baseline
        let p = t.variants["fp16"].plan(12).unwrap();
        assert!(p.iter().all(|m| *m == LayerMode::Fp16));
    }

    #[test]
    fn missing_task_errors() {
        let j = Json::parse(sample_manifest_json()).unwrap();
        let m = Manifest::from_json(PathBuf::from("/tmp/x"), &j).unwrap();
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn upsert_planned_variant_roundtrips_and_preserves_fields() {
        use crate::latency::LayerMode;
        let dir = std::env::temp_dir().join(format!(
            "samp_upsert_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest_json())
            .unwrap();
        let plan = vec![LayerMode::Int8Full, LayerMode::Int8Ffn,
                        LayerMode::Fp16];
        let mut scales = BTreeMap::new();
        scales.insert("l0/attn_in".to_string(), 0.03);
        scales.insert("l1/ffn_in".to_string(), 0.07);
        upsert_planned_variant(&dir, "tnews", "auto", &plan, &scales).unwrap();

        let m = Manifest::load(&dir).unwrap();
        let t = m.model("tnews").unwrap();
        // the persisted variant reproduces the exact plan
        assert_eq!(t.variants["auto"].plan(3).unwrap(), plan);
        assert_eq!(t.variants["auto"].n_full_quant, 1);
        assert_eq!(t.variants["auto"].n_ffn_only, 1);
        // calibrated scales merged, pre-existing ones preserved
        assert!((t.scales["l0/attn_in"] - 0.03).abs() < 1e-12);
        assert!((t.scales["emb_out"] - 0.11).abs() < 1e-12);
        // pre-existing variants and unknown top-level fields survive
        assert!(t.variants.contains_key("ffn_only_2"));
        let raw = Json::parse(
            &std::fs::read_to_string(dir.join("manifest.json")).unwrap())
            .unwrap();
        assert_eq!(raw.get("format").as_usize(), Some(1));
        // idempotent: a second upsert overwrites, not duplicates
        upsert_planned_variant(&dir, "tnews", "auto", &plan, &scales).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model("tnews").unwrap().variants.len(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn upsert_planned_variant_rejects_existing_hlo_artifact_name() {
        use crate::latency::LayerMode;
        let dir = std::env::temp_dir().join(format!(
            "samp_upsert_hlo_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("hlo/tnews")).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest_json())
            .unwrap();
        // an AOT artifact already exists under the name we want to plan into
        std::fs::write(dir.join("hlo/tnews/encoder_auto.hlo.txt"), "HloModule")
            .unwrap();
        let err = upsert_planned_variant(&dir, "tnews", "auto",
                                         &[LayerMode::Int8Full; 3],
                                         &BTreeMap::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("collides"), "{err}");
        // the manifest must be untouched
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.model("tnews").unwrap().variants.contains_key("auto"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parse_core_list_handles_singles_ranges_and_dedup() {
        assert_eq!(parse_core_list("2").unwrap(), vec![2]);
        assert_eq!(parse_core_list("0-3").unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(parse_core_list("8-9,2,0-1").unwrap(),
                   vec![0, 1, 2, 8, 9]);
        assert_eq!(parse_core_list(" 4 - 5 , 4 ").unwrap(), vec![4, 5]);
        assert!(parse_core_list("").is_err());
        assert!(parse_core_list("3-1").is_err());
        assert!(parse_core_list("a-b").is_err());
        assert!(parse_core_list("1,,2").is_err());
    }

    #[test]
    fn resolved_gemm_threads_auto_is_bounded() {
        let mut cfg = ServerConfig::default();
        let auto = cfg.resolved_gemm_threads();
        assert!((1..=4).contains(&auto), "auto threads {auto}");
        cfg.gemm_threads = 7;
        assert_eq!(cfg.resolved_gemm_threads(), 7);
    }

    #[test]
    fn upsert_planned_variant_unknown_task_errors() {
        let dir = std::env::temp_dir().join(format!(
            "samp_upsert_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest_json())
            .unwrap();
        let err = upsert_planned_variant(&dir, "nope", "auto",
                                         &[crate::latency::LayerMode::Fp16],
                                         &BTreeMap::new());
        assert!(err.is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
