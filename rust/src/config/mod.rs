//! Config system: the engine manifest (written by python/compile/aot.py) and
//! the server configuration.
//!
//! The manifest is the contract between the build path (Python, runs once)
//! and the request path (Rust, forever): model geometry, static shapes,
//! precision variants with their HLO artifact paths, calibration scales, and
//! dataset locations.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::latency::LayerMode;
use crate::util::json::Json;

/// One precision variant of one model (one AOT-compiled executable).
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    /// HLO text path relative to the artifacts dir.
    pub hlo: String,
    /// Per-layer modes, e.g. ["int8_full", ..., "fp16"].
    pub layer_modes: Vec<String>,
    pub n_full_quant: usize,
    pub n_ffn_only: usize,
    /// Golden-logits JSON (runtime parity tests), relative path.
    pub golden: Option<String>,
}

impl VariantSpec {
    /// Number of quantized layers (either mode) — the Table-2 x axis.
    pub fn quantized_layers(&self) -> usize {
        self.n_full_quant + self.n_ffn_only
    }

    /// The per-layer precision plan of this variant.  Explicit
    /// `layer_modes` win; otherwise the paper's prefix plan is
    /// reconstructed from `n_full_quant`/`n_ffn_only` (the fp32 variant is
    /// uniformly fp32).  Shared by the latency cost model and the native
    /// backend, so both always agree on what a variant means.
    pub fn plan(&self, layers: usize) -> Result<Vec<LayerMode>> {
        if self.layer_modes.len() == layers {
            return self
                .layer_modes
                .iter()
                .map(|m| {
                    LayerMode::parse(m).with_context(|| {
                        format!("variant {}: bad layer mode `{m}`", self.name)
                    })
                })
                .collect();
        }
        if self.name == "fp32" {
            return Ok(vec![LayerMode::Fp32; layers]);
        }
        let mut p = vec![LayerMode::Fp16; layers];
        for m in p.iter_mut().take(self.n_full_quant) {
            *m = LayerMode::Int8Full;
        }
        for m in p.iter_mut().take(self.n_ffn_only) {
            *m = LayerMode::Int8Ffn;
        }
        Ok(p)
    }
}

/// One task model (encoder variants + head + data).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub task: String,
    pub kind: String, // classification | matching | ner
    pub num_labels: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub head_hlo: String,
    pub head_type: String,
    /// Native-backend weights file (`SAMPNATW`), relative path.  Used when
    /// the HLO artifacts are absent; missing or absent file falls back to
    /// deterministic synthetic weights.
    pub weights: Option<String>,
    pub dev_accuracy_fp32: Option<f64>,
    pub calibrator: String,
    pub scales: BTreeMap<String, f64>,
    pub variants: BTreeMap<String, VariantSpec>,
    pub dev_data: String,
    pub dev_jsonl: String,
    pub ner_labels: Vec<String>,
}

impl ModelSpec {
    /// Variants of the Table-2 sweep for one mode prefix, ordered by k.
    /// Includes k=0 (the fp16 baseline) first.
    pub fn sweep(&self, mode_prefix: &str) -> Vec<&VariantSpec> {
        let mut v: Vec<&VariantSpec> = self
            .variants
            .values()
            .filter(|v| v.name.starts_with(mode_prefix))
            .collect();
        v.sort_by_key(|v| v.quantized_layers());
        let mut out = Vec::new();
        if let Some(base) = self.variants.get("fp16") {
            out.push(base);
        }
        out.extend(v);
        out
    }
}

/// The whole artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub serve_batch: usize,
    pub vocab: String,
    pub vocab_size: usize,
    pub models: Vec<ModelSpec>,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let mpath = root.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading manifest {}", mpath.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(root, &j)
    }

    pub fn from_json(root: PathBuf, j: &Json) -> Result<Manifest> {
        let models_json = j
            .get("models")
            .as_arr()
            .context("manifest: missing models[]")?;
        let mut models = Vec::new();
        for m in models_json {
            models.push(Self::model_from_json(m)?);
        }
        Ok(Manifest {
            root,
            serve_batch: j.get("serve_batch").as_usize().unwrap_or(8),
            vocab: j.get("vocab").as_str().unwrap_or("vocab.txt").to_string(),
            vocab_size: j.get("vocab_size").as_usize().unwrap_or(0),
            models,
        })
    }

    fn model_from_json(m: &Json) -> Result<ModelSpec> {
        let task = m
            .get("task")
            .as_str()
            .context("model: missing task")?
            .to_string();
        let mut variants = BTreeMap::new();
        if let Some(vo) = m.get("variants").as_obj() {
            for (name, v) in vo {
                let layer_modes = v
                    .get("layer_modes")
                    .as_arr()
                    .map(|a| {
                        a.iter()
                            .filter_map(|x| x.as_str().map(|s| s.to_string()))
                            .collect()
                    })
                    .unwrap_or_default();
                variants.insert(
                    name.clone(),
                    VariantSpec {
                        name: name.clone(),
                        hlo: v
                            .get("hlo")
                            .as_str()
                            .with_context(|| format!("variant {name}: missing hlo"))?
                            .to_string(),
                        layer_modes,
                        n_full_quant: v.get("n_full_quant").as_usize().unwrap_or(0),
                        n_ffn_only: v.get("n_ffn_only").as_usize().unwrap_or(0),
                        golden: v.get("golden").as_str().map(|s| s.to_string()),
                    },
                );
            }
        }
        if variants.is_empty() {
            bail!("model {task}: no variants");
        }
        let scales = m
            .get("scales")
            .as_obj()
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
                    .collect()
            })
            .unwrap_or_default();
        let ner_labels = m
            .get("ner_labels")
            .as_arr()
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(|s| s.to_string()))
                    .collect()
            })
            .unwrap_or_default();
        Ok(ModelSpec {
            kind: m.get("kind").as_str().unwrap_or("classification").to_string(),
            num_labels: m.get("num_labels").as_usize().context("num_labels")?,
            seq_len: m.get("seq_len").as_usize().context("seq_len")?,
            batch: m.get("batch").as_usize().unwrap_or(8),
            hidden: m.get("hidden").as_usize().unwrap_or(64),
            layers: m.get("layers").as_usize().unwrap_or(12),
            heads: m.get("heads").as_usize().unwrap_or(4),
            ffn: m.get("ffn").as_usize().unwrap_or(256),
            head_hlo: m.get("head_hlo").as_str().context("head_hlo")?.to_string(),
            head_type: m.get("head_type").as_str().unwrap_or("classification").to_string(),
            weights: m.get("weights").as_str().map(|s| s.to_string()),
            dev_accuracy_fp32: m.get("dev_accuracy_fp32").as_f64(),
            calibrator: m.get("calibrator").as_str().unwrap_or("minmax").to_string(),
            scales,
            variants,
            dev_data: m.get("dev_data").as_str().unwrap_or("").to_string(),
            dev_jsonl: m.get("dev_jsonl").as_str().unwrap_or("").to_string(),
            ner_labels,
            task,
        })
    }

    pub fn model(&self, task: &str) -> Result<&ModelSpec> {
        self.models
            .iter()
            .find(|m| m.task == task)
            .with_context(|| format!("task `{task}` not in manifest"))
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }
}

/// Server configuration (CLI flags or JSON config file).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub artifacts_dir: PathBuf,
    /// Max time a request waits for batch mates before a partial batch runs.
    pub batch_timeout_ms: u64,
    /// Worker threads for request handling.
    pub workers: usize,
    /// Default variant per task (None = allocator-recommended or fp16).
    pub default_variant: Option<String>,
    /// Admission control: max requests waiting in one task's batcher queue.
    /// Pushes beyond this are shed with a typed `Overloaded` rejection
    /// (HTTP 429) so overload degrades predictably instead of growing an
    /// unbounded queue.
    pub max_queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8117".to_string(),
            artifacts_dir: PathBuf::from("artifacts"),
            batch_timeout_ms: 5,
            workers: 2,
            default_variant: None,
            max_queue_depth: 1024,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> &'static str {
        r#"{
          "format": 1, "serve_batch": 8, "vocab": "vocab.txt", "vocab_size": 2048,
          "models": [{
            "task": "tnews", "kind": "classification", "num_labels": 15,
            "seq_len": 32, "batch": 8, "hidden": 64, "layers": 12, "heads": 4,
            "ffn": 256, "head_hlo": "hlo/tnews/head.hlo.txt",
            "head_type": "classification", "dev_accuracy_fp32": 0.55,
            "calibrator": "minmax",
            "scales": {"emb_out": 0.11, "l0/ffn_in": 0.2},
            "variants": {
              "fp16": {"hlo": "hlo/tnews/encoder_fp16.hlo.txt",
                        "layer_modes": ["fp16"], "n_full_quant": 0, "n_ffn_only": 0},
              "ffn_only_2": {"hlo": "hlo/tnews/encoder_ffn_only_2.hlo.txt",
                        "layer_modes": ["int8_ffn","int8_ffn","fp16"],
                        "n_full_quant": 0, "n_ffn_only": 2},
              "ffn_only_4": {"hlo": "hlo/tnews/encoder_ffn_only_4.hlo.txt",
                        "layer_modes": [], "n_full_quant": 0, "n_ffn_only": 4},
              "full_quant_2": {"hlo": "hlo/tnews/encoder_full_quant_2.hlo.txt",
                        "layer_modes": [], "n_full_quant": 2, "n_ffn_only": 0}
            },
            "dev_data": "data/tnews_dev.bin", "dev_jsonl": "data/tnews_dev.jsonl",
            "ner_labels": null
          }]
        }"#
    }

    #[test]
    fn parses_manifest() {
        let j = Json::parse(sample_manifest_json()).unwrap();
        let m = Manifest::from_json(PathBuf::from("/tmp/x"), &j).unwrap();
        assert_eq!(m.serve_batch, 8);
        let t = m.model("tnews").unwrap();
        assert_eq!(t.num_labels, 15);
        assert_eq!(t.variants.len(), 4);
        assert_eq!(t.variants["ffn_only_2"].quantized_layers(), 2);
        assert!((t.scales["emb_out"] - 0.11).abs() < 1e-12);
    }

    #[test]
    fn sweep_is_ordered_and_prefixed_with_baseline() {
        let j = Json::parse(sample_manifest_json()).unwrap();
        let m = Manifest::from_json(PathBuf::from("/tmp/x"), &j).unwrap();
        let t = m.model("tnews").unwrap();
        let sweep = t.sweep("ffn_only");
        let names: Vec<&str> = sweep.iter().map(|v| v.name.as_str()).collect();
        assert_eq!(names, vec!["fp16", "ffn_only_2", "ffn_only_4"]);
    }

    #[test]
    fn variant_plan_explicit_and_reconstructed() {
        use crate::latency::LayerMode;
        let j = Json::parse(sample_manifest_json()).unwrap();
        let m = Manifest::from_json(PathBuf::from("/tmp/x"), &j).unwrap();
        let t = m.model("tnews").unwrap();
        // explicit layer_modes (3 entries for a 3-layer interpretation)
        let p = t.variants["ffn_only_2"].plan(3).unwrap();
        assert_eq!(p, vec![LayerMode::Int8Ffn, LayerMode::Int8Ffn,
                           LayerMode::Fp16]);
        // reconstructed prefix plan from counts
        let p = t.variants["full_quant_2"].plan(12).unwrap();
        assert_eq!(p.iter().filter(|m| **m == LayerMode::Int8Full).count(), 2);
        assert_eq!(p[0], LayerMode::Int8Full);
        assert_eq!(p[11], LayerMode::Fp16);
        // fp16 baseline
        let p = t.variants["fp16"].plan(12).unwrap();
        assert!(p.iter().all(|m| *m == LayerMode::Fp16));
    }

    #[test]
    fn missing_task_errors() {
        let j = Json::parse(sample_manifest_json()).unwrap();
        let m = Manifest::from_json(PathBuf::from("/tmp/x"), &j).unwrap();
        assert!(m.model("nope").is_err());
    }
}
