//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! `samp <subcommand> [--flag value ...]`; see `samp help` for the grammar.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed command line: subcommand + flags + positionals.  Flags are
/// repeatable: every occurrence is kept in order (`--artifacts id=dir` can
/// register several models), [`Args::flag`] reads the last one.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub flags: HashMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()`-style input (element 0 = program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().skip(1).peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags: HashMap<String, Vec<String>> = HashMap::new();
        let mut positional = Vec::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.entry(k.to_string()).or_default().push(v.to_string());
                } else {
                    // `--key value` when the next token isn't a flag,
                    // otherwise a boolean flag
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = it.next().unwrap();
                            flags.entry(name.to_string()).or_default().push(v);
                        }
                        _ => {
                            flags
                                .entry(name.to_string())
                                .or_default()
                                .push("true".to_string());
                        }
                    }
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Args { command, flags, positional })
    }

    /// Last occurrence of a flag (the conventional "last one wins" read).
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags
            .get(name)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable flag, in command-line order.
    pub fn flag_all(&self, name: &str) -> Vec<&str> {
        self.flags
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(x) => Ok(x),
                Err(_) => bail!("--{name} expects an integer, got `{v}`"),
            },
        }
    }

    pub fn flag_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(x) => Ok(Some(x)),
                Err(_) => bail!("--{name} expects a number, got `{v}`"),
            },
        }
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }
}

pub const HELP: &str = "\
samp — Self-Adaptive Mixed-Precision inference toolkit (SAMP, EMNLP 2023)

USAGE:
  samp serve     [--addr 127.0.0.1:8117] [--workers N]
                 [--artifacts DIR | --artifacts ID=DIR ...]
                 # repeatable: each ID=DIR registers one model; requests
                 # address {\"model\": ID, ...}; bare DIR = model `default`
                 [--batch-timeout-ms MS] [--variant NAME]
                 [--max-queue-depth N]   # admission control (shed -> 429)
                 [--workers-per-lane N]  # dispatcher shards per task lane
                                         # (0 = auto: min(4, cores))
                 [--replicas-per-lane N] # engine replicas per lane: N packed
                                         # native weight copies, least-loaded
                                         # pick per batch (default 1)
                 [--lane-weight ID=W]    # repeatable: weight the global
                                         # dispatcher/queue pool toward hot
                                         # models — each model's lanes get
                                         # its share of (workers-per-lane x
                                         # models); unlisted models weigh 1
                 [--no-steal]            # disable cross-lane work stealing
                                         # (static partitioning: an idle
                                         # lane's dispatchers never run a
                                         # backlogged sibling's batches)
                 [--learn-weights]       # re-derive lane-budget shares from
                                         # observed arrival rates + queue-wait
                                         # (signal-hub driven; overrides any
                                         # --lane-weight once traffic arrives)
                 [--no-flight-recorder]  # disable the per-lane flight
                                         # recorder (GET /v1/debug/trace)
                 [--flight-cap N]        # flight-recorder events kept per
                                         # lane, oldest dropped (default 4096)
                 [--gemm-threads N]      # threads one native GEMM is split
                                         # across (0 = auto: min(4, cores))
                 [--pin-cores A-B[,C-D]] # repeatable: replica r pins its GEMM
                                         # pool to the r-th core set; lane
                                         # dispatchers round-robin the union
                                         # (Linux; warns + runs unpinned
                                         # elsewhere).  SAMP_ISA=scalar|sse2|
                                         # avx2|vnni forces the kernel rung
                 [--watch-manifest] [--watch-interval-ms MS]
                 # hot reload: POST /v1/models/{id}/reload (optional body
                 # {\"variant\": NAME}) or --watch-manifest mtime polling
                 # builds the next generation off-path, warms it, swaps it
                 # atomically and drains the old one — zero dropped requests
                 [--ladder]              # SLO precision ladder: shift native
                                         # lanes toward deeper-INT8 variants
                                         # under pressure, back up when clear;
                                         # responses carry served_precision
                 [--slo-p99-ms MS]       # ladder pressure signal: rolling p99
                                         # above this counts as pressure
                                         # (0 = queue-depth pressure only)
                 [--default-deadline-ms MS]
                 # end-to-end deadline for requests without X-SAMP-Deadline-Ms:
                 # rows still queued past it are dropped before the forward
                 # pass and answered 504 (0 = no deadline).  SAMP_FAULT=SPEC
                 # (or POST /v1/debug/fault {\"spec\": SPEC}) injects faults:
                 # gemm_panic:P[:N],slow_forward:Dms,slow_fp32:Dms — poisoned
                 # GEMM pools self-heal via replica rebuild + generation swap
                 [--trace-responses]     # echo per-row stage timings
                 # (\"timings\": tokenize/queue/form/forward/gemm/decode, us)
                 # on every infer response; per-request opt-in/out via the
                 # X-SAMP-Trace header (1 = on, 0 = off).  GET /metrics
                 # serves Prometheus text exposition for scrapers
  samp infer     --task TASK --text TEXT [--variant NAME] [--artifacts DIR]
  samp sweep     --task TASK [--mode ffn_only|full_quant] [--limit N]
                 [--artifacts DIR]       # Table-2 sweep through the runtime
  samp allocate  --task TASK [--mode ffn_only|full_quant] [--limit N]
                 [--max-latency-ms X | --min-accuracy Y] [--artifacts DIR]
                 # Algorithm 1 / Appendix-A recommendation
  samp plan      --task TASK [--artifacts DIR]
                 [--accuracy-budget MSE | --latency-target-ms X]
                 [--mode int8_full|int8_ffn] [--calib FILE.jsonl]
                 [--calib-size N] [--calibrator maxabs|percentile[:P]]
                 [--refine] [--name VARIANT] [--frontier-out FILE.json]
                 [--gemm-threads N]      # thread count the native-CPU
                                         # latency column assumes (0 = auto)
                 [--cost-model-from PATH]
                 # calibrate the native-CPU latency column from a measured
                 # BENCH_SERVING.json (gemm.raw_* throughputs); defaults to
                 # ./BENCH_SERVING.json when present, built-in model else
                 [--dry-run] [--scaffold [--force]] [--quick]
                 # --scaffold refuses to overwrite an existing manifest
                 # unless --force is given
                 # calibration-driven plan search: measures per-layer INT8
                 # sensitivity, walks the accuracy/latency frontier, persists
                 # the winning plan + static activation scales into the
                 # manifest (served unchanged by the router/native backend)
  samp latency   [--toolkit samp|ft|turbo|pytorch] [--precision fp32|fp16|int8]
                 [--batch B] [--seq S]   # T4 cost-model query (Fig 3 point)
  samp tokenize  --text TEXT [--artifacts DIR] [--granularity char|wordpiece]
  samp help

All artifacts default to ./artifacts (built by `make artifacts`).";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse("samp infer --task tnews --text hello --limit 5");
        assert_eq!(a.command, "infer");
        assert_eq!(a.flag("task"), Some("tnews"));
        assert_eq!(a.flag("text"), Some("hello"));
        assert_eq!(a.flag_usize("limit", 0).unwrap(), 5);
    }

    #[test]
    fn parses_eq_form_and_bools() {
        let a = parse("samp serve --addr=0.0.0.0:80 --verbose --workers 4");
        assert_eq!(a.flag("addr"), Some("0.0.0.0:80"));
        assert!(a.flag_bool("verbose"));
        assert_eq!(a.flag_usize("workers", 1).unwrap(), 4);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("samp sweep --limit abc");
        assert!(a.flag_usize("limit", 0).is_err());
    }

    #[test]
    fn default_command_is_help() {
        let a = Args::parse(vec!["samp".to_string()]).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn repeated_flags_keep_every_occurrence_in_order() {
        let a = parse("samp serve --artifacts a=dir1 --artifacts b=dir2 \
                       --workers 2 --workers 4");
        assert_eq!(a.flag_all("artifacts"), vec!["a=dir1", "b=dir2"]);
        // last one wins for the scalar read
        assert_eq!(a.flag("workers"), Some("4"));
        assert_eq!(a.flag_usize("workers", 1).unwrap(), 4);
        assert!(a.flag_all("nope").is_empty());
    }
}
