//! Downstream-task target layers (the paper's Target module, §3.1).
//!
//! The heads' linear algebra lives in the AOT head executables; this module
//! implements the *post-processing* that turns logits into answers, one type
//! per Table-1 capability:
//!   * classification -> label id + softmax confidence (+ top-k)
//!   * text matching  -> match probability
//!   * NER            -> BIO decode to typed spans

/// Softmax over one logits row.
pub fn softmax(row: &[f32]) -> Vec<f32> {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = row.iter().map(|&x| (x - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / sum.max(1e-12)).collect()
}

pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Classification result for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct Classification {
    pub label: usize,
    pub confidence: f32,
    /// (label, prob) pairs, descending.
    pub top_k: Vec<(usize, f32)>,
}

/// Decode classification logits [batch, num_labels].
pub fn decode_classification(logits: &[f32], num_labels: usize, k: usize)
                             -> Vec<Classification> {
    logits
        .chunks(num_labels)
        .map(|row| {
            let probs = softmax(row);
            let mut idx: Vec<usize> = (0..num_labels).collect();
            idx.sort_by(|&a, &b| probs[b].total_cmp(&probs[a]));
            let top_k: Vec<(usize, f32)> =
                idx.iter().take(k).map(|&i| (i, probs[i])).collect();
            Classification { label: top_k[0].0, confidence: top_k[0].1, top_k }
        })
        .collect()
}

/// Text-matching result (binary classification with P(match)).
#[derive(Debug, Clone, PartialEq)]
pub struct Matching {
    pub is_match: bool,
    pub probability: f32,
}

pub fn decode_matching(logits: &[f32], num_labels: usize) -> Vec<Matching> {
    assert!(num_labels >= 2);
    logits
        .chunks(num_labels)
        .map(|row| {
            let probs = softmax(row);
            Matching { is_match: probs[1] >= probs[0], probability: probs[1] }
        })
        .collect()
}

/// A typed entity span.
#[derive(Debug, Clone, PartialEq)]
pub struct Entity {
    pub start: usize,
    pub end: usize, // exclusive, token indices
    pub entity_type: String,
    /// surface text if tokens were provided
    pub text: Option<String>,
}

/// Decode NER logits [batch, seq, num_labels] to entities per row.
/// `mask` marks real tokens; `labels` are BIO names ("O", "B-PER", ...).
pub fn decode_ner(logits: &[f32], batch: usize, seq: usize, num_labels: usize,
                  mask: &[i32], labels: &[String],
                  tokens: Option<&[Vec<String>]>) -> Vec<Vec<Entity>> {
    assert_eq!(logits.len(), batch * seq * num_labels);
    let mut out = Vec::with_capacity(batch);
    for b in 0..batch {
        let mut tags = Vec::with_capacity(seq);
        for s in 0..seq {
            if mask[b * seq + s] == 0 {
                tags.push(0usize); // O at padding
                continue;
            }
            let row = &logits[(b * seq + s) * num_labels..(b * seq + s + 1) * num_labels];
            tags.push(argmax(row));
        }
        out.push(tags_to_entities(&tags, labels,
                                  tokens.and_then(|t| t.get(b))));
    }
    out
}

/// Streaming per-row NER decode: one row's logits `[seq * num_labels]` and
/// its f32 attention-mask row (the engine-batch layout, 1.0 keep / 0.0 pad)
/// straight to entities.  This is the dispatcher's path — each row of a
/// batch decodes and replies independently, so a long row's BIO walk never
/// delays a short row's completion.
pub fn decode_ner_row(logits: &[f32], num_labels: usize, mask: &[f32],
                      labels: &[String]) -> Vec<Entity> {
    let seq = mask.len();
    assert_eq!(logits.len(), seq * num_labels, "row logits shape mismatch");
    let mut tags = Vec::with_capacity(seq);
    for (s, &m) in mask.iter().enumerate() {
        if m == 0.0 {
            tags.push(0usize); // O at padding
            continue;
        }
        tags.push(argmax(&logits[s * num_labels..(s + 1) * num_labels]));
    }
    tags_to_entities(&tags, labels, None)
}

/// BIO tags -> entities (lenient: I- without B- starts a span).
pub fn tags_to_entities(tags: &[usize], labels: &[String],
                        tokens: Option<&Vec<String>>) -> Vec<Entity> {
    let mut entities = Vec::new();
    let mut cur: Option<(usize, String)> = None;
    let flush = |cur: &mut Option<(usize, String)>, end: usize,
                 entities: &mut Vec<Entity>| {
        if let Some((start, ty)) = cur.take() {
            let text = tokens.map(|t| {
                t[start..end.min(t.len())].join("")
            });
            entities.push(Entity { start, end, entity_type: ty, text });
        }
    };
    for (i, &t) in tags.iter().enumerate() {
        let name = labels.get(t).map(|s| s.as_str()).unwrap_or("O");
        if let Some(ty) = name.strip_prefix("B-") {
            flush(&mut cur, i, &mut entities);
            cur = Some((i, ty.to_string()));
        } else if let Some(ty) = name.strip_prefix("I-") {
            let cont = matches!(&cur, Some((_, t0)) if t0 == ty);
            if !cont {
                flush(&mut cur, i, &mut entities);
                cur = Some((i, ty.to_string()));
            }
        } else {
            flush(&mut cur, i, &mut entities);
        }
    }
    flush(&mut cur, tags.len(), &mut entities);
    entities
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn classification_top_k() {
        let logits = [0.0f32, 3.0, 1.0, /* row 2 */ 5.0, 0.0, 0.0];
        let out = decode_classification(&logits, 3, 2);
        assert_eq!(out[0].label, 1);
        assert_eq!(out[0].top_k.len(), 2);
        assert_eq!(out[0].top_k[1].0, 2);
        assert_eq!(out[1].label, 0);
        assert!(out[1].confidence > 0.9);
    }

    #[test]
    fn matching_probability() {
        let out = decode_matching(&[0.0, 2.0, 2.0, 0.0], 2);
        assert!(out[0].is_match && out[0].probability > 0.5);
        assert!(!out[1].is_match && out[1].probability < 0.5);
    }

    fn labels() -> Vec<String> {
        ["O", "B-PER", "I-PER", "B-ORG", "I-ORG"]
            .iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn bio_decode_spans() {
        let tags = [0usize, 1, 2, 0, 3, 4, 4];
        let ents = tags_to_entities(&tags, &labels(), None);
        assert_eq!(ents.len(), 2);
        assert_eq!((ents[0].start, ents[0].end, ents[0].entity_type.as_str()),
                   (1, 3, "PER"));
        assert_eq!((ents[1].start, ents[1].end, ents[1].entity_type.as_str()),
                   (4, 7, "ORG"));
    }

    #[test]
    fn bio_type_switch_breaks_span() {
        // B-PER I-ORG must be two spans (type mismatch)
        let tags = [1usize, 4];
        let ents = tags_to_entities(&tags, &labels(), None);
        assert_eq!(ents.len(), 2);
    }

    #[test]
    fn ner_decode_respects_mask() {
        // batch=1 seq=3 labels=2 ("O", "B-PER"); last position padded but
        // with a B-PER logit — must be ignored
        let lbl: Vec<String> = ["O", "B-PER"].iter().map(|s| s.to_string()).collect();
        let logits = [0.9f32, 0.1, 0.1, 0.9, 0.1, 0.9];
        let mask = [1, 1, 0];
        let out = decode_ner(&logits, 1, 3, 2, &mask, &lbl, None);
        assert_eq!(out[0].len(), 1);
        assert_eq!(out[0][0].start, 1);
        assert_eq!(out[0][0].end, 2);
    }

    #[test]
    fn ner_row_decode_matches_batch_decode() {
        let lbl: Vec<String> = ["O", "B-PER"].iter().map(|s| s.to_string())
            .collect();
        // 2 rows x seq 3 x 2 labels; row 1 has a padded tail position
        let logits = [
            0.9f32, 0.1, 0.1, 0.9, 0.1, 0.9, // row 0: O, B, B
            0.1, 0.9, 0.9, 0.1, 0.1, 0.9, // row 1: B, O, (pad w/ B logit)
        ];
        let imask = [1, 1, 1, 1, 1, 0];
        let batch = decode_ner(&logits, 2, 3, 2, &imask, &lbl, None);
        for r in 0..2 {
            let fmask: Vec<f32> =
                imask[r * 3..(r + 1) * 3].iter().map(|&m| m as f32).collect();
            let row = decode_ner_row(&logits[r * 6..(r + 1) * 6], 2, &fmask,
                                     &lbl);
            assert_eq!(row, batch[r], "row {r} diverged from batch decode");
        }
    }

    #[test]
    fn entity_surface_text() {
        let lbl = labels();
        let tags = [1usize, 2, 0];
        let toks = vec!["张".to_string(), "三".to_string(), "说".to_string()];
        let ents = tags_to_entities(&tags, &lbl, Some(&toks));
        assert_eq!(ents[0].text.as_deref(), Some("张三"));
    }
}
