//! Execution backends behind the [`Backend`](crate::runtime::Backend) trait.
//!
//! Two implementations exist today:
//!
//! * **PJRT** — [`runtime::Engine`](crate::runtime::Engine): compiled HLO
//!   artifacts through the `xla` crate (the paper's deployment target).
//! * **native** — [`native`]: in-tree Rust kernels (blocked INT8 GEMM +
//!   f32 reference) that run the full mixed-precision encoder with no
//!   compiled artifact at all.  The default whenever a variant's HLO file
//!   is absent.

pub mod native;
