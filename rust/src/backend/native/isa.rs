//! Runtime-dispatched ISA ladder for the `i8 × i8 → i32` dot product that
//! every INT8 GEMM inner loop runs on:
//!
//! ```text
//!   scalar  ->  SSE2 pmaddwd  ->  AVX2 vpmaddwd  ->  AVX-512-VNNI vpdpbusd
//!  (16-lane     (16 B/iter,       (32 B/iter,        (64 B/iter, 4-byte
//!   chunks)      x86_64            widen to i16       u8*i8 MACs with a
//!                baseline)         + madd)            +128 bias fixup)
//! ```
//!
//! The ladder is selected **once** per process via CPUID
//! ([`is_x86_feature_detected!`]) and cached; `SAMP_ISA=scalar|sse2|avx2|
//! vnni` overrides the pick for testing, clamped (with a warning) to what
//! the CPU actually has.  Every rung computes the *bit-identical* `i32`
//! accumulator: integer addition is associative, the AVX2 rung widens to
//! i16 before multiplying (no `vpmaddubsw` saturation), and the VNNI rung's
//! unsigned-operand bias is compensated exactly (see [`dot_i8_vnni`]).  The
//! per-output-channel dequant epilogue in `gemm.rs` is therefore shared
//! untouched across all paths.

use std::sync::OnceLock;

/// One rung of the kernel ladder, worst to best.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Isa {
    Scalar,
    Sse2,
    Avx2,
    Vnni,
}

impl Isa {
    /// The `SAMP_ISA` spelling of this rung.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
            Isa::Vnni => "vnni",
        }
    }

    /// Parse a `SAMP_ISA` / `--isa` value.
    pub fn parse(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "sse2" => Some(Isa::Sse2),
            "avx2" => Some(Isa::Avx2),
            "vnni" => Some(Isa::Vnni),
            _ => None,
        }
    }
}

/// Every rung this CPU can run, worst to best (scalar is always first).
pub fn available() -> &'static [Isa] {
    static AVAILABLE: OnceLock<Vec<Isa>> = OnceLock::new();
    AVAILABLE.get_or_init(|| {
        let mut isas = vec![Isa::Scalar];
        #[cfg(target_arch = "x86_64")]
        {
            isas.push(Isa::Sse2); // part of the x86_64 baseline
            if is_x86_feature_detected!("avx2") {
                isas.push(Isa::Avx2);
            }
            if is_x86_feature_detected!("avx512f")
                && is_x86_feature_detected!("avx512bw")
                && is_x86_feature_detected!("avx512vnni")
            {
                isas.push(Isa::Vnni);
            }
        }
        isas
    })
}

/// The rung the process runs on: best available, unless `SAMP_ISA`
/// overrides it.  Resolved once and cached.
pub fn active() -> Isa {
    static ACTIVE: OnceLock<Isa> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        select(std::env::var("SAMP_ISA").ok().as_deref(), available())
    })
}

/// Pure selection logic (unit-testable without touching the env): honor a
/// requested rung when the CPU has it, otherwise warn and clamp to the
/// best available one.
pub fn select(requested: Option<&str>, avail: &[Isa]) -> Isa {
    let best = *avail.last().expect("scalar is always available");
    let Some(raw) = requested else { return best };
    match Isa::parse(raw) {
        Some(isa) if avail.contains(&isa) => isa,
        Some(isa) => {
            eprintln!("[isa] SAMP_ISA={} is not available on this CPU; \
                       using {}", isa.name(), best.name());
            best
        }
        None => {
            eprintln!("[isa] unknown SAMP_ISA value `{raw}` (expected \
                       scalar|sse2|avx2|vnni); using {}", best.name());
            best
        }
    }
}

/// The dot-product kernel for `isa` as a plain function pointer (fetched
/// once per GEMM, so dispatch cost never reaches the inner loop).
///
/// Panics if `isa` is not in [`available`] — the safe wrappers below rely
/// on that check to make calling the `target_feature` kernels sound.
pub fn dot_fn(isa: Isa) -> fn(&[i8], &[i8]) -> i32 {
    assert!(available().contains(&isa),
            "ISA {} is not available on this CPU", isa.name());
    match isa {
        Isa::Scalar => dot_i8_scalar,
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => dot_sse2,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => dot_avx2,
        #[cfg(target_arch = "x86_64")]
        Isa::Vnni => dot_vnni,
        #[cfg(not(target_arch = "x86_64"))]
        _ => dot_i8_scalar,
    }
}

/// Dot product on an explicit rung (tests / bench forcing).  `isa` must be
/// in [`available`].
pub fn dot_i8_with(isa: Isa, a: &[i8], b: &[i8]) -> i32 {
    dot_fn(isa)(a, b)
}

/// Portable reference rung: fixed 16-lane chunks keep bounds checks out of
/// the loop and hand the autovectorizer straight-line widening-multiply
/// bodies.  Every other rung is property-tested bit-identical to this.
pub fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    let mut ca = a.chunks_exact(16);
    let mut cb = b.chunks_exact(16);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        let mut s = 0i32;
        for (&x, &y) in xa.iter().zip(xb.iter()) {
            s += (x as i32) * (y as i32);
        }
        acc += s;
    }
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder().iter()) {
        acc += (x as i32) * (y as i32);
    }
    acc
}

// SAFETY of the three wrappers: `dot_fn` refuses to hand them out unless
// runtime detection put the rung in `available()`, so the target features
// the kernels are compiled for are guaranteed present.
#[cfg(target_arch = "x86_64")]
fn dot_sse2(a: &[i8], b: &[i8]) -> i32 {
    unsafe { dot_i8_sse2(a, b) }
}

#[cfg(target_arch = "x86_64")]
fn dot_avx2(a: &[i8], b: &[i8]) -> i32 {
    unsafe { dot_i8_avx2(a, b) }
}

#[cfg(target_arch = "x86_64")]
fn dot_vnni(a: &[i8], b: &[i8]) -> i32 {
    unsafe { dot_i8_vnni(a, b) }
}

/// SSE2 rung, 16 bytes/iter: sign-extend both operands to i16 (compare +
/// unpack) and `pmaddwd`, accumulating i32x4.  No overflow: |pair sum| <=
/// 2 * 127^2 per lane per iter, and K <= a few thousand.
#[cfg(target_arch = "x86_64")]
unsafe fn dot_i8_sse2(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let len = a.len();
    let n16 = len - len % 16;
    let zero = _mm_setzero_si128();
    let mut acc = _mm_setzero_si128();
    let mut i = 0;
    while i < n16 {
        let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
        let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
        // byte-wise sign masks turn unpack into 8->16 sign extension
        let sa = _mm_cmpgt_epi8(zero, va);
        let sb = _mm_cmpgt_epi8(zero, vb);
        let a_lo = _mm_unpacklo_epi8(va, sa);
        let a_hi = _mm_unpackhi_epi8(va, sa);
        let b_lo = _mm_unpacklo_epi8(vb, sb);
        let b_hi = _mm_unpackhi_epi8(vb, sb);
        acc = _mm_add_epi32(acc, _mm_madd_epi16(a_lo, b_lo));
        acc = _mm_add_epi32(acc, _mm_madd_epi16(a_hi, b_hi));
        i += 16;
    }
    let mut lanes = [0i32; 4];
    _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, acc);
    let mut sum = lanes[0] + lanes[1] + lanes[2] + lanes[3];
    while i < len {
        sum += (*a.get_unchecked(i) as i32) * (*b.get_unchecked(i) as i32);
        i += 1;
    }
    sum
}

/// AVX2 rung, 32 bytes/iter.  `vpmovsxbw` widens each half to i16 and
/// `vpmaddwd` does 16 widening MACs per multiply — the issue ladder names
/// `vpmaddubsw` here, but that instruction *saturates* its i16 pair sums
/// (u8*i8 + u8*i8 can exceed i16), which would break the bit-identical
/// accumulator contract; widening first costs one extra shuffle per
/// operand and keeps the math exact.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let len = a.len();
    let n32 = len - len % 32;
    let mut acc = _mm256_setzero_si256();
    let mut i = 0;
    while i < n32 {
        let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
        let a_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(va));
        let a_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(va));
        let b_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(vb));
        let b_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(vb));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_lo, b_lo));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_hi, b_hi));
        i += 32;
    }
    let mut lanes = [0i32; 8];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut sum: i32 = lanes.iter().sum();
    while i < len {
        sum += (*a.get_unchecked(i) as i32) * (*b.get_unchecked(i) as i32);
        i += 1;
    }
    sum
}

/// AVX-512-VNNI rung, 64 bytes/iter.  `vpdpbusd` wants u8 × i8, so the
/// signed activation is biased by +128 (`xor 0x80` reinterpreted unsigned)
/// and the bias is removed exactly:
///
/// ```text
///   sum (a_j + 128) * b_j  -  128 * sum b_j  =  sum a_j * b_j
/// ```
///
/// The column sum rides in a second `vpdpbusd` against all-ones in the
/// same loop, so the fixup costs one extra VNNI op per 64 bytes and the
/// result stays an exact i32 (worst case |acc lane| < 2^21 per KB of K —
/// nowhere near overflow for transformer widths).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f", enable = "avx512bw",
                 enable = "avx512vnni")]
unsafe fn dot_i8_vnni(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let len = a.len();
    let n64 = len - len % 64;
    let sign_bit = _mm512_set1_epi8(-128); // 0x80 in every byte
    let ones = _mm512_set1_epi8(1);
    let mut acc = _mm512_setzero_si512();
    let mut colsum = _mm512_setzero_si512();
    let mut i = 0;
    while i < n64 {
        // plain unaligned POD loads (vmovdqu64 after codegen)
        let va = core::ptr::read_unaligned(a.as_ptr().add(i) as *const __m512i);
        let vb = core::ptr::read_unaligned(b.as_ptr().add(i) as *const __m512i);
        let ua = _mm512_xor_si512(va, sign_bit); // a + 128, as u8
        acc = _mm512_dpbusd_epi32(acc, ua, vb);
        colsum = _mm512_dpbusd_epi32(colsum, ones, vb);
        i += 64;
    }
    let mut acc_lanes = [0i32; 16];
    let mut col_lanes = [0i32; 16];
    core::ptr::write_unaligned(acc_lanes.as_mut_ptr() as *mut __m512i, acc);
    core::ptr::write_unaligned(col_lanes.as_mut_ptr() as *mut __m512i, colsum);
    let mut sum: i32 =
        acc_lanes.iter().sum::<i32>() - 128 * col_lanes.iter().sum::<i32>();
    while i < len {
        sum += (*a.get_unchecked(i) as i32) * (*b.get_unchecked(i) as i32);
        i += 1;
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest_lite;

    #[test]
    fn ladder_is_ordered_and_starts_scalar() {
        let avail = available();
        assert_eq!(avail[0], Isa::Scalar);
        assert!(avail.windows(2).all(|w| w[0] < w[1]));
        #[cfg(target_arch = "x86_64")]
        assert!(avail.contains(&Isa::Sse2));
        assert!(avail.contains(&active()));
    }

    #[test]
    fn parse_roundtrips_every_rung() {
        for isa in [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Vnni] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("avx512"), None);
    }

    #[test]
    fn select_honors_available_overrides_and_clamps_the_rest() {
        let avail = [Isa::Scalar, Isa::Sse2, Isa::Avx2];
        assert_eq!(select(None, &avail), Isa::Avx2);
        assert_eq!(select(Some("scalar"), &avail), Isa::Scalar);
        assert_eq!(select(Some("sse2"), &avail), Isa::Sse2);
        // not on this CPU -> clamped to best
        assert_eq!(select(Some("vnni"), &avail), Isa::Avx2);
        // unknown spelling -> clamped to best
        assert_eq!(select(Some("neon"), &avail), Isa::Avx2);
        assert_eq!(select(None, &[Isa::Scalar]), Isa::Scalar);
    }

    /// The acceptance-criterion property: every rung the host can run
    /// produces the bit-identical i32 accumulator of the scalar reference,
    /// over random panels including full-range extremes and every
    /// remainder-tail length around the 16/32/64-byte vector widths.
    #[test]
    fn every_available_rung_matches_scalar_bit_exactly() {
        proptest_lite::run(150, |g| {
            // lengths hugging the lane boundaries plus a free-range draw
            let len = match g.usize(0..=3) {
                0 => g.usize(0..=17),
                1 => g.usize(30..=34),
                2 => g.usize(62..=66),
                _ => g.usize(0..=300),
            };
            let pick = |g: &mut proptest_lite::Gen| -> i8 {
                match g.usize(0..=4) {
                    0 => -128,
                    1 => 127,
                    2 => 0,
                    _ => g.i64(-128..=127) as i8,
                }
            };
            let a: Vec<i8> = (0..len).map(|_| pick(g)).collect();
            let b: Vec<i8> = (0..len).map(|_| pick(g)).collect();
            let want = dot_i8_scalar(&a, &b);
            for &isa in available() {
                let got = dot_i8_with(isa, &a, &b);
                prop_assert!(got == want,
                             "{} diverged: {got} != {want} (len {len})",
                             isa.name());
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "not available")]
    fn dot_fn_refuses_unavailable_rungs() {
        // on x86_64 hosts without AVX-512-VNNI this trips the availability
        // check; on VNNI hosts every rung is legal, so fake the panic to
        // keep the should_panic contract host-independent
        if available().contains(&Isa::Vnni) {
            panic!("not available (host has the full ladder)");
        }
        dot_fn(Isa::Vnni);
    }
}
