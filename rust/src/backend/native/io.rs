//! Binary weights file for the native backend.
//!
//! Format (`SAMPNATW`, version 1, little-endian):
//!
//! ```text
//!   magic    8 bytes  b"SAMPNATW"
//!   version  u32      1
//!   geometry u32 × 8  vocab, max_len, type_vocab, hidden, layers, heads,
//!                     ffn, num_labels
//!   tensors  f32le    in the fixed order below, no padding
//! ```
//!
//! Tensor order: `emb/tok [V,H]`, `emb/seg [T,H]`, `emb/pos [P,H]`,
//! `emb/ln_g [H]`, `emb/ln_b [H]`; then per layer `wq [H,H]`, `bq [H]`,
//! `wk`, `bk`, `wv`, `bv`, `wo`, `bo`, `ln1_g`, `ln1_b`, `w1 [H,F]`,
//! `b1 [F]`, `w2 [F,H]`, `b2 [H]`, `ln2_g`, `ln2_b`; then `pool/w [H,H]`,
//! `pool/b [H]`, `head/w [H,L]`, `head/b [L]`.  All matrices are row-major
//! in the `x @ W` orientation, exactly as `python/compile/model.py` stores
//! them; `python/compile/export_weights.py` emits this format.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::model::{Geometry, RawLayer, Weights};

const MAGIC: &[u8; 8] = b"SAMPNATW";
const VERSION: u32 = 1;

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(),
                "weights file truncated at byte {} (need {n} more)", self.pos);
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32_vec(&mut self, len: usize) -> Result<Vec<f32>> {
        let bytes = self.take(len * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Load a `SAMPNATW` weights file.
pub fn load_weights(path: impl AsRef<Path>) -> Result<Weights> {
    let path = path.as_ref();
    let buf = std::fs::read(path)
        .with_context(|| format!("reading weights {}", path.display()))?;
    let mut r = Reader { buf: &buf, pos: 0 };
    if r.take(8)? != MAGIC {
        bail!("{}: not a SAMPNATW weights file", path.display());
    }
    let version = r.u32()?;
    ensure!(version == VERSION,
            "{}: unsupported weights version {version}", path.display());
    let geom = Geometry {
        vocab: r.u32()? as usize,
        max_len: r.u32()? as usize,
        type_vocab: r.u32()? as usize,
        hidden: r.u32()? as usize,
        layers: r.u32()? as usize,
        heads: r.u32()? as usize,
        ffn: r.u32()? as usize,
        num_labels: r.u32()? as usize,
    };
    let h = geom.hidden;
    let f = geom.ffn;
    // A corrupt header could ask for absurd tensor counts (and drive
    // Vec::with_capacity into an allocation abort): require the payload to
    // be *exactly* the size the geometry implies before allocating anything.
    // u128 math so overflowed header fields cannot wrap the check itself.
    let (hu, fu) = (h as u128, f as u128);
    let per_layer = 4 * hu * hu + 2 * hu * fu + fu + 9 * hu;
    let total_floats = (geom.vocab as u128) * hu
        + (geom.type_vocab as u128) * hu
        + (geom.max_len as u128) * hu
        + 2 * hu
        + (geom.layers as u128) * per_layer
        + hu * hu
        + hu
        + hu * (geom.num_labels as u128)
        + geom.num_labels as u128;
    ensure!((buf.len() - r.pos) as u128 == total_floats * 4,
            "{}: payload is {} bytes but the header geometry implies {}",
            path.display(), buf.len() - r.pos, total_floats * 4);
    let emb_tok = r.f32_vec(geom.vocab * h)?;
    let emb_seg = r.f32_vec(geom.type_vocab * h)?;
    let emb_pos = r.f32_vec(geom.max_len * h)?;
    let emb_ln_g = r.f32_vec(h)?;
    let emb_ln_b = r.f32_vec(h)?;
    let mut layers = Vec::with_capacity(geom.layers);
    for _ in 0..geom.layers {
        layers.push(RawLayer {
            wq: r.f32_vec(h * h)?,
            bq: r.f32_vec(h)?,
            wk: r.f32_vec(h * h)?,
            bk: r.f32_vec(h)?,
            wv: r.f32_vec(h * h)?,
            bv: r.f32_vec(h)?,
            wo: r.f32_vec(h * h)?,
            bo: r.f32_vec(h)?,
            ln1_g: r.f32_vec(h)?,
            ln1_b: r.f32_vec(h)?,
            w1: r.f32_vec(h * f)?,
            b1: r.f32_vec(f)?,
            w2: r.f32_vec(f * h)?,
            b2: r.f32_vec(h)?,
            ln2_g: r.f32_vec(h)?,
            ln2_b: r.f32_vec(h)?,
        });
    }
    let pool_w = r.f32_vec(h * h)?;
    let pool_b = r.f32_vec(h)?;
    let head_w = r.f32_vec(h * geom.num_labels)?;
    let head_b = r.f32_vec(geom.num_labels)?;
    ensure!(r.pos == buf.len(),
            "{}: {} trailing bytes after weights", path.display(),
            buf.len() - r.pos);
    let w = Weights {
        geom,
        emb_tok,
        emb_seg,
        emb_pos,
        emb_ln_g,
        emb_ln_b,
        layers,
        pool_w,
        pool_b,
        head_w,
        head_b,
    };
    w.validate()?;
    Ok(w)
}

/// Write a `SAMPNATW` weights file (tests + tools; python exports normally).
pub fn save_weights(path: impl AsRef<Path>, w: &Weights) -> Result<()> {
    w.validate()?;
    let g = &w.geom;
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    for dim in [g.vocab, g.max_len, g.type_vocab, g.hidden, g.layers,
                g.heads, g.ffn, g.num_labels] {
        out.extend_from_slice(&(dim as u32).to_le_bytes());
    }
    let mut push = |t: &[f32]| {
        for x in t {
            out.extend_from_slice(&x.to_le_bytes());
        }
    };
    push(&w.emb_tok);
    push(&w.emb_seg);
    push(&w.emb_pos);
    push(&w.emb_ln_g);
    push(&w.emb_ln_b);
    for lw in &w.layers {
        push(&lw.wq);
        push(&lw.bq);
        push(&lw.wk);
        push(&lw.bk);
        push(&lw.wv);
        push(&lw.bv);
        push(&lw.wo);
        push(&lw.bo);
        push(&lw.ln1_g);
        push(&lw.ln1_b);
        push(&lw.w1);
        push(&lw.b1);
        push(&lw.w2);
        push(&lw.b2);
        push(&lw.ln2_g);
        push(&lw.ln2_b);
    }
    push(&w.pool_w);
    push(&w.pool_b);
    push(&w.head_w);
    push(&w.head_b);
    let path = path.as_ref();
    std::fs::write(path, &out)
        .with_context(|| format!("writing weights {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> Geometry {
        Geometry {
            vocab: 16,
            max_len: 8,
            type_vocab: 2,
            hidden: 8,
            layers: 2,
            heads: 2,
            ffn: 16,
            num_labels: 3,
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let dir = std::env::temp_dir().join("samp_weights_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let w = Weights::synthetic(geom(), 11);
        save_weights(&path, &w).unwrap();
        let r = load_weights(&path).unwrap();
        assert_eq!(r.geom, w.geom);
        assert_eq!(r.emb_tok, w.emb_tok);
        assert_eq!(r.emb_pos, w.emb_pos);
        assert_eq!(r.layers[1].w1, w.layers[1].w1);
        assert_eq!(r.layers[0].ln2_b, w.layers[0].ln2_b);
        assert_eq!(r.head_b, w.head_b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let dir = std::env::temp_dir().join("samp_weights_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.bin");
        std::fs::write(&bad, b"NOTMAGIC rest").unwrap();
        assert!(load_weights(&bad).is_err());

        let trunc = dir.join("trunc.bin");
        let w = Weights::synthetic(geom(), 3);
        save_weights(&trunc, &w).unwrap();
        let bytes = std::fs::read(&trunc).unwrap();
        std::fs::write(&trunc, &bytes[..bytes.len() - 7]).unwrap();
        assert!(load_weights(&trunc).is_err());
        std::fs::remove_file(&bad).ok();
        std::fs::remove_file(&trunc).ok();
    }
}
