//! Blocked GEMM kernels for the native backend.
//!
//! Two matmul paths, selected per layer by the SAMP precision plan:
//!
//! * [`gemm_f32`] — the floating-point reference: a straightforward
//!   register-friendly `ikj` loop (row of C accumulates across K) that the
//!   autovectorizer turns into wide FMA streams.  This is the correctness
//!   anchor every INT8 result is judged against.
//! * [`gemm_i8`] — the quantized path: `i8 × i8 → i32` dot products over
//!   pre-packed column-major weight panels ([`PackedI8`]), dequantized with
//!   one per-output-channel scale multiply in the epilogue.  Column blocking
//!   (`NC` columns at a time) keeps the active weight panel resident in L1
//!   while the activation row streams over it, so the kernel is compute-bound
//!   at sizes where the f32 path is already memory-bound — that gap (4× less
//!   weight traffic + wide integer multiplies vs FMA) is where the INT8
//!   speedup comes from.
//!
//! The inner dot product is a **runtime-dispatched ISA ladder**
//! ([`isa`](super::isa): scalar → SSE2 → AVX2 → AVX-512-VNNI, overridable
//! with `SAMP_ISA`), and both GEMMs can be **row-partitioned across a
//! persistent worker pool** ([`GemmPool`](super::pool::GemmPool)) via
//! [`GemmKernel`].  Rows are independent in both loops, every rung of the
//! ladder returns the bit-identical i32 accumulator, and the
//! per-output-channel requantization epilogue below is the single shared
//! implementation — so forcing any ISA or any thread count never changes a
//! single output bit.
//!
//! Weight quantization is symmetric per *output channel* (per column of the
//! `[K, N]` weight): column `j` gets `scale[j] = amax(w[:, j]) / 127`, the
//! Lin et al. integer-Transformer convention, so one row of badly-scaled
//! weights cannot poison the whole tensor.  Activations are quantized
//! per-tensor on the fly ([`quantize_dynamic`]) via `quant::quantize_into`.

use super::isa::{self, Isa};
use super::pool::{GemmPool, PoolPoisoned};
use crate::quant;

/// Column block width for the INT8 kernel: `NC * K` weight bytes stay L1
/// resident while every activation row visits the block (K ≤ 1024 → ≤ 32 KB).
const NC: usize = 32;

/// How one GEMM call executes: which ISA rung the dot product runs on and
/// which worker pool (if any) the rows are partitioned across.  `Copy`, so
/// the model resolves it once per forward and hands it to every call.
#[derive(Clone, Copy)]
pub struct GemmKernel<'p> {
    pub isa: Isa,
    pub pool: Option<&'p GemmPool>,
}

impl GemmKernel<'_> {
    /// The process-default kernel: active ISA, single-threaded.
    pub fn active() -> GemmKernel<'static> {
        GemmKernel { isa: isa::active(), pool: None }
    }

    /// Force an ISA rung, single-threaded (benches / tests).
    pub fn with_isa(isa: Isa) -> GemmKernel<'static> {
        GemmKernel { isa, pool: None }
    }

    /// Parallelism this kernel runs a GEMM at (1 = no pool).
    pub fn threads(&self) -> usize {
        self.pool.map_or(1, |p| p.threads())
    }
}

/// A weight matrix pre-quantized to INT8 and pre-packed for [`gemm_i8`].
///
/// Layout: plain column-major panels — `data[j * k + kk]` holds the
/// quantized `w[kk, j]`, so the dot product for output column `j` reads one
/// contiguous `k`-byte run.  `scales[j]` is the symmetric per-output-channel
/// dequant scale of column `j`.
#[derive(Debug, Clone)]
pub struct PackedI8 {
    pub k: usize,
    pub n: usize,
    data: Vec<i8>,
    scales: Vec<f32>,
}

impl PackedI8 {
    /// Quantize + pack a row-major `[k, n]` f32 weight (done once at load).
    pub fn pack(w: &[f32], k: usize, n: usize) -> PackedI8 {
        assert_eq!(w.len(), k * n, "weight shape mismatch");
        let mut data = vec![0i8; k * n];
        let mut scales = vec![0f32; n];
        for j in 0..n {
            let mut amax = 0f32;
            for kk in 0..k {
                amax = amax.max(w[kk * n + j].abs());
            }
            let s = quant::amax_to_scale(amax);
            scales[j] = s;
            let col = &mut data[j * k..(j + 1) * k];
            for (kk, q) in col.iter_mut().enumerate() {
                *q = quant::quantize(w[kk * n + j], s);
            }
        }
        PackedI8 { k, n, data, scales }
    }

    /// Per-output-channel dequant scales (length `n`).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// The packed column for output channel `j` (length `k`).
    pub fn col(&self, j: usize) -> &[i8] {
        &self.data[j * self.k..(j + 1) * self.k]
    }
}

/// Quantize a whole activation tensor with a per-tensor dynamic scale
/// (amax of the batch), reusing `buf` across calls.  Returns the scale.
pub fn quantize_dynamic(xs: &[f32], buf: &mut Vec<i8>) -> f32 {
    let mut amax = 0f32;
    for &x in xs {
        amax = amax.max(x.abs());
    }
    let scale = quant::amax_to_scale(amax);
    quant::quantize_into(xs, scale, buf);
    scale
}

/// f32 reference GEMM: `out[m, n] = a[m, k] @ b[k, n] (+ bias)`.
///
/// `bias` (length `n`) is broadcast over rows.  All slices are exact-size;
/// the inner loop runs over a row of C so stores are contiguous.
pub fn gemm_f32(a: &[f32], b: &[f32], bias: Option<&[f32]>, m: usize,
                k: usize, n: usize, out: &mut [f32]) {
    gemm_f32_with(GemmKernel::active(), a, b, bias, m, k, n, out)
        .expect("pool-less gemm cannot be poisoned");
}

/// [`gemm_f32`] on an explicit kernel (the ISA rung is irrelevant here —
/// the f32 loop is autovectorized — but the pool row-partitions it).
/// Errors only when the kernel's pool is poisoned by a panicked worker
/// job; the output buffer must then be discarded.
///
/// Wall time is charged to the calling thread's telemetry GEMM clock
/// ([`crate::telemetry::gemm_clock_take`]); `pool.run` blocks the caller
/// until every chunk finishes, so caller-side elapsed time is the true
/// kernel cost even when the rows are partitioned across the pool.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_with(kern: GemmKernel, a: &[f32], b: &[f32],
                     bias: Option<&[f32]>, m: usize, k: usize, n: usize,
                     out: &mut [f32]) -> Result<(), PoolPoisoned> {
    let clock = std::time::Instant::now();
    let r = gemm_f32_inner(kern, a, b, bias, m, k, n, out);
    crate::telemetry::gemm_clock_add(clock.elapsed().as_nanos() as u64);
    r
}

#[allow(clippy::too_many_arguments)]
fn gemm_f32_inner(kern: GemmKernel, a: &[f32], b: &[f32],
                  bias: Option<&[f32]>, m: usize, k: usize, n: usize,
                  out: &mut [f32]) -> Result<(), PoolPoisoned> {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if let Some(bs) = bias {
        assert_eq!(bs.len(), n, "bias shape mismatch");
    }
    let t = kern.threads().min(m).max(1);
    if t <= 1 {
        gemm_f32_rows(a, b, bias, m, k, n, out);
        return Ok(());
    }
    let pool = kern.pool.expect("t > 1 implies a pool");
    let base = m / t;
    let rem = m % t;
    let mut a_rest = a;
    let mut out_rest = out;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
        Vec::with_capacity(t - 1);
    let mut local: Option<(&[f32], &mut [f32], usize)> = None;
    for c in 0..t {
        let rows = base + usize::from(c < rem);
        let (ac, a_tail) = a_rest.split_at(rows * k);
        let (oc, o_tail) =
            std::mem::take(&mut out_rest).split_at_mut(rows * n);
        a_rest = a_tail;
        out_rest = o_tail;
        if c == 0 {
            local = Some((ac, oc, rows));
        } else {
            jobs.push(Box::new(move || {
                gemm_f32_rows(ac, b, bias, rows, k, n, oc);
            }));
        }
    }
    let (la, lo, lrows) = local.expect("t >= 1");
    pool.run(jobs, move || gemm_f32_rows(la, b, bias, lrows, k, n, lo))
}

/// The f32 loop body for one contiguous row range (rows are independent,
/// so partitioned execution is bit-identical to one pass).
fn gemm_f32_rows(a: &[f32], b: &[f32], bias: Option<&[f32]>, m: usize,
                 k: usize, n: usize, out: &mut [f32]) {
    for i in 0..m {
        let crow = &mut out[i * n..(i + 1) * n];
        match bias {
            Some(bs) => crow.copy_from_slice(bs),
            None => crow.fill(0.0),
        }
        let arow = &a[i * k..(i + 1) * k];
        for (kk, &aik) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (c, &bv) in crow.iter_mut().zip(brow.iter()) {
                *c += aik * bv;
            }
        }
    }
}

/// Blocked INT8 GEMM: `out[m, n] = dequant(qa[m, k] × w) (+ bias)`,
/// running on the process-active ISA rung, single-threaded.
///
/// `qa` is the row-major quantized activation (per-tensor scale `a_scale`);
/// `w` the packed per-channel weight.  Accumulation is exact i32; the only
/// float math is the single dequant multiply per output element.
pub fn gemm_i8(qa: &[i8], a_scale: f32, w: &PackedI8, bias: Option<&[f32]>,
               m: usize, out: &mut [f32]) {
    gemm_i8_with(GemmKernel::active(), qa, a_scale, w, bias, m, out)
        .expect("pool-less gemm cannot be poisoned");
}

/// [`gemm_i8`] on an explicit kernel: forced ISA rung and/or row
/// partitioning across a [`GemmPool`].  Bit-identical to [`gemm_i8`] for
/// every valid kernel (see the module docs).  Errors only when the
/// kernel's pool is poisoned by a panicked worker job.  Wall time is
/// charged to the calling thread's telemetry GEMM clock, like
/// [`gemm_f32_with`].
pub fn gemm_i8_with(kern: GemmKernel, qa: &[i8], a_scale: f32, w: &PackedI8,
                    bias: Option<&[f32]>, m: usize, out: &mut [f32])
                    -> Result<(), PoolPoisoned> {
    let clock = std::time::Instant::now();
    let r = gemm_i8_inner(kern, qa, a_scale, w, bias, m, out);
    crate::telemetry::gemm_clock_add(clock.elapsed().as_nanos() as u64);
    r
}

fn gemm_i8_inner(kern: GemmKernel, qa: &[i8], a_scale: f32, w: &PackedI8,
                 bias: Option<&[f32]>, m: usize, out: &mut [f32])
                 -> Result<(), PoolPoisoned> {
    let (k, n) = (w.k, w.n);
    assert_eq!(qa.len(), m * k, "A shape mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if let Some(bs) = bias {
        assert_eq!(bs.len(), n, "bias shape mismatch");
    }
    let dot = isa::dot_fn(kern.isa);
    let t = kern.threads().min(m).max(1);
    if t <= 1 {
        gemm_i8_rows(dot, qa, a_scale, w, bias, m, out);
        return Ok(());
    }
    let pool = kern.pool.expect("t > 1 implies a pool");
    let base = m / t;
    let rem = m % t;
    let mut qa_rest = qa;
    let mut out_rest = out;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
        Vec::with_capacity(t - 1);
    let mut local: Option<(&[i8], &mut [f32], usize)> = None;
    for c in 0..t {
        let rows = base + usize::from(c < rem);
        let (qc, q_tail) = qa_rest.split_at(rows * k);
        let (oc, o_tail) =
            std::mem::take(&mut out_rest).split_at_mut(rows * n);
        qa_rest = q_tail;
        out_rest = o_tail;
        if c == 0 {
            local = Some((qc, oc, rows));
        } else {
            jobs.push(Box::new(move || {
                gemm_i8_rows(dot, qc, a_scale, w, bias, rows, oc);
            }));
        }
    }
    let (lq, lo, lrows) = local.expect("t >= 1");
    pool.run(jobs, move || {
        gemm_i8_rows(dot, lq, a_scale, w, bias, lrows, lo);
    })
}

/// The blocked INT8 loop for one contiguous row range — the **shared
/// requantization epilogue**: whatever rung `dot` is, the i32 accumulator
/// gets exactly one `* (a_scale * scale[j]) (+ bias[j])` per element.
fn gemm_i8_rows(dot: fn(&[i8], &[i8]) -> i32, qa: &[i8], a_scale: f32,
                w: &PackedI8, bias: Option<&[f32]>, m: usize,
                out: &mut [f32]) {
    let (k, n) = (w.k, w.n);
    let mut jc = 0;
    while jc < n {
        let jend = (jc + NC).min(n);
        for i in 0..m {
            let arow = &qa[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in jc..jend {
                let col = &w.data[j * k..(j + 1) * k];
                let v = dot(arow, col) as f32 * (a_scale * w.scales[j]);
                orow[j] = match bias {
                    Some(bs) => v + bs[j],
                    None => v,
                };
            }
        }
        jc = jend;
    }
}

/// Plain dot product (attention QK^T rows).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc += x * y;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_mat(p: &mut Prng, len: usize, amp: f32) -> Vec<f32> {
        (0..len).map(|_| (p.f64() as f32 * 2.0 - 1.0) * amp).collect()
    }

    #[test]
    fn f32_gemm_matches_naive() {
        let (m, k, n) = (5, 7, 9);
        let mut p = Prng::new(1);
        let a = rand_mat(&mut p, m * k, 1.0);
        let b = rand_mat(&mut p, k * n, 1.0);
        let bias = rand_mat(&mut p, n, 0.5);
        let mut out = vec![0f32; m * n];
        gemm_f32(&a, &b, Some(&bias), m, k, n, &mut out);
        for i in 0..m {
            for j in 0..n {
                let mut want = bias[j];
                for kk in 0..k {
                    want += a[i * k + kk] * b[kk * n + j];
                }
                let got = out[i * n + j];
                assert!((got - want).abs() < 1e-4, "C[{i}][{j}] {got} != {want}");
            }
        }
    }

    #[test]
    fn packing_is_column_major_with_per_channel_scales() {
        // w[kk][j] = small distinct values; column 1 has the largest amax
        let w = vec![0.1, 1.27, 0.2, -0.635, 0.3, 0.127];
        let p = PackedI8::pack(&w, 3, 2);
        // col 0 = [0.1, 0.2, 0.3] -> scale 0.3/127
        let s0 = 0.3f32 / 127.0;
        assert!((p.scales()[0] - s0).abs() < 1e-7);
        assert_eq!(p.col(0), &[42, 85, 127]);
        // col 1 = [1.27, -0.635, 0.127] -> scale 0.01
        assert!((p.scales()[1] - 0.01).abs() < 1e-7);
        assert_eq!(p.col(1), &[127, -64, 13]);
    }

    #[test]
    fn i8_gemm_tracks_f32_within_quant_error() {
        let (m, k, n) = (17, 64, 33);
        let mut p = Prng::new(7);
        let a = rand_mat(&mut p, m * k, 1.0);
        let w = rand_mat(&mut p, k * n, 1.0);
        let bias = rand_mat(&mut p, n, 0.25);

        let mut want = vec![0f32; m * n];
        gemm_f32(&a, &w, Some(&bias), m, k, n, &mut want);

        let packed = PackedI8::pack(&w, k, n);
        let mut qa = Vec::new();
        let sa = quantize_dynamic(&a, &mut qa);
        let mut got = vec![0f32; m * n];
        gemm_i8(&qa, sa, &packed, Some(&bias), m, &mut got);

        // |C - Ĉ| <= K * (sa/2 * |w|max + sw/2 * |a|max + sa*sw/4)
        let sw = packed.scales().iter().cloned().fold(0f32, f32::max);
        let bound = k as f32 * (sa * 0.5 * 1.0 + sw * 0.5 * 1.0 + sa * sw * 0.25);
        for i in 0..m * n {
            let err = (got[i] - want[i]).abs();
            assert!(err <= bound, "elem {i}: err {err} > bound {bound}");
        }
    }

    #[test]
    fn i8_gemm_blocked_equals_unblocked_on_odd_shapes() {
        // shapes that don't divide the NC block evenly
        for (m, k, n) in [(1, 5, 1), (3, 16, 37), (2, 100, 65)] {
            let mut p = Prng::new((m * 1000 + k * 10 + n) as u64);
            let a = rand_mat(&mut p, m * k, 1.0);
            let w = rand_mat(&mut p, k * n, 1.0);
            let packed = PackedI8::pack(&w, k, n);
            let mut qa = Vec::new();
            let sa = quantize_dynamic(&a, &mut qa);
            let mut got = vec![0f32; m * n];
            gemm_i8(&qa, sa, &packed, None, m, &mut got);
            // naive integer accumulation over the same quantized operands
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0i32;
                    for kk in 0..k {
                        acc += qa[i * k + kk] as i32 * packed.col(j)[kk] as i32;
                    }
                    let want = acc as f32 * sa * packed.scales()[j];
                    assert_eq!(got[i * n + j], want, "({i},{j}) of {m}x{k}x{n}");
                }
            }
        }
    }

    #[test]
    fn every_isa_rung_produces_bit_identical_gemm_output() {
        let (m, k, n) = (5, 100, 37);
        let mut p = Prng::new(11);
        let a = rand_mat(&mut p, m * k, 1.0);
        let w = rand_mat(&mut p, k * n, 1.0);
        let bias = rand_mat(&mut p, n, 0.25);
        let packed = PackedI8::pack(&w, k, n);
        let mut qa = Vec::new();
        let sa = quantize_dynamic(&a, &mut qa);
        let mut want = vec![0f32; m * n];
        gemm_i8_with(GemmKernel::with_isa(Isa::Scalar), &qa, sa, &packed,
                     Some(&bias), m, &mut want)
            .unwrap();
        for &rung in isa::available() {
            let mut got = vec![0f32; m * n];
            gemm_i8_with(GemmKernel::with_isa(rung), &qa, sa, &packed,
                         Some(&bias), m, &mut got)
                .unwrap();
            for (i, (g, e)) in got.iter().zip(want.iter()).enumerate() {
                assert_eq!(g.to_bits(), e.to_bits(),
                           "{}: elem {i} diverged", rung.name());
            }
        }
    }

    /// The threaded-vs-single identity on odd row counts that don't split
    /// evenly across the pool — the acceptance-criterion test for the
    /// row-partitioned path, for both GEMMs, down to the bit.
    #[test]
    fn threaded_gemm_is_bit_identical_on_odd_row_counts() {
        let pool = GemmPool::new(4, &[]);
        let kern = GemmKernel { isa: isa::active(), pool: Some(&pool) };
        let (k, n) = (96, 37);
        for m in [1usize, 2, 3, 5, 7, 13] {
            let mut p = Prng::new(m as u64 * 31 + 5);
            let a = rand_mat(&mut p, m * k, 1.0);
            let w = rand_mat(&mut p, k * n, 1.0);
            let bias = rand_mat(&mut p, n, 0.5);
            let packed = PackedI8::pack(&w, k, n);
            let mut qa = Vec::new();
            let sa = quantize_dynamic(&a, &mut qa);

            let mut want_i8 = vec![0f32; m * n];
            gemm_i8(&qa, sa, &packed, Some(&bias), m, &mut want_i8);
            let mut got_i8 = vec![0f32; m * n];
            gemm_i8_with(kern, &qa, sa, &packed, Some(&bias), m, &mut got_i8)
                .unwrap();

            let mut want_f = vec![0f32; m * n];
            gemm_f32(&a, &w, Some(&bias), m, k, n, &mut want_f);
            let mut got_f = vec![0f32; m * n];
            gemm_f32_with(kern, &a, &w, Some(&bias), m, k, n, &mut got_f)
                .unwrap();

            for i in 0..m * n {
                assert_eq!(got_i8[i].to_bits(), want_i8[i].to_bits(),
                           "i8 m={m} elem {i}");
                assert_eq!(got_f[i].to_bits(), want_f[i].to_bits(),
                           "f32 m={m} elem {i}");
            }
        }
    }

    #[test]
    fn dynamic_quantization_uses_amax_scale() {
        let xs = [0.5f32, -2.0, 1.0];
        let mut buf = Vec::new();
        let s = quantize_dynamic(&xs, &mut buf);
        assert!((s - 2.0 / 127.0).abs() < 1e-7);
        assert_eq!(buf.len(), 3);
        assert_eq!(buf[1], -127);
    }
}
