//! Blocked GEMM kernels for the native backend.
//!
//! Two matmul paths, selected per layer by the SAMP precision plan:
//!
//! * [`gemm_f32`] — the floating-point reference: a straightforward
//!   register-friendly `ikj` loop (row of C accumulates across K) that the
//!   autovectorizer turns into wide FMA streams.  This is the correctness
//!   anchor every INT8 result is judged against.
//! * [`gemm_i8`] — the quantized path: `i8 × i8 → i32` dot products over
//!   pre-packed column-major weight panels ([`PackedI8`]), dequantized with
//!   one per-output-channel scale multiply in the epilogue.  Column blocking
//!   (`NC` columns at a time) keeps the active weight panel resident in L1
//!   while the activation row streams over it, so the kernel is compute-bound
//!   at sizes where the f32 path is already memory-bound — that gap (4× less
//!   weight traffic + 16-lane widening integer multiplies vs 8-lane FMA) is
//!   where the INT8 speedup comes from.
//!
//! Weight quantization is symmetric per *output channel* (per column of the
//! `[K, N]` weight): column `j` gets `scale[j] = amax(w[:, j]) / 127`, the
//! Lin et al. integer-Transformer convention, so one row of badly-scaled
//! weights cannot poison the whole tensor.  Activations are quantized
//! per-tensor on the fly ([`quantize_dynamic`]) via `quant::quantize_into`.

use crate::quant;

/// Column block width for the INT8 kernel: `NC * K` weight bytes stay L1
/// resident while every activation row visits the block (K ≤ 1024 → ≤ 32 KB).
const NC: usize = 32;

/// A weight matrix pre-quantized to INT8 and pre-packed for [`gemm_i8`].
///
/// Layout: plain column-major panels — `data[j * k + kk]` holds the
/// quantized `w[kk, j]`, so the dot product for output column `j` reads one
/// contiguous `k`-byte run.  `scales[j]` is the symmetric per-output-channel
/// dequant scale of column `j`.
#[derive(Debug, Clone)]
pub struct PackedI8 {
    pub k: usize,
    pub n: usize,
    data: Vec<i8>,
    scales: Vec<f32>,
}

impl PackedI8 {
    /// Quantize + pack a row-major `[k, n]` f32 weight (done once at load).
    pub fn pack(w: &[f32], k: usize, n: usize) -> PackedI8 {
        assert_eq!(w.len(), k * n, "weight shape mismatch");
        let mut data = vec![0i8; k * n];
        let mut scales = vec![0f32; n];
        for j in 0..n {
            let mut amax = 0f32;
            for kk in 0..k {
                amax = amax.max(w[kk * n + j].abs());
            }
            let s = quant::amax_to_scale(amax);
            scales[j] = s;
            let col = &mut data[j * k..(j + 1) * k];
            for (kk, q) in col.iter_mut().enumerate() {
                *q = quant::quantize(w[kk * n + j], s);
            }
        }
        PackedI8 { k, n, data, scales }
    }

    /// Per-output-channel dequant scales (length `n`).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// The packed column for output channel `j` (length `k`).
    pub fn col(&self, j: usize) -> &[i8] {
        &self.data[j * self.k..(j + 1) * self.k]
    }
}

/// Quantize a whole activation tensor with a per-tensor dynamic scale
/// (amax of the batch), reusing `buf` across calls.  Returns the scale.
pub fn quantize_dynamic(xs: &[f32], buf: &mut Vec<i8>) -> f32 {
    let mut amax = 0f32;
    for &x in xs {
        amax = amax.max(x.abs());
    }
    let scale = quant::amax_to_scale(amax);
    quant::quantize_into(xs, scale, buf);
    scale
}

/// f32 reference GEMM: `out[m, n] = a[m, k] @ b[k, n] (+ bias)`.
///
/// `bias` (length `n`) is broadcast over rows.  All slices are exact-size;
/// the inner loop runs over a row of C so stores are contiguous.
pub fn gemm_f32(a: &[f32], b: &[f32], bias: Option<&[f32]>, m: usize,
                k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if let Some(bs) = bias {
        assert_eq!(bs.len(), n, "bias shape mismatch");
    }
    for i in 0..m {
        let crow = &mut out[i * n..(i + 1) * n];
        match bias {
            Some(bs) => crow.copy_from_slice(bs),
            None => crow.fill(0.0),
        }
        let arow = &a[i * k..(i + 1) * k];
        for (kk, &aik) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (c, &bv) in crow.iter_mut().zip(brow.iter()) {
                *c += aik * bv;
            }
        }
    }
}

/// Blocked INT8 GEMM: `out[m, n] = dequant(qa[m, k] × w) (+ bias)`.
///
/// `qa` is the row-major quantized activation (per-tensor scale `a_scale`);
/// `w` the packed per-channel weight.  Accumulation is exact i32; the only
/// float math is the single dequant multiply per output element.
pub fn gemm_i8(qa: &[i8], a_scale: f32, w: &PackedI8, bias: Option<&[f32]>,
               m: usize, out: &mut [f32]) {
    let (k, n) = (w.k, w.n);
    assert_eq!(qa.len(), m * k, "A shape mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if let Some(bs) = bias {
        assert_eq!(bs.len(), n, "bias shape mismatch");
    }
    let mut jc = 0;
    while jc < n {
        let jend = (jc + NC).min(n);
        for i in 0..m {
            let arow = &qa[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in jc..jend {
                let col = &w.data[j * k..(j + 1) * k];
                let v = dot_i8(arow, col) as f32 * (a_scale * w.scales[j]);
                orow[j] = match bias {
                    Some(bs) => v + bs[j],
                    None => v,
                };
            }
        }
        jc = jend;
    }
}

/// Widening `i8 × i8 → i32` dot product: explicit SSE2 `pmaddwd` on x86_64
/// (part of the baseline target, so no runtime detection needed), a
/// fixed-16-lane autovectorizable scalar loop elsewhere.  Both compute the
/// exact same integer result.
#[inline]
fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: SSE2 is unconditionally available on x86_64; the loop
        // bounds keep every 16-byte load inside the slices.
        unsafe { dot_i8_sse2(a, b) }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        dot_i8_scalar(a, b)
    }
}

/// 16 lanes per iteration: sign-extend both operands to i16 and `pmaddwd`
/// (16 widening MACs in 2 multiply instructions), accumulating i32x4.
/// No overflow: |pair sum| <= 2 * 127^2 and lanes accumulate K/4 <= 256
/// pairs, far below i32::MAX.
#[cfg(target_arch = "x86_64")]
unsafe fn dot_i8_sse2(a: &[i8], b: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let len = a.len();
    let n16 = len - len % 16;
    let zero = _mm_setzero_si128();
    let mut acc = _mm_setzero_si128();
    let mut i = 0;
    while i < n16 {
        let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
        let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
        // byte-wise sign masks turn unpack into 8->16 sign extension
        let sa = _mm_cmpgt_epi8(zero, va);
        let sb = _mm_cmpgt_epi8(zero, vb);
        let a_lo = _mm_unpacklo_epi8(va, sa);
        let a_hi = _mm_unpackhi_epi8(va, sa);
        let b_lo = _mm_unpacklo_epi8(vb, sb);
        let b_hi = _mm_unpackhi_epi8(vb, sb);
        acc = _mm_add_epi32(acc, _mm_madd_epi16(a_lo, b_lo));
        acc = _mm_add_epi32(acc, _mm_madd_epi16(a_hi, b_hi));
        i += 16;
    }
    let mut lanes = [0i32; 4];
    _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, acc);
    let mut sum = lanes[0] + lanes[1] + lanes[2] + lanes[3];
    while i < len {
        sum += (*a.get_unchecked(i) as i32) * (*b.get_unchecked(i) as i32);
        i += 1;
    }
    sum
}

/// Portable fallback: fixed 16-lane chunks keep bounds checks out of the
/// loop and hand the autovectorizer straight-line widening-multiply bodies.
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    let mut acc = 0i32;
    let mut ca = a.chunks_exact(16);
    let mut cb = b.chunks_exact(16);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        let mut s = 0i32;
        for (&x, &y) in xa.iter().zip(xb.iter()) {
            s += (x as i32) * (y as i32);
        }
        acc += s;
    }
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder().iter()) {
        acc += (x as i32) * (y as i32);
    }
    acc
}

/// Plain dot product (attention QK^T rows).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc += x * y;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_mat(p: &mut Prng, len: usize, amp: f32) -> Vec<f32> {
        (0..len).map(|_| (p.f64() as f32 * 2.0 - 1.0) * amp).collect()
    }

    #[test]
    fn f32_gemm_matches_naive() {
        let (m, k, n) = (5, 7, 9);
        let mut p = Prng::new(1);
        let a = rand_mat(&mut p, m * k, 1.0);
        let b = rand_mat(&mut p, k * n, 1.0);
        let bias = rand_mat(&mut p, n, 0.5);
        let mut out = vec![0f32; m * n];
        gemm_f32(&a, &b, Some(&bias), m, k, n, &mut out);
        for i in 0..m {
            for j in 0..n {
                let mut want = bias[j];
                for kk in 0..k {
                    want += a[i * k + kk] * b[kk * n + j];
                }
                let got = out[i * n + j];
                assert!((got - want).abs() < 1e-4, "C[{i}][{j}] {got} != {want}");
            }
        }
    }

    #[test]
    fn packing_is_column_major_with_per_channel_scales() {
        // w[kk][j] = small distinct values; column 1 has the largest amax
        let w = vec![0.1, 1.27, 0.2, -0.635, 0.3, 0.127];
        let p = PackedI8::pack(&w, 3, 2);
        // col 0 = [0.1, 0.2, 0.3] -> scale 0.3/127
        let s0 = 0.3f32 / 127.0;
        assert!((p.scales()[0] - s0).abs() < 1e-7);
        assert_eq!(p.col(0), &[42, 85, 127]);
        // col 1 = [1.27, -0.635, 0.127] -> scale 0.01
        assert!((p.scales()[1] - 0.01).abs() < 1e-7);
        assert_eq!(p.col(1), &[127, -64, 13]);
    }

    #[test]
    fn i8_gemm_tracks_f32_within_quant_error() {
        let (m, k, n) = (17, 64, 33);
        let mut p = Prng::new(7);
        let a = rand_mat(&mut p, m * k, 1.0);
        let w = rand_mat(&mut p, k * n, 1.0);
        let bias = rand_mat(&mut p, n, 0.25);

        let mut want = vec![0f32; m * n];
        gemm_f32(&a, &w, Some(&bias), m, k, n, &mut want);

        let packed = PackedI8::pack(&w, k, n);
        let mut qa = Vec::new();
        let sa = quantize_dynamic(&a, &mut qa);
        let mut got = vec![0f32; m * n];
        gemm_i8(&qa, sa, &packed, Some(&bias), m, &mut got);

        // |C - Ĉ| <= K * (sa/2 * |w|max + sw/2 * |a|max + sa*sw/4)
        let sw = packed.scales().iter().cloned().fold(0f32, f32::max);
        let bound = k as f32 * (sa * 0.5 * 1.0 + sw * 0.5 * 1.0 + sa * sw * 0.25);
        for i in 0..m * n {
            let err = (got[i] - want[i]).abs();
            assert!(err <= bound, "elem {i}: err {err} > bound {bound}");
        }
    }

    #[test]
    fn i8_gemm_blocked_equals_unblocked_on_odd_shapes() {
        // shapes that don't divide the NC block evenly
        for (m, k, n) in [(1, 5, 1), (3, 16, 37), (2, 100, 65)] {
            let mut p = Prng::new((m * 1000 + k * 10 + n) as u64);
            let a = rand_mat(&mut p, m * k, 1.0);
            let w = rand_mat(&mut p, k * n, 1.0);
            let packed = PackedI8::pack(&w, k, n);
            let mut qa = Vec::new();
            let sa = quantize_dynamic(&a, &mut qa);
            let mut got = vec![0f32; m * n];
            gemm_i8(&qa, sa, &packed, None, m, &mut got);
            // naive integer accumulation over the same quantized operands
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0i32;
                    for kk in 0..k {
                        acc += qa[i * k + kk] as i32 * packed.col(j)[kk] as i32;
                    }
                    let want = acc as f32 * sa * packed.scales()[j];
                    assert_eq!(got[i * n + j], want, "({i},{j}) of {m}x{k}x{n}");
                }
            }
        }
    }

    #[test]
    fn dynamic_quantization_uses_amax_scale() {
        let xs = [0.5f32, -2.0, 1.0];
        let mut buf = Vec::new();
        let s = quantize_dynamic(&xs, &mut buf);
        assert!((s - 2.0 / 127.0).abs() < 1e-7);
        assert_eq!(buf.len(), 3);
        assert_eq!(buf[1], -127);
    }
}
