//! Native mixed-precision encoder backend: in-tree Rust compute, no PJRT.
//!
//! The PJRT path executes AOT-compiled HLO artifacts; when no artifact is
//! present (fresh checkout, offline environment, or a deployment that ships
//! only a weights file) the coordinator used to have *nothing* to run — the
//! paper's mixed-precision latency story was unmeasurable.  This module owns
//! the compute in-tree:
//!
//! * [`gemm`] — the kernels.  Weight matrices are pre-quantized to INT8 with
//!   one symmetric scale **per output channel** and pre-packed into
//!   column-major panels at load time ([`gemm::PackedI8`]): the dot product
//!   for output channel `j` reads one contiguous `K`-byte run, and the
//!   column-blocked loop keeps the active `NC × K` panel L1-resident while
//!   activation rows stream over it.  Activations are quantized on the fly
//!   with a per-tensor dynamic scale (`quant::quantize_into` underneath).
//! * [`model`] — the full encoder forward (fused embedding + LayerNorm,
//!   MHA, FFN, bias+residual+LN epilogues) with each layer dispatched to
//!   the INT8 or f32-reference GEMMs by a SAMP per-layer precision plan,
//!   plus the classification / matching / NER heads.
//! * [`io`] — the `SAMPNATW` binary weights format (exported by
//!   `python/compile/export_weights.py`) and a deterministic synthetic
//!   fallback so serving and benches work from a bare checkout.
//!
//! [`NativeEncoder`] / [`NativeHead`] adapt a shared [`NativeModel`] to the
//! [`Backend`] trait; `coordinator::pipeline` selects them automatically
//! whenever a variant's HLO artifact is missing, so lanes dispatch to PJRT
//! or native transparently.

pub mod gemm;
pub mod io;
pub mod isa;
pub mod model;
pub mod pool;

use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Result};

use crate::config::ModelSpec;
use crate::fault;
use crate::latency::LayerMode;
use crate::runtime::{Backend, EncoderBatch};

pub use gemm::{gemm_f32, gemm_f32_with, gemm_i8, gemm_i8_with,
               quantize_dynamic, GemmKernel, PackedI8};
pub use io::{load_weights, save_weights};
pub use isa::Isa;
pub use model::{Geometry, KernelInfo, LayerScales, NativeModel, RawLayer,
                Scratch, Tap, Weights};
pub use pool::{GemmPool, PoolPoisoned};

/// Fallback vocab rows for synthetic weights when the manifest does not
/// declare a vocab size.
const DEFAULT_SYNTHETIC_VOCAB: usize = 4096;

impl NativeModel {
    /// Build the native model for one task spec: load the exported weights
    /// file if the manifest names one and it exists, otherwise synthesize
    /// deterministic weights at the task's geometry (seeded by task name,
    /// so every process — and every variant — sees identical weights).
    pub fn for_spec(spec: &ModelSpec, weights_path: Option<&Path>,
                    vocab_size: usize) -> Result<NativeModel> {
        let mut model = Self::for_spec_uncalibrated(spec, weights_path,
                                                    vocab_size)?;
        // calibrated static activation scales from the manifest (written by
        // `samp plan`); layers without entries keep dynamic max-abs
        model.set_static_scales(
            LayerScales::from_manifest(&spec.scales, spec.layers))?;
        Ok(model)
    }

    /// [`NativeModel::for_spec`] without installing the manifest's static
    /// activation scales — the planner loads through this so its calibration
    /// pass measures from a clean slate before writing fresh scales.
    pub fn for_spec_uncalibrated(spec: &ModelSpec, weights_path: Option<&Path>,
                                 vocab_size: usize) -> Result<NativeModel> {
        if let Some(p) = weights_path {
            if p.exists() {
                let w = io::load_weights(p)?;
                let g = &w.geom;
                ensure!(g.hidden == spec.hidden && g.layers == spec.layers
                        && g.heads == spec.heads && g.ffn == spec.ffn
                        && g.num_labels == spec.num_labels,
                        "weights {} geometry {:?} does not match task {} spec",
                        p.display(), g, spec.task);
                ensure!(g.max_len >= spec.seq_len,
                        "weights {} max_len {} < task seq_len {}",
                        p.display(), g.max_len, spec.seq_len);
                // embed() clamps out-of-table ids, so a too-small embedding
                // table would silently corrupt most lookups — reject it
                ensure!(vocab_size == 0 || g.vocab >= vocab_size,
                        "weights {} vocab {} < serving vocab {} — tokens \
                         beyond the table would silently clamp",
                        p.display(), g.vocab, vocab_size);
                return NativeModel::new(w, spec.head_type.clone());
            }
        }
        let geom = Geometry {
            vocab: if vocab_size > 0 { vocab_size } else { DEFAULT_SYNTHETIC_VOCAB },
            max_len: spec.seq_len.max(1),
            type_vocab: 2,
            hidden: spec.hidden,
            layers: spec.layers,
            heads: spec.heads,
            ffn: spec.ffn,
            num_labels: spec.num_labels,
        };
        let w = Weights::synthetic(geom, fnv1a(spec.task.as_bytes()));
        NativeModel::new(w, spec.head_type.clone())
    }
}

/// FNV-1a — stable synthetic-weights seed from the task name.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Encoder half of the native backend: a shared model + this variant's
/// per-layer precision plan.
///
/// Reentrant by construction — `run_encoder` takes `&self` and a lane's N
/// dispatcher workers call it concurrently through one `Arc<dyn Backend>`.
/// Each concurrent call checks a [`Scratch`] out of a small pool (or
/// allocates one on a cold/contended start) and returns it afterwards, so
/// steady-state forwards reuse per-worker activation and quantization
/// buffers instead of allocating per batch.
pub struct NativeEncoder {
    model: Arc<NativeModel>,
    plan: Vec<LayerMode>,
    scratch: Mutex<Vec<Scratch>>,
}

/// Idle scratch sets kept per encoder: enough for a typical shard set
/// (`--workers-per-lane` defaults to at most 4) with headroom.
const SCRATCH_POOL_CAP: usize = 8;

impl NativeEncoder {
    pub fn new(model: Arc<NativeModel>, plan: Vec<LayerMode>)
               -> Result<NativeEncoder> {
        ensure!(plan.len() == model.geom().layers,
                "plan length {} != model layers {}", plan.len(),
                model.geom().layers);
        Ok(NativeEncoder { model, plan, scratch: Mutex::new(Vec::new()) })
    }

    /// Quantized-layer count of this variant's plan (diagnostics).
    pub fn quantized_layers(&self) -> usize {
        self.plan
            .iter()
            .filter(|m| matches!(m, LayerMode::Int8Ffn | LayerMode::Int8Full))
            .count()
    }

    /// Idle scratch sets currently pooled (test observability).
    pub fn idle_scratch(&self) -> usize {
        self.scratch.lock().unwrap().len()
    }
}

impl Backend for NativeEncoder {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn run_encoder(&self, b: &EncoderBatch) -> Result<Vec<f32>> {
        // fault injection (no-ops unless SAMP_FAULT / /v1/debug/fault armed):
        // a flat forward delay, plus a delay scaled by this plan's share of
        // full-precision layers — the knob overload tests use to make f32
        // genuinely slower than the INT8 ladder rung.
        if let Some(d) = fault::forward_delay() {
            std::thread::sleep(d);
        }
        let layers = self.plan.len().max(1);
        let fp32_frac = (layers - self.quantized_layers()) as f64
            / layers as f64;
        if let Some(d) = fault::fp32_delay(fp32_frac) {
            std::thread::sleep(d);
        }
        let mut sc = self.scratch.lock().unwrap().pop().unwrap_or_default();
        let out = self.model.forward_scratch(b, &self.plan, &mut sc);
        let mut pool = self.scratch.lock().unwrap();
        if pool.len() < SCRATCH_POOL_CAP {
            pool.push(sc);
        }
        out
    }

    fn is_poisoned(&self) -> bool {
        self.model.pool_poisoned()
    }

    fn run_head(&self, _hidden: &[f32], _batch: usize, _seq: usize,
                _hidden_dim: usize) -> Result<Vec<f32>> {
        bail!("native encoder backend does not serve heads")
    }
}

/// Head half of the native backend (shares the encoder's model).
pub struct NativeHead {
    model: Arc<NativeModel>,
}

impl NativeHead {
    pub fn new(model: Arc<NativeModel>) -> NativeHead {
        NativeHead { model }
    }
}

impl Backend for NativeHead {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn run_encoder(&self, _b: &EncoderBatch) -> Result<Vec<f32>> {
        bail!("native head backend does not serve encoders")
    }

    fn run_head(&self, hidden: &[f32], batch: usize, seq: usize,
                hidden_dim: usize) -> Result<Vec<f32>> {
        ensure!(hidden_dim == self.model.geom().hidden,
                "head hidden_dim {} != model hidden {}", hidden_dim,
                self.model.geom().hidden);
        self.model.head_forward(hidden, batch, seq)
    }

    fn is_poisoned(&self) -> bool {
        self.model.pool_poisoned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        use std::collections::BTreeMap;
        ModelSpec {
            task: "tnews".to_string(),
            kind: "classification".to_string(),
            num_labels: 3,
            seq_len: 8,
            batch: 2,
            hidden: 32,
            layers: 2,
            heads: 4,
            ffn: 64,
            head_hlo: "hlo/none.hlo.txt".to_string(),
            head_type: "classification".to_string(),
            weights: None,
            dev_accuracy_fp32: None,
            calibrator: "minmax".to_string(),
            scales: BTreeMap::new(),
            variants: BTreeMap::new(),
            dev_data: String::new(),
            dev_jsonl: String::new(),
            ner_labels: vec![],
        }
    }

    #[test]
    fn for_spec_synthesizes_and_is_deterministic() {
        let m1 = NativeModel::for_spec(&spec(), None, 128).unwrap();
        let m2 = NativeModel::for_spec(&spec(), None, 128).unwrap();
        assert_eq!(m1.weights.emb_tok, m2.weights.emb_tok);
        assert_eq!(m1.geom().vocab, 128);
        assert_eq!(m1.geom().hidden, 32);
    }

    #[test]
    fn for_spec_installs_manifest_static_scales() {
        let mut s = spec();
        s.scales.insert("l0/ffn_in".to_string(), 0.125);
        s.scales.insert("l1/attn_in".to_string(), 0.5);
        let m = NativeModel::for_spec(&s, None, 128).unwrap();
        assert_eq!(m.static_scales()[0].ffn_in, Some(0.125));
        assert_eq!(m.static_scales()[1].attn_in, Some(0.5));
        assert_eq!(m.static_scales()[1].ffn_in, None);
        // the uncalibrated loader leaves every tap dynamic
        let m = NativeModel::for_spec_uncalibrated(&s, None, 128).unwrap();
        assert!(m.static_scales().iter()
                    .all(|ls| *ls == LayerScales::default()));
    }

    #[test]
    fn for_spec_prefers_weights_file() {
        let dir = std::env::temp_dir().join("samp_for_spec");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tnews.natw");
        let geom = Geometry {
            vocab: 64,
            max_len: 8,
            type_vocab: 2,
            hidden: 32,
            layers: 2,
            heads: 4,
            ffn: 64,
            num_labels: 3,
        };
        let w = Weights::synthetic(geom, 99);
        save_weights(&path, &w).unwrap();
        let m = NativeModel::for_spec(&spec(), Some(path.as_path()), 4096)
            .unwrap();
        assert_eq!(m.weights.emb_tok, w.emb_tok);
        assert_eq!(m.geom().vocab, 64);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn for_spec_rejects_geometry_mismatch() {
        let dir = std::env::temp_dir().join("samp_for_spec_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.natw");
        let geom = Geometry {
            vocab: 64,
            max_len: 8,
            type_vocab: 2,
            hidden: 16, // != spec.hidden 32
            layers: 2,
            heads: 4,
            ffn: 64,
            num_labels: 3,
        };
        save_weights(&path, &Weights::synthetic(geom, 1)).unwrap();
        assert!(NativeModel::for_spec(&spec(), Some(path.as_path()), 64)
                    .is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn encoder_and_head_roundtrip_via_backend_trait() {
        let model = Arc::new(NativeModel::for_spec(&spec(), None, 64).unwrap());
        let enc = NativeEncoder::new(
            model.clone(),
            vec![LayerMode::Int8Full, LayerMode::Fp16]).unwrap();
        assert_eq!(enc.quantized_layers(), 1);
        let head = NativeHead::new(model);
        let mut b = EncoderBatch::zeros(2, 8);
        b.set_row(0, &[2, 5, 9, 3, 0, 0, 0, 0], &[0; 8],
                  &[1, 1, 1, 1, 0, 0, 0, 0]);
        let backend: &dyn Backend = &enc;
        assert_eq!(backend.backend_name(), "native");
        let hidden = backend.run_encoder(&b).unwrap();
        assert_eq!(hidden.len(), 2 * 8 * 32);
        let logits = head.run_head(&hidden, 2, 8, 32).unwrap();
        assert_eq!(logits.len(), 2 * 3);
        assert!(logits.iter().all(|x| x.is_finite()));
        // wrong halves error
        assert!(enc.run_head(&hidden, 2, 8, 32).is_err());
        assert!(head.run_encoder(&b).is_err());
    }

    #[test]
    fn plan_length_checked_at_construction() {
        let model = Arc::new(NativeModel::for_spec(&spec(), None, 64).unwrap());
        assert!(NativeEncoder::new(model, vec![LayerMode::Fp16]).is_err());
    }

    #[test]
    fn encoder_pools_scratch_across_calls_and_workers() {
        let model = Arc::new(NativeModel::for_spec(&spec(), None, 64).unwrap());
        let enc = Arc::new(NativeEncoder::new(
            model, vec![LayerMode::Int8Full, LayerMode::Fp16]).unwrap());
        let mut b = EncoderBatch::zeros(2, 8);
        b.set_row(0, &[2, 5, 9, 3, 0, 0, 0, 0], &[0; 8],
                  &[1, 1, 1, 1, 0, 0, 0, 0]);
        assert_eq!(enc.idle_scratch(), 0);
        let h1 = enc.run_encoder(&b).unwrap();
        assert_eq!(enc.idle_scratch(), 1, "scratch must return to the pool");
        let h2 = enc.run_encoder(&b).unwrap();
        assert_eq!(enc.idle_scratch(), 1, "reuse must not grow the pool");
        assert_eq!(h1, h2, "scratch reuse changed the forward");
        // concurrent workers each get (and return) a scratch
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let enc = enc.clone();
                let b = b.clone();
                std::thread::spawn(move || enc.run_encoder(&b).unwrap())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), h1);
        }
        let idle = enc.idle_scratch();
        assert!((1..=4).contains(&idle), "idle scratch {idle}");
    }
}
