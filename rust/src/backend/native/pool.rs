//! A small persistent worker pool that parallelizes **one GEMM across its
//! batch rows**.  The native backend owns one pool per model replica; the
//! per-worker `Scratch` pool already keeps layer state disjoint, and a GEMM
//! partitions its output rows into contiguous, non-overlapping `&mut`
//! chunks, so the threading boundary carries no shared mutable state at
//! all — a threaded GEMM is bit-identical to the single-threaded one by
//! construction.
//!
//! Design notes (offline environment: no crossbeam/rayon):
//!
//! * Workers are spawned once and live as long as the pool; a GEMM call
//!   hands each worker a boxed closure over an `mpsc` channel and runs one
//!   partition itself, then blocks until every job has signalled a
//!   per-call completion channel.  That strict join is what makes the
//!   lifetime-erasing transmute in [`GemmPool::run`] sound: no job can
//!   outlive the borrows it captured.
//! * Jobs run under `catch_unwind`; the worker records the panic in a
//!   poison flag **before** signalling completion, and `run` surfaces it
//!   on the *calling* thread as a typed [`PoolPoisoned`] error — a
//!   crashing kernel job degrades the request instead of panicking the
//!   dispatcher, and can't silently corrupt one output tile or deadlock
//!   the next GEMM.  The poison is sticky: the replica that owns the pool
//!   is expected to retire and rebuild through the registry's generation
//!   machinery (`ReplicaSet::heal`).
//! * Each worker optionally pins itself to a core
//!   (`util::affinity::try_pin`) before serving jobs; the observed outcome
//!   is reported so `/v1/models` can show real pinning, not intent.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::fault;
use crate::util::affinity;

/// Typed error for a pool whose worker job panicked: the partial GEMM
/// output is untrustworthy and the pool refuses further work until its
/// owning replica is rebuilt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolPoisoned;

impl std::fmt::Display for PoolPoisoned {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "gemm pool poisoned: a worker job panicked")
    }
}

impl std::error::Error for PoolPoisoned {}

/// One queued row-partition job plus its caller's completion channel.
struct WorkItem {
    job: Box<dyn FnOnce() + Send + 'static>,
    done: mpsc::Sender<()>,
}

/// Persistent row-partition workers for the native GEMMs.
pub struct GemmPool {
    /// One queue per worker (senders are mutex-wrapped so the pool is
    /// `Sync` without leaning on `mpsc::Sender`'s `Sync`-ness).
    senders: Vec<Mutex<mpsc::Sender<WorkItem>>>,
    handles: Vec<JoinHandle<()>>,
    /// Core each worker actually landed on (`None` = unpinned).
    pinned: Vec<Option<usize>>,
    /// Total parallelism of a GEMM through this pool, caller included.
    threads: usize,
    poisoned: Arc<AtomicBool>,
}

impl GemmPool {
    /// Build a pool giving GEMMs `threads`-way parallelism (the calling
    /// thread counts, so `threads - 1` workers are spawned; `threads <= 1`
    /// spawns none).  When `cores` is non-empty, worker `i` pins itself to
    /// `cores[i % cores.len()]`, best-effort.
    pub fn new(threads: usize, cores: &[usize]) -> GemmPool {
        let threads = threads.max(1);
        let poisoned = Arc::new(AtomicBool::new(false));
        let mut senders = Vec::with_capacity(threads - 1);
        let mut handles = Vec::with_capacity(threads - 1);
        let mut pinned = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let core = (!cores.is_empty()).then(|| cores[i % cores.len()]);
            let (tx, rx) = mpsc::channel::<WorkItem>();
            let (ready_tx, ready_rx) = mpsc::channel::<Option<usize>>();
            let p = poisoned.clone();
            handles.push(std::thread::spawn(move || {
                let got = core.and_then(affinity::try_pin);
                let _ = ready_tx.send(got);
                while let Ok(item) = rx.recv() {
                    if catch_unwind(AssertUnwindSafe(item.job)).is_err() {
                        // poison *before* done: the caller's recv of the
                        // done signal orders this store before its check
                        p.store(true, Ordering::SeqCst);
                    }
                    let _ = item.done.send(());
                }
            }));
            // the worker reports its pin outcome before serving jobs, so
            // construction returns with accurate `pinned()` data
            pinned.push(ready_rx.recv().unwrap_or(None));
            senders.push(Mutex::new(tx));
        }
        GemmPool { senders, handles, pinned, threads, poisoned }
    }

    /// Parallelism a GEMM gets through this pool (caller included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Observed pin outcome per worker thread.
    pub fn pinned(&self) -> &[Option<usize>] {
        &self.pinned
    }

    /// True once any worker job has panicked; the pool stays poisoned for
    /// the rest of its life (its owning replica must be rebuilt).
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// Run `jobs` on the workers while executing `local` (the caller's own
    /// partition) on this thread; returns only after **every** job has
    /// finished.  Returns [`PoolPoisoned`] if any job — this call's or an
    /// earlier one's — panicked; the output buffers the jobs wrote into
    /// must then be discarded.
    ///
    /// Concurrent `run` calls from different dispatcher threads interleave
    /// safely: each call waits on its own completion channel, and jobs are
    /// self-contained closures.
    pub fn run<'scope>(&self,
                       jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>,
                       local: impl FnOnce())
                       -> Result<(), PoolPoisoned> {
        if self.senders.is_empty() {
            // no workers (threads <= 1): degenerate inline execution
            for job in jobs {
                job();
            }
            local();
            return Ok(());
        }
        // sticky poison: refuse new work instead of computing on a pool
        // whose previous output was partially written by a dead job
        if self.is_poisoned() {
            return Err(PoolPoisoned);
        }
        let mut jobs = jobs;
        if fault::gemm_panic_armed() {
            jobs.push(Box::new(|| panic!("injected gemm fault (SAMP_FAULT)")));
        }
        let n = jobs.len();
        let (done_tx, done_rx) = mpsc::channel::<()>();
        for (i, job) in jobs.into_iter().enumerate() {
            // SAFETY: the loop below blocks until all `n` jobs have
            // signalled `done_rx` (the worker signals even on panic), so
            // no job — executed or unwound — outlives 'scope.
            let job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>,
                                      Box<dyn FnOnce() + Send + 'static>>(job)
            };
            self.senders[i % self.senders.len()]
                .lock()
                .unwrap()
                .send(WorkItem { job, done: done_tx.clone() })
                .expect("gemm pool worker died with the pool still alive");
        }
        drop(done_tx);
        local();
        for _ in 0..n {
            if done_rx.recv().is_err() {
                break; // every sender dropped: all jobs consumed
            }
        }
        if self.is_poisoned() {
            return Err(PoolPoisoned);
        }
        Ok(())
    }
}

impl Drop for GemmPool {
    fn drop(&mut self) {
        self.senders.clear(); // closes every queue -> workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_scoped_jobs_to_completion() {
        let pool = GemmPool::new(4, &[]);
        assert_eq!(pool.threads(), 4);
        assert_eq!(pool.pinned().len(), 3);
        let mut out = vec![0usize; 64];
        {
            let mut rest = out.as_mut_slice();
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut base = 0usize;
            for _ in 0..3 {
                let (chunk, tail) = rest.split_at_mut(16);
                rest = tail;
                let start = base;
                jobs.push(Box::new(move || {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = start + i;
                    }
                }));
                base += 16;
            }
            let local = rest;
            pool.run(jobs, move || {
                for (i, v) in local.iter_mut().enumerate() {
                    *v = 48 + i;
                }
            })
            .unwrap();
        }
        let want: Vec<usize> = (0..64).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn pool_survives_many_small_runs() {
        let pool = GemmPool::new(3, &[]);
        let hits = AtomicUsize::new(0);
        for _ in 0..50 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                .map(|_| {
                    let h = &hits;
                    Box::new(move || {
                        h.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run(jobs, || {
                hits.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        assert_eq!(hits.load(Ordering::Relaxed), 150);
    }

    #[test]
    fn panicking_job_poisons_the_pool_without_deadlock() {
        let pool = GemmPool::new(2, &[]);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("kernel bug"))];
        assert_eq!(pool.run(jobs, || {}), Err(PoolPoisoned));
        assert!(pool.is_poisoned());
    }

    #[test]
    fn poisoned_pool_stays_poisoned_and_rejects_new_work() {
        let pool = GemmPool::new(2, &[]);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("kernel bug"))];
        assert!(pool.run(jobs, || {}).is_err());
        // the next run must fail fast without touching its jobs (sticky
        // poison), and must not deadlock on the completion channel
        let ran = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| {
            ran.fetch_add(1, Ordering::Relaxed);
        })];
        assert_eq!(pool.run(jobs, || {}), Err(PoolPoisoned));
        assert_eq!(ran.load(Ordering::Relaxed), 0);
        assert!(pool.is_poisoned());
    }

    #[test]
    fn single_thread_pool_spawns_no_workers() {
        let pool = GemmPool::new(1, &[0]);
        assert_eq!(pool.threads(), 1);
        assert!(pool.pinned().is_empty());
        let ran = AtomicUsize::new(0);
        pool.run(Vec::new(), || {
            ran.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }
}
