//! Native encoder model: weights + the mixed-precision forward pass.
//!
//! One [`NativeModel`] per task; every precision variant of the task shares
//! it (the weights are identical — only the per-layer [`LayerMode`] plan
//! changes which GEMM kernel a layer dispatches to).  INT8 weight panels are
//! quantized + packed once at construction, so switching a layer between
//! f32 and INT8 at serving time costs nothing.
//!
//! Layer semantics mirror `python/compile/model.py`:
//!
//! * `Fp32` / `Fp16` — the f32 reference path (this backend computes all
//!   floating math in f32; f16 storage is a GPU concern).
//! * `Int8Ffn` — Quant-FFN-Only (Fig 2b): MHA floating, the two FFN GEMMs
//!   INT8.
//! * `Int8Full` — Fully-Quant (Fig 2a): the four projection GEMMs
//!   (Q/K/V/output) *and* both FFN GEMMs run INT8.  The attention core
//!   (QK^T, softmax, PV) stays f32 here — on CPU those are small
//!   batch-strided products where quantization buys little and costs
//!   accuracy (the Appendix-B softmax culprit), so the native backend keeps
//!   the paper's weight-GEMM quantization and skips its score quantization.

use anyhow::{ensure, Result};

use crate::latency::LayerMode;
use crate::runtime::EncoderBatch;
use crate::util::prng::Prng;

use super::gemm::{dot_f32, gemm_f32, gemm_i8, quantize_dynamic, PackedI8};

const LN_EPS: f32 = 1e-12;

/// Static geometry of a native model (mirrors python `ModelConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    pub vocab: usize,
    pub max_len: usize,
    pub type_vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub num_labels: usize,
}

impl Geometry {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }
}

/// Raw f32 weights of one transformer layer (row-major, `x @ W` layout).
#[derive(Debug, Clone)]
pub struct RawLayer {
    pub wq: Vec<f32>,
    pub bq: Vec<f32>,
    pub wk: Vec<f32>,
    pub bk: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
    pub wo: Vec<f32>,
    pub bo: Vec<f32>,
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
}

/// Full raw weight set (what the binary weights file stores).
#[derive(Debug, Clone)]
pub struct Weights {
    pub geom: Geometry,
    pub emb_tok: Vec<f32>,
    pub emb_seg: Vec<f32>,
    pub emb_pos: Vec<f32>,
    pub emb_ln_g: Vec<f32>,
    pub emb_ln_b: Vec<f32>,
    pub layers: Vec<RawLayer>,
    pub pool_w: Vec<f32>,
    pub pool_b: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

impl Weights {
    /// Deterministic synthetic weights (BERT-style clipped-normal amplitude)
    /// for environments with no exported weights file: serving, benches and
    /// tests get a real computable encoder whose outputs are stable across
    /// runs for a given (geometry, seed).
    pub fn synthetic(geom: Geometry, seed: u64) -> Weights {
        let mut p = Prng::new(seed);
        let mut t = |len: usize| -> Vec<f32> {
            (0..len).map(|_| (p.f64() as f32 * 2.0 - 1.0) * 0.04).collect()
        };
        let h = geom.hidden;
        let f = geom.ffn;
        let mut layers = Vec::with_capacity(geom.layers);
        for _ in 0..geom.layers {
            layers.push(RawLayer {
                wq: t(h * h),
                bq: t(h),
                wk: t(h * h),
                bk: t(h),
                wv: t(h * h),
                bv: t(h),
                wo: t(h * h),
                bo: t(h),
                ln1_g: vec![1.0; h],
                ln1_b: vec![0.0; h],
                w1: t(h * f),
                b1: t(f),
                w2: t(f * h),
                b2: t(h),
                ln2_g: vec![1.0; h],
                ln2_b: vec![0.0; h],
            });
        }
        Weights {
            emb_tok: t(geom.vocab * h),
            emb_seg: t(geom.type_vocab * h),
            emb_pos: t(geom.max_len * h),
            emb_ln_g: vec![1.0; h],
            emb_ln_b: vec![0.0; h],
            layers,
            pool_w: t(h * h),
            pool_b: t(h),
            head_w: t(h * geom.num_labels),
            head_b: t(geom.num_labels),
            geom,
        }
    }

    /// Validate every tensor length against the geometry.
    pub fn validate(&self) -> Result<()> {
        let g = &self.geom;
        ensure!(g.hidden > 0 && g.heads > 0 && g.hidden % g.heads == 0,
                "hidden {} not divisible by heads {}", g.hidden, g.heads);
        ensure!(g.vocab > 0 && g.type_vocab > 0 && g.max_len > 0
                && g.layers > 0 && g.ffn > 0 && g.num_labels > 0,
                "degenerate geometry {:?}", g);
        ensure!(self.emb_tok.len() == g.vocab * g.hidden, "emb_tok shape");
        ensure!(self.emb_seg.len() == g.type_vocab * g.hidden, "emb_seg shape");
        ensure!(self.emb_pos.len() == g.max_len * g.hidden, "emb_pos shape");
        ensure!(self.emb_ln_g.len() == g.hidden, "emb_ln_g shape");
        ensure!(self.emb_ln_b.len() == g.hidden, "emb_ln_b shape");
        ensure!(self.layers.len() == g.layers, "layer count");
        for (l, lw) in self.layers.iter().enumerate() {
            for (nm, t, want) in [
                ("wq", &lw.wq, g.hidden * g.hidden),
                ("wk", &lw.wk, g.hidden * g.hidden),
                ("wv", &lw.wv, g.hidden * g.hidden),
                ("wo", &lw.wo, g.hidden * g.hidden),
                ("w1", &lw.w1, g.hidden * g.ffn),
                ("w2", &lw.w2, g.ffn * g.hidden),
                ("bq", &lw.bq, g.hidden),
                ("bk", &lw.bk, g.hidden),
                ("bv", &lw.bv, g.hidden),
                ("bo", &lw.bo, g.hidden),
                ("b1", &lw.b1, g.ffn),
                ("b2", &lw.b2, g.hidden),
                ("ln1_g", &lw.ln1_g, g.hidden),
                ("ln1_b", &lw.ln1_b, g.hidden),
                ("ln2_g", &lw.ln2_g, g.hidden),
                ("ln2_b", &lw.ln2_b, g.hidden),
            ] {
                ensure!(t.len() == want, "layer {l}: {nm} shape {} != {want}",
                        t.len());
            }
        }
        ensure!(self.pool_w.len() == g.hidden * g.hidden, "pool_w shape");
        ensure!(self.pool_b.len() == g.hidden, "pool_b shape");
        ensure!(self.head_w.len() == g.hidden * g.num_labels, "head_w shape");
        ensure!(self.head_b.len() == g.num_labels, "head_b shape");
        Ok(())
    }
}

/// Pre-packed INT8 panels of one layer's six GEMM weights.
#[derive(Debug, Clone)]
struct PackedLayer {
    wq: PackedI8,
    wk: PackedI8,
    wv: PackedI8,
    wo: PackedI8,
    w1: PackedI8,
    w2: PackedI8,
}

/// Weights + packed panels + head type: everything the native backend needs
/// to run a task end to end.
pub struct NativeModel {
    pub weights: Weights,
    pub head_type: String,
    packed: Vec<PackedLayer>,
}

/// Per-forward scratch buffers (one allocation set per `forward` call; the
/// engine math dominates at serving shapes).
struct Scratch {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    tmp_h: Vec<f32>,
    ffn1: Vec<f32>,
    probs: Vec<f32>,
    qbuf: Vec<i8>,
}

impl Scratch {
    fn new(rows: usize, seq: usize, geom: &Geometry) -> Scratch {
        Scratch {
            q: vec![0.0; rows * geom.hidden],
            k: vec![0.0; rows * geom.hidden],
            v: vec![0.0; rows * geom.hidden],
            ctx: vec![0.0; rows * geom.hidden],
            tmp_h: vec![0.0; rows * geom.hidden],
            ffn1: vec![0.0; rows * geom.ffn],
            probs: vec![0.0; seq],
            qbuf: Vec::new(),
        }
    }
}

impl NativeModel {
    /// Build from raw weights (validates shapes, packs INT8 panels).
    pub fn new(weights: Weights, head_type: impl Into<String>)
               -> Result<NativeModel> {
        weights.validate()?;
        let g = weights.geom;
        let packed = weights
            .layers
            .iter()
            .map(|lw| PackedLayer {
                wq: PackedI8::pack(&lw.wq, g.hidden, g.hidden),
                wk: PackedI8::pack(&lw.wk, g.hidden, g.hidden),
                wv: PackedI8::pack(&lw.wv, g.hidden, g.hidden),
                wo: PackedI8::pack(&lw.wo, g.hidden, g.hidden),
                w1: PackedI8::pack(&lw.w1, g.hidden, g.ffn),
                w2: PackedI8::pack(&lw.w2, g.ffn, g.hidden),
            })
            .collect();
        Ok(NativeModel { weights, head_type: head_type.into(), packed })
    }

    pub fn geom(&self) -> &Geometry {
        &self.weights.geom
    }

    /// Mixed-precision encoder forward: `[B, S]` inputs -> `[B, S, H]`
    /// hidden states, each layer dispatched per `plan`.
    pub fn forward(&self, b: &EncoderBatch, plan: &[LayerMode])
                   -> Result<Vec<f32>> {
        let g = self.weights.geom;
        ensure!(plan.len() == g.layers,
                "plan length {} != layers {}", plan.len(), g.layers);
        ensure!(b.ids.len() == b.batch * b.seq, "batch shape mismatch");
        let rows = b.batch * b.seq;
        let mut h = vec![0f32; rows * g.hidden];
        self.embed(b, &mut h);
        // additive attention bias per key position: 0 keep / -1e9 pad
        let mask_bias: Vec<f32> = b
            .attention_mask
            .iter()
            .map(|&m| (1.0 - m) * -1e9)
            .collect();
        let mut sc = Scratch::new(rows, b.seq, &g);
        for (l, &mode) in plan.iter().enumerate() {
            self.layer(&mut h, l, mode, b.batch, b.seq, &mask_bias, &mut sc);
        }
        Ok(h)
    }

    /// The pure-f32 reference forward (every layer on the reference path) —
    /// the baseline the INT8 parity tests and `bench_gemm` compare against.
    pub fn forward_f32(&self, b: &EncoderBatch) -> Result<Vec<f32>> {
        let plan = vec![LayerMode::Fp32; self.weights.geom.layers];
        self.forward(b, &plan)
    }

    /// Downstream head: `[B, S, H]` hidden -> logits.
    ///
    /// * classification / matching: tanh pooler over the CLS token, then the
    ///   label projection -> `[B, num_labels]`;
    /// * ner: per-token label projection -> `[B, S, num_labels]`.
    pub fn head_forward(&self, hidden: &[f32], b: usize, s: usize)
                        -> Result<Vec<f32>> {
        let g = self.weights.geom;
        let h = g.hidden;
        let nl = g.num_labels;
        ensure!(hidden.len() == b * s * h,
                "hidden shape {} != {}x{}x{}", hidden.len(), b, s, h);
        if self.head_type == "ner" {
            let mut out = vec![0f32; b * s * nl];
            gemm_f32(hidden, &self.weights.head_w, Some(&self.weights.head_b),
                     b * s, h, nl, &mut out);
            return Ok(out);
        }
        let mut cls = vec![0f32; b * h];
        for bi in 0..b {
            cls[bi * h..(bi + 1) * h]
                .copy_from_slice(&hidden[bi * s * h..bi * s * h + h]);
        }
        let mut pooled = vec![0f32; b * h];
        gemm_f32(&cls, &self.weights.pool_w, Some(&self.weights.pool_b),
                 b, h, h, &mut pooled);
        for x in pooled.iter_mut() {
            *x = x.tanh();
        }
        let mut out = vec![0f32; b * nl];
        gemm_f32(&pooled, &self.weights.head_w, Some(&self.weights.head_b),
                 b, h, nl, &mut out);
        Ok(out)
    }

    /// Fused token+segment+position embedding + LayerNorm.  Out-of-range
    /// ids clamp to the table edge (the tokenizer and table are built from
    /// the same vocab, so this only matters for synthetic weights smaller
    /// than the serving vocab).
    fn embed(&self, b: &EncoderBatch, h: &mut [f32]) {
        let g = self.weights.geom;
        let hd = g.hidden;
        for r in 0..b.batch {
            for t in 0..b.seq {
                let row = r * b.seq + t;
                let id = (b.ids[row].max(0) as usize).min(g.vocab - 1);
                let seg = (b.segment_ids[row].max(0) as usize)
                    .min(g.type_vocab - 1);
                let pos = t.min(g.max_len - 1);
                let tok = &self.weights.emb_tok[id * hd..(id + 1) * hd];
                let sg = &self.weights.emb_seg[seg * hd..(seg + 1) * hd];
                let ps = &self.weights.emb_pos[pos * hd..(pos + 1) * hd];
                let out = &mut h[row * hd..(row + 1) * hd];
                for (((o, &tk), &sv), &pv) in
                    out.iter_mut().zip(tok).zip(sg).zip(ps)
                {
                    *o = tk + sv + pv;
                }
                layernorm_row(out, &self.weights.emb_ln_g,
                              &self.weights.emb_ln_b);
            }
        }
    }

    /// One transformer layer, updating `h` in place.
    #[allow(clippy::too_many_arguments)]
    fn layer(&self, h: &mut [f32], l: usize, mode: LayerMode, b: usize,
             s: usize, mask_bias: &[f32], sc: &mut Scratch) {
        let g = self.weights.geom;
        let hsz = g.hidden;
        let rows = b * s;
        let lw = &self.weights.layers[l];
        let pk = &self.packed[l];
        let int8_proj = mode == LayerMode::Int8Full;
        let int8_ffn = matches!(mode, LayerMode::Int8Full | LayerMode::Int8Ffn);

        // Q/K/V projections
        if int8_proj {
            let sa = quantize_dynamic(h, &mut sc.qbuf);
            gemm_i8(&sc.qbuf, sa, &pk.wq, Some(&lw.bq), rows, &mut sc.q);
            gemm_i8(&sc.qbuf, sa, &pk.wk, Some(&lw.bk), rows, &mut sc.k);
            gemm_i8(&sc.qbuf, sa, &pk.wv, Some(&lw.bv), rows, &mut sc.v);
        } else {
            gemm_f32(h, &lw.wq, Some(&lw.bq), rows, hsz, hsz, &mut sc.q);
            gemm_f32(h, &lw.wk, Some(&lw.bk), rows, hsz, hsz, &mut sc.k);
            gemm_f32(h, &lw.wv, Some(&lw.bv), rows, hsz, hsz, &mut sc.v);
        }

        // attention core (always f32 — see module docs)
        attention(&sc.q, &sc.k, &sc.v, mask_bias, b, s, g.heads,
                  g.head_dim(), &mut sc.ctx, &mut sc.probs);

        // output projection (bias folds into the LN epilogue)
        if int8_proj {
            let sctx = quantize_dynamic(&sc.ctx, &mut sc.qbuf);
            gemm_i8(&sc.qbuf, sctx, &pk.wo, None, rows, &mut sc.tmp_h);
        } else {
            gemm_f32(&sc.ctx, &lw.wo, None, rows, hsz, hsz, &mut sc.tmp_h);
        }
        // h1 = LN(attn_out + bo + h)
        add_bias_residual_layernorm(h, &sc.tmp_h, &lw.bo, &lw.ln1_g,
                                    &lw.ln1_b, hsz);

        // FFN
        if int8_ffn {
            let sh = quantize_dynamic(h, &mut sc.qbuf);
            gemm_i8(&sc.qbuf, sh, &pk.w1, None, rows, &mut sc.ffn1);
            bias_gelu(&mut sc.ffn1, &lw.b1, g.ffn);
            let sact = quantize_dynamic(&sc.ffn1, &mut sc.qbuf);
            gemm_i8(&sc.qbuf, sact, &pk.w2, None, rows, &mut sc.tmp_h);
        } else {
            gemm_f32(h, &lw.w1, None, rows, hsz, g.ffn, &mut sc.ffn1);
            bias_gelu(&mut sc.ffn1, &lw.b1, g.ffn);
            gemm_f32(&sc.ffn1, &lw.w2, None, rows, g.ffn, hsz, &mut sc.tmp_h);
        }
        // h2 = LN(ffn2 + b2 + h1)
        add_bias_residual_layernorm(h, &sc.tmp_h, &lw.b2, &lw.ln2_g,
                                    &lw.ln2_b, hsz);
    }
}

/// Multi-head scaled-dot-product attention over `[rows, H]` Q/K/V, context
/// written to `ctx`.  `mask_bias` is per key position (`[B*S]`, 0 / -1e9).
#[allow(clippy::too_many_arguments)]
fn attention(q: &[f32], k: &[f32], v: &[f32], mask_bias: &[f32], b: usize,
             s: usize, heads: usize, hd: usize, ctx: &mut [f32],
             probs: &mut [f32]) {
    let h = heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    for bi in 0..b {
        for hh in 0..heads {
            for i in 0..s {
                let qo = (bi * s + i) * h + hh * hd;
                let qrow = &q[qo..qo + hd];
                let mut max = f32::NEG_INFINITY;
                for (j, pj) in probs.iter_mut().enumerate().take(s) {
                    let ko = (bi * s + j) * h + hh * hd;
                    let score = dot_f32(qrow, &k[ko..ko + hd]) * scale
                        + mask_bias[bi * s + j];
                    *pj = score;
                    max = max.max(score);
                }
                let mut sum = 0f32;
                for pj in probs.iter_mut().take(s) {
                    *pj = (*pj - max).exp();
                    sum += *pj;
                }
                let inv = 1.0 / sum;
                let crow = &mut ctx[qo..qo + hd];
                crow.fill(0.0);
                for (j, pj) in probs.iter().enumerate().take(s) {
                    let p = *pj * inv;
                    let vo = (bi * s + j) * h + hh * hd;
                    let vrow = &v[vo..vo + hd];
                    for (c, &vv) in crow.iter_mut().zip(vrow.iter()) {
                        *c += p * vv;
                    }
                }
            }
        }
    }
}

/// LayerNorm one row in place.
fn layernorm_row(row: &mut [f32], g: &[f32], b: &[f32]) {
    let n = row.len() as f32;
    let mean = row.iter().sum::<f32>() / n;
    let var = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + LN_EPS).sqrt();
    for (j, x) in row.iter_mut().enumerate() {
        *x = (*x - mean) * inv * g[j] + b[j];
    }
}

/// The fused big-kernel epilogue: `h = LN(x + bias + h)` row by row
/// (bias+residual+LayerNorm, the paper's Fig-2 "big kernel").
fn add_bias_residual_layernorm(h: &mut [f32], x: &[f32], bias: &[f32],
                               g: &[f32], b: &[f32], hidden: usize) {
    debug_assert_eq!(h.len(), x.len());
    let rows = h.len() / hidden;
    for r in 0..rows {
        let hrow = &mut h[r * hidden..(r + 1) * hidden];
        let xrow = &x[r * hidden..(r + 1) * hidden];
        for (j, hx) in hrow.iter_mut().enumerate() {
            *hx += xrow[j] + bias[j];
        }
        layernorm_row(hrow, g, b);
    }
}

/// GELU (tanh approximation) fused with its bias add, in place.
fn bias_gelu(x: &mut [f32], bias: &[f32], width: usize) {
    let rows = x.len() / width;
    for r in 0..rows {
        let row = &mut x[r * width..(r + 1) * width];
        for (j, v) in row.iter_mut().enumerate() {
            let t = *v + bias[j];
            *v = 0.5 * t
                * (1.0 + (0.797_884_6 * (t + 0.044_715 * t * t * t)).tanh());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_geom() -> Geometry {
        Geometry {
            vocab: 64,
            max_len: 16,
            type_vocab: 2,
            hidden: 32,
            layers: 2,
            heads: 4,
            ffn: 64,
            num_labels: 3,
        }
    }

    fn tiny_model(head_type: &str) -> NativeModel {
        NativeModel::new(Weights::synthetic(tiny_geom(), 42), head_type)
            .unwrap()
    }

    fn tiny_batch() -> EncoderBatch {
        let mut b = EncoderBatch::zeros(2, 8);
        b.set_row(0, &[2, 5, 9, 3, 0, 0, 0, 0], &[0; 8],
                  &[1, 1, 1, 1, 0, 0, 0, 0]);
        b.set_row(1, &[2, 7, 3, 0, 0, 0, 0, 0], &[0; 8],
                  &[1, 1, 1, 0, 0, 0, 0, 0]);
        b
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = tiny_model("classification");
        let g = *m.geom();
        let plan = vec![LayerMode::Fp16; g.layers];
        let h = m.forward(&tiny_batch(), &plan).unwrap();
        assert_eq!(h.len(), 2 * 8 * g.hidden);
        assert!(h.iter().all(|x| x.is_finite()));
        // layernormed rows have ~zero mean
        let row = &h[..g.hidden];
        let mean: f32 = row.iter().sum::<f32>() / g.hidden as f32;
        assert!(mean.abs() < 1e-4, "mean {mean}");
    }

    #[test]
    fn head_shapes_per_task_kind() {
        let b = tiny_batch();
        let m = tiny_model("classification");
        let h = m.forward_f32(&b).unwrap();
        assert_eq!(m.head_forward(&h, 2, 8).unwrap().len(), 2 * 3);
        let m = tiny_model("ner");
        let h = m.forward_f32(&b).unwrap();
        assert_eq!(m.head_forward(&h, 2, 8).unwrap().len(), 2 * 8 * 3);
    }

    #[test]
    fn int8_forward_close_to_f32() {
        let m = tiny_model("classification");
        let g = *m.geom();
        let b = tiny_batch();
        let f = m.forward_f32(&b).unwrap();
        for mode in [LayerMode::Int8Ffn, LayerMode::Int8Full] {
            let q = m.forward(&b, &vec![mode; g.layers]).unwrap();
            // post-LN activations are O(1); dynamic per-tensor INT8 keeps
            // the drift small
            let max_err = f
                .iter()
                .zip(&q)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 0.35, "{mode:?}: max err {max_err}");
            assert!(q.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn mixed_plan_runs() {
        let m = tiny_model("matching");
        let plan = vec![LayerMode::Int8Full, LayerMode::Fp16];
        let h = m.forward(&tiny_batch(), &plan).unwrap();
        assert!(h.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn bad_plan_length_rejected() {
        let m = tiny_model("classification");
        assert!(m.forward(&tiny_batch(), &[LayerMode::Fp16]).is_err());
    }

    #[test]
    fn synthetic_weights_are_deterministic() {
        let a = Weights::synthetic(tiny_geom(), 7);
        let b = Weights::synthetic(tiny_geom(), 7);
        assert_eq!(a.emb_tok, b.emb_tok);
        assert_eq!(a.layers[1].w2, b.layers[1].w2);
        let c = Weights::synthetic(tiny_geom(), 8);
        assert_ne!(a.emb_tok, c.emb_tok);
    }
}
