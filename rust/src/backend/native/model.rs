//! Native encoder model: weights + the mixed-precision forward pass.
//!
//! One [`NativeModel`] per task; every precision variant of the task shares
//! it (the weights are identical — only the per-layer [`LayerMode`] plan
//! changes which GEMM kernel a layer dispatches to).  INT8 weight panels are
//! quantized + packed once at construction, so switching a layer between
//! f32 and INT8 at serving time costs nothing.
//!
//! Layer semantics mirror `python/compile/model.py`:
//!
//! * `Fp32` / `Fp16` — the f32 reference path (this backend computes all
//!   floating math in f32; f16 storage is a GPU concern).
//! * `Int8Ffn` — Quant-FFN-Only (Fig 2b): MHA floating, the two FFN GEMMs
//!   INT8.
//! * `Int8Full` — Fully-Quant (Fig 2a): the four projection GEMMs
//!   (Q/K/V/output) *and* both FFN GEMMs run INT8.  The attention core
//!   (QK^T, softmax, PV) stays f32 here — on CPU those are small
//!   batch-strided products where quantization buys little and costs
//!   accuracy (the Appendix-B softmax culprit), so the native backend keeps
//!   the paper's weight-GEMM quantization and skips its score quantization.
//!
//! # Activation quantization: static vs dynamic scales
//!
//! Each INT8 layer quantizes activations at up to four sites ([`Tap`]): the
//! Q/K/V input, the attention context (output-projection input), the FFN
//! input, and the post-GELU FFN activation.  By default the scale is
//! *dynamic* (per-tensor max-abs of the live batch).  When the manifest's
//! `scales` map carries a calibrated entry for a tap (`l{i}/attn_in` etc.,
//! written by the `planner` subsystem), that *static* scale is used instead
//! — the paper's fixed-scale engine behaviour, which removes the amax
//! reduction from the hot path and makes serving-time numerics independent
//! of batch composition.  [`NativeModel::act_quant_modes`] reports which
//! source each layer ended up with (surfaced in pipeline debug logs and
//! `GET /v1/plan`).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::latency::LayerMode;
use crate::runtime::EncoderBatch;
use crate::util::prng::Prng;

use super::gemm::{dot_f32, gemm_f32_with, gemm_i8_with, quantize_dynamic,
                  GemmKernel, PackedI8};
use super::isa::{self, Isa};
use super::pool::GemmPool;

const LN_EPS: f32 = 1e-12;

/// Static geometry of a native model (mirrors python `ModelConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    pub vocab: usize,
    pub max_len: usize,
    pub type_vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub num_labels: usize,
}

impl Geometry {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }
}

/// One of the four per-layer activation-quantization sites of the INT8 path
/// (the places [`NativeModel::forward`] calls `quantize_*` on activations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tap {
    /// Layer input entering the Q/K/V projections (`Int8Full` only).
    AttnIn,
    /// Attention context entering the output projection (`Int8Full` only).
    AttnCtx,
    /// Post-LN hidden entering the first FFN GEMM.
    FfnIn,
    /// Post-GELU activation entering the second FFN GEMM.
    FfnAct,
}

impl Tap {
    pub const ALL: [Tap; 4] = [Tap::AttnIn, Tap::AttnCtx, Tap::FfnIn,
                               Tap::FfnAct];

    pub fn name(self) -> &'static str {
        match self {
            Tap::AttnIn => "attn_in",
            Tap::AttnCtx => "attn_ctx",
            Tap::FfnIn => "ffn_in",
            Tap::FfnAct => "ffn_act",
        }
    }

    /// The manifest `scales` key of this tap on layer `layer`.
    pub fn key(self, layer: usize) -> String {
        format!("l{layer}/{}", self.name())
    }

    /// Whether a layer running in `mode` quantizes activations at this tap.
    pub fn applies(self, mode: LayerMode) -> bool {
        match self {
            Tap::AttnIn | Tap::AttnCtx => mode == LayerMode::Int8Full,
            Tap::FfnIn | Tap::FfnAct => mode.is_int8(),
        }
    }
}

/// Calibrated static activation scales of one layer (absent taps fall back
/// to dynamic max-abs quantization at run time).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerScales {
    pub attn_in: Option<f32>,
    pub attn_ctx: Option<f32>,
    pub ffn_in: Option<f32>,
    pub ffn_act: Option<f32>,
}

impl LayerScales {
    pub fn get(&self, tap: Tap) -> Option<f32> {
        match tap {
            Tap::AttnIn => self.attn_in,
            Tap::AttnCtx => self.attn_ctx,
            Tap::FfnIn => self.ffn_in,
            Tap::FfnAct => self.ffn_act,
        }
    }

    pub fn set(&mut self, tap: Tap, scale: f32) {
        let slot = match tap {
            Tap::AttnIn => &mut self.attn_in,
            Tap::AttnCtx => &mut self.attn_ctx,
            Tap::FfnIn => &mut self.ffn_in,
            Tap::FfnAct => &mut self.ffn_act,
        };
        *slot = Some(scale);
    }

    /// Extract per-layer tap scales from a manifest `scales` map
    /// (`l{i}/attn_in`-style keys; unrelated keys are ignored).
    pub fn from_manifest(scales: &BTreeMap<String, f64>, layers: usize)
                         -> Vec<LayerScales> {
        let mut out = vec![LayerScales::default(); layers];
        for (l, ls) in out.iter_mut().enumerate() {
            for tap in Tap::ALL {
                if let Some(&s) = scales.get(&tap.key(l)) {
                    if s > 0.0 && s.is_finite() {
                        ls.set(tap, s as f32);
                    }
                }
            }
        }
        out
    }
}

/// Raw f32 weights of one transformer layer (row-major, `x @ W` layout).
#[derive(Debug, Clone, PartialEq)]
pub struct RawLayer {
    pub wq: Vec<f32>,
    pub bq: Vec<f32>,
    pub wk: Vec<f32>,
    pub bk: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
    pub wo: Vec<f32>,
    pub bo: Vec<f32>,
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
}

/// Full raw weight set (what the binary weights file stores).
#[derive(Debug, Clone, PartialEq)]
pub struct Weights {
    pub geom: Geometry,
    pub emb_tok: Vec<f32>,
    pub emb_seg: Vec<f32>,
    pub emb_pos: Vec<f32>,
    pub emb_ln_g: Vec<f32>,
    pub emb_ln_b: Vec<f32>,
    pub layers: Vec<RawLayer>,
    pub pool_w: Vec<f32>,
    pub pool_b: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

impl Weights {
    /// Deterministic synthetic weights (BERT-style clipped-normal amplitude)
    /// for environments with no exported weights file: serving, benches and
    /// tests get a real computable encoder whose outputs are stable across
    /// runs for a given (geometry, seed).
    pub fn synthetic(geom: Geometry, seed: u64) -> Weights {
        let mut p = Prng::new(seed);
        let mut t = |len: usize| -> Vec<f32> {
            (0..len).map(|_| (p.f64() as f32 * 2.0 - 1.0) * 0.04).collect()
        };
        let h = geom.hidden;
        let f = geom.ffn;
        let mut layers = Vec::with_capacity(geom.layers);
        for _ in 0..geom.layers {
            layers.push(RawLayer {
                wq: t(h * h),
                bq: t(h),
                wk: t(h * h),
                bk: t(h),
                wv: t(h * h),
                bv: t(h),
                wo: t(h * h),
                bo: t(h),
                ln1_g: vec![1.0; h],
                ln1_b: vec![0.0; h],
                w1: t(h * f),
                b1: t(f),
                w2: t(f * h),
                b2: t(h),
                ln2_g: vec![1.0; h],
                ln2_b: vec![0.0; h],
            });
        }
        Weights {
            emb_tok: t(geom.vocab * h),
            emb_seg: t(geom.type_vocab * h),
            emb_pos: t(geom.max_len * h),
            emb_ln_g: vec![1.0; h],
            emb_ln_b: vec![0.0; h],
            layers,
            pool_w: t(h * h),
            pool_b: t(h),
            head_w: t(h * geom.num_labels),
            head_b: t(geom.num_labels),
            geom,
        }
    }

    /// Validate every tensor length against the geometry.
    pub fn validate(&self) -> Result<()> {
        let g = &self.geom;
        ensure!(g.hidden > 0 && g.heads > 0 && g.hidden % g.heads == 0,
                "hidden {} not divisible by heads {}", g.hidden, g.heads);
        ensure!(g.vocab > 0 && g.type_vocab > 0 && g.max_len > 0
                && g.layers > 0 && g.ffn > 0 && g.num_labels > 0,
                "degenerate geometry {:?}", g);
        ensure!(self.emb_tok.len() == g.vocab * g.hidden, "emb_tok shape");
        ensure!(self.emb_seg.len() == g.type_vocab * g.hidden, "emb_seg shape");
        ensure!(self.emb_pos.len() == g.max_len * g.hidden, "emb_pos shape");
        ensure!(self.emb_ln_g.len() == g.hidden, "emb_ln_g shape");
        ensure!(self.emb_ln_b.len() == g.hidden, "emb_ln_b shape");
        ensure!(self.layers.len() == g.layers, "layer count");
        for (l, lw) in self.layers.iter().enumerate() {
            for (nm, t, want) in [
                ("wq", &lw.wq, g.hidden * g.hidden),
                ("wk", &lw.wk, g.hidden * g.hidden),
                ("wv", &lw.wv, g.hidden * g.hidden),
                ("wo", &lw.wo, g.hidden * g.hidden),
                ("w1", &lw.w1, g.hidden * g.ffn),
                ("w2", &lw.w2, g.ffn * g.hidden),
                ("bq", &lw.bq, g.hidden),
                ("bk", &lw.bk, g.hidden),
                ("bv", &lw.bv, g.hidden),
                ("bo", &lw.bo, g.hidden),
                ("b1", &lw.b1, g.ffn),
                ("b2", &lw.b2, g.hidden),
                ("ln1_g", &lw.ln1_g, g.hidden),
                ("ln1_b", &lw.ln1_b, g.hidden),
                ("ln2_g", &lw.ln2_g, g.hidden),
                ("ln2_b", &lw.ln2_b, g.hidden),
            ] {
                ensure!(t.len() == want, "layer {l}: {nm} shape {} != {want}",
                        t.len());
            }
        }
        ensure!(self.pool_w.len() == g.hidden * g.hidden, "pool_w shape");
        ensure!(self.pool_b.len() == g.hidden, "pool_b shape");
        ensure!(self.head_w.len() == g.hidden * g.num_labels, "head_w shape");
        ensure!(self.head_b.len() == g.num_labels, "head_b shape");
        Ok(())
    }
}

/// Pre-packed INT8 panels of one layer's six GEMM weights.
#[derive(Debug, Clone)]
struct PackedLayer {
    wq: PackedI8,
    wk: PackedI8,
    wv: PackedI8,
    wo: PackedI8,
    w1: PackedI8,
    w2: PackedI8,
}

/// Weights + packed panels + head type: everything the native backend needs
/// to run a task end to end.
pub struct NativeModel {
    pub weights: Weights,
    pub head_type: String,
    packed: Vec<PackedLayer>,
    /// Calibrated static activation scales per layer (all-`None` entries
    /// mean dynamic max-abs at every tap).
    static_scales: Vec<LayerScales>,
    /// ISA rung every GEMM dot product runs on (process-wide dispatch,
    /// resolved once — see `backend::native::isa`).
    isa: Isa,
    /// Optional replica-owned worker pool that row-partitions each GEMM
    /// (`Runtime::native_model_for_replica` attaches it at load).
    pool: Option<Arc<GemmPool>>,
}

/// Active kernel configuration of one native model replica: the dispatched
/// ISA rung, GEMM parallelism, and where the pool workers actually landed.
/// Reported on `GET /v1/models` and in the `[native]` load log.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelInfo {
    pub isa: &'static str,
    /// GEMM parallelism, calling thread included (1 = no pool).
    pub threads: usize,
    /// Observed pin per pool worker (`None` = unpinned).
    pub pinned: Vec<Option<usize>>,
}

/// Per-forward scratch buffers: Q/K/V/context/FFN activations plus the
/// activation-quantization byte buffer (`qbuf`).
///
/// Reusable across forwards: [`Scratch::ensure`] resizes every buffer to
/// the batch at hand without reallocating once the high-water mark is
/// reached, so a dispatcher worker that threads one `Scratch` through its
/// batches ([`NativeModel::forward_scratch`]) runs the steady state
/// allocation-free — including the per-INT8-GEMM activation quantization,
/// which previously grew a fresh buffer every forward.  [`NativeEncoder`]
/// (`super`) keeps a small pool of these, one checked out per concurrent
/// worker.
#[derive(Debug, Default)]
pub struct Scratch {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctx: Vec<f32>,
    tmp_h: Vec<f32>,
    ffn1: Vec<f32>,
    probs: Vec<f32>,
    mask_bias: Vec<f32>,
    qbuf: Vec<i8>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Size every buffer for a `[rows = batch*seq]` forward.  Contents
    /// become stale; every consumer fully overwrites its buffer before
    /// reading it.  `Vec::resize` reuses the allocation whenever the new
    /// length fits the existing capacity.
    fn ensure(&mut self, rows: usize, seq: usize, geom: &Geometry) {
        self.q.resize(rows * geom.hidden, 0.0);
        self.k.resize(rows * geom.hidden, 0.0);
        self.v.resize(rows * geom.hidden, 0.0);
        self.ctx.resize(rows * geom.hidden, 0.0);
        self.tmp_h.resize(rows * geom.hidden, 0.0);
        self.ffn1.resize(rows * geom.ffn, 0.0);
        self.probs.resize(seq, 0.0);
        self.mask_bias.resize(rows, 0.0);
    }
}

impl NativeModel {
    /// Build from raw weights (validates shapes, packs INT8 panels).
    pub fn new(weights: Weights, head_type: impl Into<String>)
               -> Result<NativeModel> {
        weights.validate()?;
        let g = weights.geom;
        let packed = weights
            .layers
            .iter()
            .map(|lw| PackedLayer {
                wq: PackedI8::pack(&lw.wq, g.hidden, g.hidden),
                wk: PackedI8::pack(&lw.wk, g.hidden, g.hidden),
                wv: PackedI8::pack(&lw.wv, g.hidden, g.hidden),
                wo: PackedI8::pack(&lw.wo, g.hidden, g.hidden),
                w1: PackedI8::pack(&lw.w1, g.hidden, g.ffn),
                w2: PackedI8::pack(&lw.w2, g.ffn, g.hidden),
            })
            .collect();
        let static_scales = vec![LayerScales::default(); g.layers];
        Ok(NativeModel { weights, head_type: head_type.into(), packed,
                         static_scales, isa: isa::active(), pool: None })
    }

    pub fn geom(&self) -> &Geometry {
        &self.weights.geom
    }

    /// Attach (or detach, with `None`) the replica-owned worker pool that
    /// row-partitions every GEMM of this model.
    pub fn set_gemm_pool(&mut self, pool: Option<Arc<GemmPool>>) {
        self.pool = pool;
    }

    /// The per-call kernel configuration: active ISA rung + pool handle.
    fn kernel(&self) -> GemmKernel<'_> {
        GemmKernel { isa: self.isa, pool: self.pool.as_deref() }
    }

    /// True when this model's GEMM pool has been poisoned by a panicked
    /// worker job — further forwards fail typed until the owning replica
    /// is rebuilt (`ReplicaSet::heal` / registry generation swap).
    pub fn pool_poisoned(&self) -> bool {
        self.pool.as_ref().is_some_and(|p| p.is_poisoned())
    }

    /// Kernel configuration for reporting surfaces.
    pub fn kernel_info(&self) -> KernelInfo {
        KernelInfo {
            isa: self.isa.name(),
            threads: self.pool.as_ref().map_or(1, |p| p.threads()),
            pinned: self
                .pool
                .as_ref()
                .map_or_else(Vec::new, |p| p.pinned().to_vec()),
        }
    }

    /// Install calibrated static activation scales (one entry per layer).
    pub fn set_static_scales(&mut self, scales: Vec<LayerScales>) -> Result<()> {
        ensure!(scales.len() == self.weights.geom.layers,
                "static scales length {} != layers {}", scales.len(),
                self.weights.geom.layers);
        self.static_scales = scales;
        Ok(())
    }

    pub fn static_scales(&self) -> &[LayerScales] {
        &self.static_scales
    }

    /// Which activation-quantization source each layer of `plan` uses:
    /// `"static"` (every applicable tap calibrated), `"dynamic"` (none),
    /// `"mixed(n/m)"`, or `"-"` for floating layers.
    pub fn act_quant_modes(&self, plan: &[LayerMode]) -> Vec<String> {
        plan.iter()
            .enumerate()
            .map(|(l, &mode)| {
                if !mode.is_int8() {
                    return "-".to_string();
                }
                let taps: Vec<Tap> = Tap::ALL
                    .into_iter()
                    .filter(|t| t.applies(mode))
                    .collect();
                let have = taps
                    .iter()
                    .filter(|t| self.static_scales[l].get(**t).is_some())
                    .count();
                if have == taps.len() {
                    "static".to_string()
                } else if have == 0 {
                    "dynamic".to_string()
                } else {
                    format!("mixed({have}/{})", taps.len())
                }
            })
            .collect()
    }

    /// Mixed-precision encoder forward: `[B, S]` inputs -> `[B, S, H]`
    /// hidden states, each layer dispatched per `plan`.  Allocates its own
    /// scratch; the serving path threads a reusable one through
    /// [`NativeModel::forward_scratch`] instead.
    pub fn forward(&self, b: &EncoderBatch, plan: &[LayerMode])
                   -> Result<Vec<f32>> {
        self.forward_observed(b, plan, &mut |_, _, _| {})
    }

    /// [`NativeModel::forward`] with caller-owned scratch buffers — the
    /// dispatcher workers' path: each worker reuses one [`Scratch`] across
    /// every batch it serves, so steady-state forwards do not allocate for
    /// Q/K/V/FFN activations or activation quantization.
    pub fn forward_scratch(&self, b: &EncoderBatch, plan: &[LayerMode],
                           sc: &mut Scratch) -> Result<Vec<f32>> {
        self.forward_observed_scratch(b, plan, sc, &mut |_, _, _| {})
    }

    /// [`NativeModel::forward`] with an activation observer: `obs(layer,
    /// tap, xs)` fires at every quantization site ([`Tap`]) of every layer,
    /// on the floating and INT8 paths alike.  The planner's calibration pass
    /// uses this to record per-layer activation statistics from the f32
    /// reference forward; serving goes through [`NativeModel::forward`],
    /// whose no-op observer costs four indirect calls per layer per batch.
    pub fn forward_observed(&self, b: &EncoderBatch, plan: &[LayerMode],
                            obs: &mut dyn FnMut(usize, Tap, &[f32]))
                            -> Result<Vec<f32>> {
        let mut sc = Scratch::new();
        self.forward_observed_scratch(b, plan, &mut sc, obs)
    }

    /// The full forward: observer hooks + caller-owned scratch.
    pub fn forward_observed_scratch(&self, b: &EncoderBatch,
                                    plan: &[LayerMode], sc: &mut Scratch,
                                    obs: &mut dyn FnMut(usize, Tap, &[f32]))
                                    -> Result<Vec<f32>> {
        let g = self.weights.geom;
        ensure!(plan.len() == g.layers,
                "plan length {} != layers {}", plan.len(), g.layers);
        ensure!(b.ids.len() == b.batch * b.seq, "batch shape mismatch");
        let rows = b.batch * b.seq;
        sc.ensure(rows, b.seq, &g);
        let mut h = vec![0f32; rows * g.hidden];
        self.embed(b, &mut h);
        // additive attention bias per key position: 0 keep / -1e9 pad
        for (mb, &m) in sc.mask_bias.iter_mut().zip(b.attention_mask.iter()) {
            *mb = (1.0 - m) * -1e9;
        }
        for (l, &mode) in plan.iter().enumerate() {
            self.layer(&mut h, l, mode, b.batch, b.seq, obs, sc)?;
        }
        Ok(h)
    }

    /// The pure-f32 reference forward (every layer on the reference path) —
    /// the baseline the INT8 parity tests and `bench_gemm` compare against.
    pub fn forward_f32(&self, b: &EncoderBatch) -> Result<Vec<f32>> {
        let plan = vec![LayerMode::Fp32; self.weights.geom.layers];
        self.forward(b, &plan)
    }

    /// Downstream head: `[B, S, H]` hidden -> logits.
    ///
    /// * classification / matching: tanh pooler over the CLS token, then the
    ///   label projection -> `[B, num_labels]`;
    /// * ner: per-token label projection -> `[B, S, num_labels]`.
    pub fn head_forward(&self, hidden: &[f32], b: usize, s: usize)
                        -> Result<Vec<f32>> {
        let g = self.weights.geom;
        let h = g.hidden;
        let nl = g.num_labels;
        ensure!(hidden.len() == b * s * h,
                "hidden shape {} != {}x{}x{}", hidden.len(), b, s, h);
        let kern = self.kernel();
        if self.head_type == "ner" {
            let mut out = vec![0f32; b * s * nl];
            gemm_f32_with(kern, hidden, &self.weights.head_w,
                          Some(&self.weights.head_b), b * s, h, nl,
                          &mut out)?;
            return Ok(out);
        }
        let mut cls = vec![0f32; b * h];
        for bi in 0..b {
            cls[bi * h..(bi + 1) * h]
                .copy_from_slice(&hidden[bi * s * h..bi * s * h + h]);
        }
        let mut pooled = vec![0f32; b * h];
        gemm_f32_with(kern, &cls, &self.weights.pool_w,
                      Some(&self.weights.pool_b), b, h, h, &mut pooled)?;
        for x in pooled.iter_mut() {
            *x = x.tanh();
        }
        let mut out = vec![0f32; b * nl];
        gemm_f32_with(kern, &pooled, &self.weights.head_w,
                      Some(&self.weights.head_b), b, h, nl, &mut out)?;
        Ok(out)
    }

    /// Fused token+segment+position embedding + LayerNorm.  Out-of-range
    /// ids clamp to the table edge (the tokenizer and table are built from
    /// the same vocab, so this only matters for synthetic weights smaller
    /// than the serving vocab).
    fn embed(&self, b: &EncoderBatch, h: &mut [f32]) {
        let g = self.weights.geom;
        let hd = g.hidden;
        for r in 0..b.batch {
            for t in 0..b.seq {
                let row = r * b.seq + t;
                let id = (b.ids[row].max(0) as usize).min(g.vocab - 1);
                let seg = (b.segment_ids[row].max(0) as usize)
                    .min(g.type_vocab - 1);
                let pos = t.min(g.max_len - 1);
                let tok = &self.weights.emb_tok[id * hd..(id + 1) * hd];
                let sg = &self.weights.emb_seg[seg * hd..(seg + 1) * hd];
                let ps = &self.weights.emb_pos[pos * hd..(pos + 1) * hd];
                let out = &mut h[row * hd..(row + 1) * hd];
                for (((o, &tk), &sv), &pv) in
                    out.iter_mut().zip(tok).zip(sg).zip(ps)
                {
                    *o = tk + sv + pv;
                }
                layernorm_row(out, &self.weights.emb_ln_g,
                              &self.weights.emb_ln_b);
            }
        }
    }

    /// One transformer layer, updating `h` in place (activations and the
    /// attention mask bias live in `sc`).  Fails typed (without panicking
    /// the caller) when the GEMM pool was poisoned by a panicked worker.
    #[allow(clippy::too_many_arguments)]
    fn layer(&self, h: &mut [f32], l: usize, mode: LayerMode, b: usize,
             s: usize, obs: &mut dyn FnMut(usize, Tap, &[f32]),
             sc: &mut Scratch) -> Result<()> {
        let g = self.weights.geom;
        let hsz = g.hidden;
        let rows = b * s;
        let lw = &self.weights.layers[l];
        let pk = &self.packed[l];
        let ls = &self.static_scales[l];
        let int8_proj = mode == LayerMode::Int8Full;
        let int8_ffn = matches!(mode, LayerMode::Int8Full | LayerMode::Int8Ffn);
        let kern = self.kernel();

        // Q/K/V projections
        obs(l, Tap::AttnIn, h);
        if int8_proj {
            let sa = quantize_act(h, ls.attn_in, &mut sc.qbuf);
            gemm_i8_with(kern, &sc.qbuf, sa, &pk.wq, Some(&lw.bq), rows,
                         &mut sc.q)?;
            gemm_i8_with(kern, &sc.qbuf, sa, &pk.wk, Some(&lw.bk), rows,
                         &mut sc.k)?;
            gemm_i8_with(kern, &sc.qbuf, sa, &pk.wv, Some(&lw.bv), rows,
                         &mut sc.v)?;
        } else {
            gemm_f32_with(kern, h, &lw.wq, Some(&lw.bq), rows, hsz, hsz,
                          &mut sc.q)?;
            gemm_f32_with(kern, h, &lw.wk, Some(&lw.bk), rows, hsz, hsz,
                          &mut sc.k)?;
            gemm_f32_with(kern, h, &lw.wv, Some(&lw.bv), rows, hsz, hsz,
                          &mut sc.v)?;
        }

        // attention core (always f32 — see module docs)
        attention(&sc.q, &sc.k, &sc.v, &sc.mask_bias, b, s, g.heads,
                  g.head_dim(), &mut sc.ctx, &mut sc.probs);

        // output projection (bias folds into the LN epilogue)
        obs(l, Tap::AttnCtx, &sc.ctx);
        if int8_proj {
            let sctx = quantize_act(&sc.ctx, ls.attn_ctx, &mut sc.qbuf);
            gemm_i8_with(kern, &sc.qbuf, sctx, &pk.wo, None, rows,
                         &mut sc.tmp_h)?;
        } else {
            gemm_f32_with(kern, &sc.ctx, &lw.wo, None, rows, hsz, hsz,
                          &mut sc.tmp_h)?;
        }
        // h1 = LN(attn_out + bo + h)
        add_bias_residual_layernorm(h, &sc.tmp_h, &lw.bo, &lw.ln1_g,
                                    &lw.ln1_b, hsz);

        // FFN
        obs(l, Tap::FfnIn, h);
        if int8_ffn {
            let sh = quantize_act(h, ls.ffn_in, &mut sc.qbuf);
            gemm_i8_with(kern, &sc.qbuf, sh, &pk.w1, None, rows,
                         &mut sc.ffn1)?;
            bias_gelu(&mut sc.ffn1, &lw.b1, g.ffn);
            obs(l, Tap::FfnAct, &sc.ffn1);
            let sact = quantize_act(&sc.ffn1, ls.ffn_act, &mut sc.qbuf);
            gemm_i8_with(kern, &sc.qbuf, sact, &pk.w2, None, rows,
                         &mut sc.tmp_h)?;
        } else {
            gemm_f32_with(kern, h, &lw.w1, None, rows, hsz, g.ffn,
                          &mut sc.ffn1)?;
            bias_gelu(&mut sc.ffn1, &lw.b1, g.ffn);
            obs(l, Tap::FfnAct, &sc.ffn1);
            gemm_f32_with(kern, &sc.ffn1, &lw.w2, None, rows, g.ffn, hsz,
                          &mut sc.tmp_h)?;
        }
        // h2 = LN(ffn2 + b2 + h1)
        add_bias_residual_layernorm(h, &sc.tmp_h, &lw.b2, &lw.ln2_g,
                                    &lw.ln2_b, hsz);
        Ok(())
    }
}

/// Quantize an activation tensor entering one INT8 GEMM: the calibrated
/// static scale when one is installed, dynamic per-tensor max-abs otherwise.
/// Returns the scale actually used.
fn quantize_act(xs: &[f32], fixed: Option<f32>, buf: &mut Vec<i8>) -> f32 {
    match fixed {
        Some(s) if s > 0.0 && s.is_finite() => {
            crate::quant::quantize_into(xs, s, buf);
            s
        }
        _ => quantize_dynamic(xs, buf),
    }
}

/// Multi-head scaled-dot-product attention over `[rows, H]` Q/K/V, context
/// written to `ctx`.  `mask_bias` is per key position (`[B*S]`, 0 / -1e9).
#[allow(clippy::too_many_arguments)]
fn attention(q: &[f32], k: &[f32], v: &[f32], mask_bias: &[f32], b: usize,
             s: usize, heads: usize, hd: usize, ctx: &mut [f32],
             probs: &mut [f32]) {
    let h = heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    for bi in 0..b {
        for hh in 0..heads {
            for i in 0..s {
                let qo = (bi * s + i) * h + hh * hd;
                let qrow = &q[qo..qo + hd];
                let mut max = f32::NEG_INFINITY;
                for (j, pj) in probs.iter_mut().enumerate().take(s) {
                    let ko = (bi * s + j) * h + hh * hd;
                    let score = dot_f32(qrow, &k[ko..ko + hd]) * scale
                        + mask_bias[bi * s + j];
                    *pj = score;
                    max = max.max(score);
                }
                let mut sum = 0f32;
                for pj in probs.iter_mut().take(s) {
                    *pj = (*pj - max).exp();
                    sum += *pj;
                }
                let inv = 1.0 / sum;
                let crow = &mut ctx[qo..qo + hd];
                crow.fill(0.0);
                for (j, pj) in probs.iter().enumerate().take(s) {
                    let p = *pj * inv;
                    let vo = (bi * s + j) * h + hh * hd;
                    let vrow = &v[vo..vo + hd];
                    for (c, &vv) in crow.iter_mut().zip(vrow.iter()) {
                        *c += p * vv;
                    }
                }
            }
        }
    }
}

/// LayerNorm one row in place.
fn layernorm_row(row: &mut [f32], g: &[f32], b: &[f32]) {
    let n = row.len() as f32;
    let mean = row.iter().sum::<f32>() / n;
    let var = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + LN_EPS).sqrt();
    for (j, x) in row.iter_mut().enumerate() {
        *x = (*x - mean) * inv * g[j] + b[j];
    }
}

/// The fused big-kernel epilogue: `h = LN(x + bias + h)` row by row
/// (bias+residual+LayerNorm, the paper's Fig-2 "big kernel").
fn add_bias_residual_layernorm(h: &mut [f32], x: &[f32], bias: &[f32],
                               g: &[f32], b: &[f32], hidden: usize) {
    debug_assert_eq!(h.len(), x.len());
    let rows = h.len() / hidden;
    for r in 0..rows {
        let hrow = &mut h[r * hidden..(r + 1) * hidden];
        let xrow = &x[r * hidden..(r + 1) * hidden];
        for (j, hx) in hrow.iter_mut().enumerate() {
            *hx += xrow[j] + bias[j];
        }
        layernorm_row(hrow, g, b);
    }
}

/// GELU (tanh approximation) fused with its bias add, in place.
fn bias_gelu(x: &mut [f32], bias: &[f32], width: usize) {
    let rows = x.len() / width;
    for r in 0..rows {
        let row = &mut x[r * width..(r + 1) * width];
        for (j, v) in row.iter_mut().enumerate() {
            let t = *v + bias[j];
            *v = 0.5 * t
                * (1.0 + (0.797_884_6 * (t + 0.044_715 * t * t * t)).tanh());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_geom() -> Geometry {
        Geometry {
            vocab: 64,
            max_len: 16,
            type_vocab: 2,
            hidden: 32,
            layers: 2,
            heads: 4,
            ffn: 64,
            num_labels: 3,
        }
    }

    fn tiny_model(head_type: &str) -> NativeModel {
        NativeModel::new(Weights::synthetic(tiny_geom(), 42), head_type)
            .unwrap()
    }

    fn tiny_batch() -> EncoderBatch {
        let mut b = EncoderBatch::zeros(2, 8);
        b.set_row(0, &[2, 5, 9, 3, 0, 0, 0, 0], &[0; 8],
                  &[1, 1, 1, 1, 0, 0, 0, 0]);
        b.set_row(1, &[2, 7, 3, 0, 0, 0, 0, 0], &[0; 8],
                  &[1, 1, 1, 0, 0, 0, 0, 0]);
        b
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = tiny_model("classification");
        let g = *m.geom();
        let plan = vec![LayerMode::Fp16; g.layers];
        let h = m.forward(&tiny_batch(), &plan).unwrap();
        assert_eq!(h.len(), 2 * 8 * g.hidden);
        assert!(h.iter().all(|x| x.is_finite()));
        // layernormed rows have ~zero mean
        let row = &h[..g.hidden];
        let mean: f32 = row.iter().sum::<f32>() / g.hidden as f32;
        assert!(mean.abs() < 1e-4, "mean {mean}");
    }

    #[test]
    fn head_shapes_per_task_kind() {
        let b = tiny_batch();
        let m = tiny_model("classification");
        let h = m.forward_f32(&b).unwrap();
        assert_eq!(m.head_forward(&h, 2, 8).unwrap().len(), 2 * 3);
        let m = tiny_model("ner");
        let h = m.forward_f32(&b).unwrap();
        assert_eq!(m.head_forward(&h, 2, 8).unwrap().len(), 2 * 8 * 3);
    }

    #[test]
    fn int8_forward_close_to_f32() {
        let m = tiny_model("classification");
        let g = *m.geom();
        let b = tiny_batch();
        let f = m.forward_f32(&b).unwrap();
        for mode in [LayerMode::Int8Ffn, LayerMode::Int8Full] {
            let q = m.forward(&b, &vec![mode; g.layers]).unwrap();
            // post-LN activations are O(1); dynamic per-tensor INT8 keeps
            // the drift small
            let max_err = f
                .iter()
                .zip(&q)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 0.35, "{mode:?}: max err {max_err}");
            assert!(q.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical_across_shapes() {
        // one Scratch threaded through forwards of different [B, S] shapes
        // (the continuous batcher's regime) must reproduce the fresh-scratch
        // forward exactly — stale buffer contents may never leak into math
        let m = tiny_model("classification");
        let g = *m.geom();
        let plan = vec![LayerMode::Int8Full; g.layers];
        let mut sc = Scratch::new();
        let shapes: [(usize, usize); 4] = [(2, 8), (4, 3), (1, 8), (3, 5)];
        for (bs, seq) in shapes {
            let mut b = EncoderBatch::zeros(bs, seq);
            for r in 0..bs {
                let ids: Vec<i32> = (0..seq).map(|t| (r * seq + t) as i32 % 40
                                                 + 2).collect();
                let mask: Vec<i32> = (0..seq)
                    .map(|t| i32::from(t < seq - r % seq))
                    .collect();
                let segs = vec![0; seq];
                b.set_row(r, &ids, &segs, &mask);
            }
            let fresh = m.forward(&b, &plan).unwrap();
            let reused = m.forward_scratch(&b, &plan, &mut sc).unwrap();
            assert_eq!(fresh.len(), reused.len());
            for (i, (x, y)) in fresh.iter().zip(&reused).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(),
                           "[{bs},{seq}] element {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn mixed_plan_runs() {
        let m = tiny_model("matching");
        let plan = vec![LayerMode::Int8Full, LayerMode::Fp16];
        let h = m.forward(&tiny_batch(), &plan).unwrap();
        assert!(h.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn bad_plan_length_rejected() {
        let m = tiny_model("classification");
        assert!(m.forward(&tiny_batch(), &[LayerMode::Fp16]).is_err());
    }

    #[test]
    fn observer_fires_at_every_tap_on_float_and_int8_paths() {
        let m = tiny_model("classification");
        let b = tiny_batch();
        for plan in [vec![LayerMode::Fp32; 2], vec![LayerMode::Int8Full; 2]] {
            let mut seen: Vec<(usize, Tap)> = Vec::new();
            m.forward_observed(&b, &plan, &mut |l, tap, xs| {
                assert!(!xs.is_empty());
                seen.push((l, tap));
            })
            .unwrap();
            assert_eq!(seen.len(), 2 * 4, "4 taps x 2 layers");
            for l in 0..2 {
                for tap in Tap::ALL {
                    assert!(seen.contains(&(l, tap)), "missing {l}/{tap:?}");
                }
            }
        }
    }

    #[test]
    fn static_scales_equal_to_dynamic_amax_reproduce_dynamic_output() {
        // observe the exact tensors the dynamic path quantizes, install
        // their amax as static scales: the forward must be bit-identical
        // (proves the tap -> quantization-site mapping is right)
        let mut m = tiny_model("classification");
        let b = tiny_batch();
        let plan = vec![LayerMode::Int8Full; 2];
        let mut scales = vec![LayerScales::default(); 2];
        let dynamic = m
            .forward_observed(&b, &plan, &mut |l, tap, xs| {
                let amax = xs.iter().fold(0f32, |a, &x| a.max(x.abs()));
                scales[l].set(tap, crate::quant::amax_to_scale(amax));
            })
            .unwrap();
        m.set_static_scales(scales).unwrap();
        assert_eq!(m.act_quant_modes(&plan), vec!["static", "static"]);
        let fixed = m.forward(&b, &plan).unwrap();
        for (i, (x, y)) in fixed.iter().zip(dynamic.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "element {i}: {x} vs {y}");
        }
    }

    #[test]
    fn act_quant_modes_reports_per_layer_sources() {
        let mut m = tiny_model("classification");
        let mut s0 = LayerScales::default();
        s0.set(Tap::FfnIn, 0.1);
        s0.set(Tap::FfnAct, 0.2);
        m.set_static_scales(vec![s0, LayerScales::default()]).unwrap();
        // ffn-only layer 0 has both of its taps -> static; int8_full layer 0
        // has 2 of 4 -> mixed; layer 1 has none -> dynamic; float layer -> -
        assert_eq!(m.act_quant_modes(&[LayerMode::Int8Ffn, LayerMode::Fp16]),
                   vec!["static", "-"]);
        assert_eq!(m.act_quant_modes(&[LayerMode::Int8Full,
                                       LayerMode::Int8Full]),
                   vec!["mixed(2/4)", "dynamic"]);
    }

    #[test]
    fn layer_scales_from_manifest_keys() {
        let mut map = std::collections::BTreeMap::new();
        map.insert("l0/ffn_in".to_string(), 0.25);
        map.insert("l1/attn_in".to_string(), 0.5);
        map.insert("l1/bogus".to_string(), 1.0);
        map.insert("emb_out".to_string(), 0.11);
        map.insert("l0/attn_ctx".to_string(), -1.0); // non-positive: ignored
        let s = LayerScales::from_manifest(&map, 2);
        assert_eq!(s[0].ffn_in, Some(0.25));
        assert_eq!(s[0].attn_ctx, None);
        assert_eq!(s[1].attn_in, Some(0.5));
        assert_eq!(s[1].ffn_act, None);
    }

    #[test]
    fn synthetic_weights_are_deterministic() {
        let a = Weights::synthetic(tiny_geom(), 7);
        let b = Weights::synthetic(tiny_geom(), 7);
        assert_eq!(a.emb_tok, b.emb_tok);
        assert_eq!(a.layers[1].w2, b.layers[1].w2);
        let c = Weights::synthetic(tiny_geom(), 8);
        assert_ne!(a.emb_tok, c.emb_tok);
    }
}
