//! Engine replica sets: N independent engines behind one task lane.
//!
//! A lane's shard set used to share a single `Arc<dyn Backend>` — one packed
//! copy of the native weights that every dispatcher worker's GEMMs stream
//! over.  A [`ReplicaSet`] duplicates the lane's engine `--replicas-per-lane`
//! times: replica 0 shares the router's cached pipeline (so a 1-replica set
//! is exactly the pre-replica behavior, weights and all), and each further
//! replica loads the *same* variant under a private native-model cache key,
//! which packs its **own** copy of the weights.  Dispatcher workers
//! [`acquire`](ReplicaSet::acquire) the least-loaded replica per batch, so
//! memory-bandwidth-bound INT8 GEMMs stop contending on one weight copy.
//!
//! Variant switches stay live: `acquire` re-resolves the task's active
//! pipeline through the router on every call (one read lock, exactly what
//! the pre-replica dispatch loop paid), and lazily rebuilds a replica whose
//! pipeline is serving a stale variant.  PJRT engines are cached by artifact
//! path, so replicas of a PJRT lane share the compiled executable — the
//! duplication is meaningful for the native backend, which is where the
//! weight-copy contention lives.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::Result;

use crate::backend::native::KernelInfo;
use crate::coordinator::{Pipeline, Router};

/// One engine replica: a pipeline handle plus load accounting.
struct Replica {
    /// Native-model cache key; empty = replica 0, which shares the router's
    /// cache entry (and therefore the router's weight copy).
    native_key: String,
    pipeline: RwLock<Arc<Pipeline>>,
    in_flight: AtomicUsize,
    batches: AtomicU64,
}

impl Replica {
    fn new(native_key: String, pipeline: Arc<Pipeline>) -> Replica {
        Replica {
            native_key,
            pipeline: RwLock::new(pipeline),
            in_flight: AtomicUsize::new(0),
            batches: AtomicU64::new(0),
        }
    }
}

/// N independent engines serving one task lane (N >= 1).
pub struct ReplicaSet {
    task: String,
    router: Arc<Router>,
    replicas: Vec<Replica>,
    /// Serializes [`ReplicaSet::heal`]: N dispatcher workers hitting the
    /// same poisoned pool rebuild each replica once, not N times.
    heal_lock: Mutex<()>,
    healed: AtomicU64,
}

impl ReplicaSet {
    /// Build `n.max(1)` replicas of `task`'s active variant.  Replica 0 is
    /// the router's own pipeline; replicas 1.. pack private weight copies.
    pub fn build(router: Arc<Router>, task: &str, n: usize)
                 -> Result<ReplicaSet> {
        let primary = router.pipeline(task)?;
        let mut replicas = vec![Replica::new(String::new(), primary.clone())];
        for i in 1..n.max(1) {
            let key = format!("{task}#r{i}");
            let pipe =
                router.pipeline_replica(task, &primary.variant, &key, i)?;
            replicas.push(Replica::new(key, pipe));
        }
        Ok(ReplicaSet {
            task: task.to_string(),
            router,
            replicas,
            heal_lock: Mutex::new(()),
            healed: AtomicU64::new(0),
        })
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// The pipeline replica `i` currently serves (warmup / introspection).
    pub fn pipeline_at(&self, i: usize) -> Arc<Pipeline> {
        self.replicas[i].pipeline.read().unwrap().clone()
    }

    /// Check out the least-loaded replica for one batch.  Re-resolves the
    /// task's active variant through the router, so `Router::activate` on a
    /// live lane switches every replica (replica 0 immediately, the others
    /// rebuilt lazily on their next acquire).
    pub fn acquire(&self) -> Result<ReplicaGuard<'_>> {
        let active = self.router.pipeline(&self.task)?;
        let (index, replica) = self
            .replicas
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.in_flight.load(Ordering::Relaxed))
            .expect("replica set is never empty");
        let pipeline = if index == 0 {
            // replica 0 mirrors the router's active pipeline exactly
            let mut slot = replica.pipeline.write().unwrap();
            if !Arc::ptr_eq(&*slot, &active) {
                *slot = active.clone();
            }
            active
        } else {
            let current = replica.pipeline.read().unwrap().clone();
            if current.variant == active.variant {
                current
            } else {
                let fresh = self.router.pipeline_replica(
                    &self.task, &active.variant, &replica.native_key, index)?;
                *replica.pipeline.write().unwrap() = fresh.clone();
                fresh
            }
        };
        replica.in_flight.fetch_add(1, Ordering::SeqCst);
        Ok(ReplicaGuard { replica, index, pipeline })
    }

    /// Whether any replica's pipeline reports a poisoned GEMM pool (a worker
    /// job panicked — e.g. injected via `SAMP_FAULT=gemm_panic`).
    pub fn any_poisoned(&self) -> bool {
        self.replicas
            .iter()
            .any(|r| r.pipeline.read().unwrap().is_poisoned())
    }

    /// Replicas rebuilt by [`ReplicaSet::heal`] since construction.
    pub fn healed_count(&self) -> u64 {
        self.healed.load(Ordering::Relaxed)
    }

    /// Rebuild every replica whose pipeline reports a poisoned GEMM pool,
    /// in place, without dropping a single queued row.  Returns the number
    /// of replicas rebuilt (0 when nothing is poisoned, or when a concurrent
    /// caller already healed them).
    ///
    /// Replica 0 shares the router's cached native model, so healing it
    /// means evicting the task's native-cache entry and re-activating the
    /// current variant: the rebuild packs fresh weights and spawns a fresh
    /// GEMM worker pool, and the router's active-pipeline table serves the
    /// healthy pipeline to every future resolve.  Replicas 1.. evict their
    /// private cache key and reload under it, so the poisoned model's memory
    /// dies with its last `Arc`.  Serialized: concurrent dispatcher workers
    /// that detect the same poisoning rebuild each replica exactly once.
    pub fn heal(&self) -> usize {
        let _serialize = self.heal_lock.lock().unwrap();
        let mut rebuilt = 0usize;
        for (index, r) in self.replicas.iter().enumerate() {
            let pipe = r.pipeline.read().unwrap().clone();
            if !pipe.is_poisoned() {
                continue;
            }
            let variant = pipe.variant.clone();
            let fresh = if index == 0 {
                self.router.runtime.evict_native(&self.task);
                self.router.activate(&self.task, &variant)
            } else {
                self.router.runtime.evict_native(&r.native_key);
                self.router.pipeline_replica(&self.task, &variant,
                                             &r.native_key, index)
            };
            match fresh {
                Ok(p) => {
                    *r.pipeline.write().unwrap() = p;
                    rebuilt += 1;
                }
                Err(e) => eprintln!(
                    "[heal] {}: rebuilding poisoned replica {index} failed: \
                     {e:#} (will retry on the next poisoned batch)",
                    self.task),
            }
        }
        self.healed.fetch_add(rebuilt as u64, Ordering::Relaxed);
        rebuilt
    }

    /// Per-replica native kernel identity, for `/v1/models` (`None`
    /// entries are PJRT replicas — no native kernels in play).
    pub fn kernel_snapshot(&self) -> Vec<Option<KernelInfo>> {
        self.replicas
            .iter()
            .map(|r| r.pipeline.read().unwrap().kernel_info().cloned())
            .collect()
    }

    /// Batches currently in flight across every replica — the steal
    /// router's tie-break: of two equally-backlogged lanes, the one whose
    /// engines are busier is the one least likely to drain itself soon.
    pub fn in_flight_total(&self) -> usize {
        self.replicas
            .iter()
            .map(|r| r.in_flight.load(Ordering::Relaxed))
            .sum()
    }

    /// `(in_flight, batches)` per replica, for stats surfaces.
    pub fn snapshot(&self) -> Vec<(usize, u64)> {
        self.replicas
            .iter()
            .map(|r| (r.in_flight.load(Ordering::Relaxed),
                      r.batches.load(Ordering::Relaxed)))
            .collect()
    }
}

/// A checked-out replica; dropping it releases the in-flight slot.
pub struct ReplicaGuard<'a> {
    replica: &'a Replica,
    index: usize,
    pipeline: Arc<Pipeline>,
}

impl ReplicaGuard<'_> {
    pub fn index(&self) -> usize {
        self.index
    }

    pub fn pipeline(&self) -> &Arc<Pipeline> {
        &self.pipeline
    }

    /// Count one dispatched batch against this replica.
    pub fn record_batch(&self) {
        self.replica.batches.fetch_add(1, Ordering::Relaxed);
    }
}

impl Drop for ReplicaGuard<'_> {
    fn drop(&mut self) {
        self.replica.in_flight.fetch_sub(1, Ordering::SeqCst);
    }
}
