//! Model registry: lifecycle owner for every loaded model.
//!
//! The server process used to load exactly one `manifest.json` at boot and
//! could never change it without a restart.  The registry closes that gap by
//! making every loaded model an immutable **deployment generation**:
//!
//! ```text
//!   Registry ─ model_id ─> ModelEntry ─ atomic swap ─> Arc<Deployment>
//!                                                        ├ Runtime (own native caches)
//!                                                        ├ Router  (manifest + pipelines)
//!                                                        └ lanes: task -> TaskLane
//!                                                            ├ Batcher (shared queue)
//!                                                            ├ ReplicaSet (N engines)
//!                                                            └ dispatcher shard set
//! ```
//!
//! * **Load** — [`Registry::load_model`] builds generation 1 of a model from
//!   an artifacts directory (`--artifacts id=dir` makes this repeatable).
//! * **Reload** — [`Registry::reload`] builds the *next* generation entirely
//!   off-path (own `Runtime`, so native weights/packs are fresh and the old
//!   generation's memory dies with it), warms it (one synthetic batch per
//!   task per replica), atomically swaps it in, and only then drains the old
//!   generation: its batchers close, in-flight rows finish on their original
//!   engines (the batcher drains residual rows after `close()`), and the
//!   generation retires once nothing holds its `Arc` any more.  A request
//!   that raced the swap and hit a closed queue gets a typed `Closed`
//!   rejection and retries against the freshly-swapped generation — the
//!   pointer swap happens *before* the old lanes close, so zero requests
//!   fail across a reload.
//! * **Retire** — a reaper thread joins the drained generation's dispatcher
//!   workers and counts the retirement; block pools, packed weights and
//!   engines are freed when the last `Arc<Deployment>` drops.
//! * **Drain** — [`Registry::drain_all`] routes graceful shutdown
//!   (SIGTERM / ctrl-c) through the same path: close, drain, join — no
//!   batch is aborted mid-flight.
//!
//! Aggregate [`Counters`] are registry-wide and outlive every generation, so
//! shed/pool totals stay monotonic across reloads (the PR #4 invariant,
//! extended).

pub mod replica;

pub use replica::{ReplicaGuard, ReplicaSet};

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize,
                        Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{Manifest, ModelSpec, ServerConfig};
use crate::coordinator::batcher::{BatchWait, Batcher};
use crate::coordinator::{Router, TaskOutput};
use crate::metrics::{Counters, Histogram, RollingWindow};
use crate::runtime::{EncoderBatch, KernelConfig, Runtime};
use crate::telemetry::{self, FlightRecorder, RowTimings, SignalHub,
                       StageStats};

/// One completed row: the decoded output plus the precision variant of the
/// pipeline that actually served it — the SLO ladder may have shifted the
/// lane away from its default rung between admission and dispatch, and
/// every response reports `served_precision` so degraded answers are
/// visible to the caller.
#[derive(Debug, Clone)]
pub struct RowOutput {
    pub output: TaskOutput,
    pub served_variant: String,
    /// Dispatcher-side stage timings of this row (queue / form / forward /
    /// gemm / decode; `tokenize_us` is filled in by the server).  `None`
    /// only for paths that never crossed a dispatcher.
    pub timings: Option<RowTimings>,
}

/// Typed per-row failure delivered through a [`Reply`] handle.
#[derive(Debug, Clone)]
pub enum RowError {
    /// Engine failure after the row was formed (HTTP 500).
    Failed(String),
    /// The row's deadline passed before the forward pass ran (HTTP 504);
    /// the row was dropped at form time and never cost a batch slot.
    DeadlineExceeded,
}

/// Reply handle of one enqueued row (the submitting thread blocks on the
/// receiving end).
pub type Reply = mpsc::Sender<Result<RowOutput, RowError>>;

/// Per-generation lane tuning, distilled from [`ServerConfig`]: the registry
/// applies the same knobs to every generation it builds.
#[derive(Debug, Clone)]
pub struct LaneConfig {
    pub batch_timeout_ms: u64,
    /// Dispatcher workers per lane (resolved, >= 1).
    pub workers_per_lane: usize,
    /// Engine replicas per lane (>= 1; see [`ReplicaSet`]).
    pub replicas_per_lane: usize,
    pub max_queue_depth: usize,
    /// Variant to activate on every task of every new generation (reload
    /// keeps serving the variant policy the process was started with unless
    /// the reload request names one explicitly).
    pub default_variant: Option<String>,
    /// Threads one native GEMM is split across (resolved, >= 1).
    pub gemm_threads: usize,
    /// `--pin-cores` core sets: replica `r` pins its GEMM pool to set
    /// `r % len`, dispatcher workers round-robin the flattened union.
    pub pin_cores: Vec<Vec<usize>>,
    /// Run the SLO precision-degradation ladder controller on every
    /// native-backend lane (`--ladder`).
    pub ladder: bool,
    /// Rolling-p99 SLO in milliseconds for the ladder's pressure signal
    /// (`--slo-p99-ms`; 0 = queue-depth pressure only).
    pub slo_p99_ms: u64,
    /// Per-model dispatcher/queue budgets apportioned from the global
    /// weighted pool (`--lane-weight`).  The table is *shared* (one `Arc`
    /// behind every generation of every model), so when `--learn-weights`
    /// re-apportions shares at runtime the new budgets take effect on the
    /// live generation and survive hot reloads.
    pub budgets: Arc<BudgetTable>,
    /// Cross-lane work stealing (`--no-steal` turns it off).
    pub steal: bool,
    /// Periodically re-derive lane-budget shares from the signal hub's
    /// observed per-model arrival rates and queue waits
    /// (`--learn-weights`; the collector thread runs the learner).
    pub learn_weights: bool,
    /// The in-process time-series store the closed-loop controllers (ladder
    /// pressure test, weight learner) query; registry-lifetime, fed by the
    /// collector thread ([`telemetry::hub::spawn_signal_collector`]).
    pub hub: Arc<SignalHub>,
    /// The black-box flight recorder every lane's lifecycle hooks write to
    /// (cap 0 = disabled); registry-lifetime, so traces span hot reloads.
    pub flight: Arc<FlightRecorder>,
}

/// The shared, runtime-mutable lane-budget table: the global worker/queue
/// pools are fixed at startup, the per-model shares dividing them are not —
/// `--learn-weights` rewrites shares through [`BudgetTable::apply_shares`]
/// and every reader (lane startup, `/v1/models`, budget gauges) sees the
/// new apportionment immediately.
#[derive(Debug)]
pub struct BudgetTable {
    /// Total dispatcher workers across all models (fixed at startup).
    worker_pool: f64,
    /// Total batcher queue depth across all models (fixed at startup).
    queue_pool: f64,
    /// Flat fallback for models outside the startup budget.
    fallback_workers: usize,
    fallback_queue: usize,
    inner: RwLock<HashMap<String, LaneBudget>>,
}

impl BudgetTable {
    fn new(worker_pool: f64, queue_pool: f64, fallback_workers: usize,
           fallback_queue: usize, initial: HashMap<String, LaneBudget>)
           -> Arc<BudgetTable> {
        Arc::new(BudgetTable {
            worker_pool,
            queue_pool,
            fallback_workers: fallback_workers.max(1),
            fallback_queue: fallback_queue.max(1),
            inner: RwLock::new(initial),
        })
    }

    /// Full budget record of `model_id` (the flat fallback, flagged by
    /// `share == 0.0`, for models the startup budget never saw).
    pub fn budget(&self, model_id: &str) -> LaneBudget {
        let inner = self.inner.read().unwrap();
        inner.get(model_id).copied().unwrap_or(LaneBudget {
            weight: 1.0,
            share: if inner.is_empty() { 1.0 } else { 0.0 },
            workers: self.fallback_workers,
            queue_depth: self.fallback_queue,
        })
    }

    /// `(workers, queue_depth)` of `model_id`'s lanes.
    pub fn budget_for(&self, model_id: &str) -> (usize, usize) {
        let b = self.budget(model_id);
        (b.workers, b.queue_depth)
    }

    /// Current `(model, budget)` rows, sorted by model id.
    pub fn snapshot(&self) -> Vec<(String, LaneBudget)> {
        let mut v: Vec<(String, LaneBudget)> = self.inner.read().unwrap()
            .iter()
            .map(|(id, b)| (id.clone(), *b))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Replace the per-model shares, re-slicing the fixed worker/queue
    /// pools.  `shares` need not be normalized; each model keeps at least
    /// one worker and one queue slot (same floor as the startup split).
    pub fn apply_shares(&self, shares: &[(String, f64)]) {
        let total: f64 = shares.iter().map(|(_, s)| s.max(0.0)).sum();
        if total <= 0.0 {
            return;
        }
        let n = shares.len() as f64;
        let mut inner = self.inner.write().unwrap();
        for (id, share) in shares {
            let share = share.max(0.0) / total;
            inner.insert(id.clone(), LaneBudget {
                weight: share * n,
                share,
                workers: ((self.worker_pool * share).round() as usize).max(1),
                queue_depth: ((self.queue_pool * share).round() as usize)
                    .max(1),
            });
        }
    }
}

/// One model's slice of the global dispatcher/queue budget: the fixed
/// per-lane split (`workers_per_lane` x models, `max_queue_depth` x models)
/// re-apportioned by `--lane-weight` share.
#[derive(Debug, Clone, Copy)]
pub struct LaneBudget {
    /// Raw `--lane-weight` value (1.0 when unspecified).
    pub weight: f64,
    /// Normalized share of the global pool.
    pub share: f64,
    /// Dispatcher workers each of this model's lanes gets (>= 1).
    pub workers: usize,
    /// Batcher queue depth each of this model's lanes gets (>= 1).
    pub queue_depth: usize,
}

impl LaneConfig {
    pub fn from_server(cfg: &ServerConfig) -> LaneConfig {
        let workers_per_lane = cfg.resolved_workers_per_lane().max(1);
        let max_queue_depth = cfg.max_queue_depth.max(1);
        // the global pool is what the flat split would have provisioned in
        // total; weights re-divide it, so equal weights reproduce the flat
        // split exactly and a hot model can only gain what a cold one cedes
        let ids: Vec<&str> = if cfg.models.is_empty() {
            vec!["default"]
        } else {
            cfg.models.iter().map(|(id, _)| id.as_str()).collect()
        };
        let weight_of = |id: &str| {
            cfg.lane_weights
                .iter()
                .find(|(w_id, _)| w_id == id)
                .map(|(_, w)| w.max(f64::MIN_POSITIVE))
                .unwrap_or(1.0)
        };
        let total_w: f64 = ids.iter().map(|id| weight_of(id)).sum();
        let worker_pool = (workers_per_lane * ids.len()) as f64;
        let queue_pool = (max_queue_depth * ids.len()) as f64;
        let initial: HashMap<String, LaneBudget> = ids
            .iter()
            .map(|&id| {
                let weight = weight_of(id);
                let share = weight / total_w;
                let budget = LaneBudget {
                    weight,
                    share,
                    workers: ((worker_pool * share).round() as usize).max(1),
                    queue_depth: ((queue_pool * share).round() as usize)
                        .max(1),
                };
                (id.to_string(), budget)
            })
            .collect();
        let budgets = BudgetTable::new(worker_pool, queue_pool,
                                       workers_per_lane, max_queue_depth,
                                       initial);
        let flight_cap = if cfg.flight_recorder { cfg.flight_cap } else { 0 };
        LaneConfig {
            batch_timeout_ms: cfg.batch_timeout_ms,
            workers_per_lane,
            replicas_per_lane: cfg.replicas_per_lane.max(1),
            max_queue_depth,
            default_variant: cfg.default_variant.clone(),
            gemm_threads: cfg.resolved_gemm_threads().max(1),
            pin_cores: cfg.pin_cores.clone(),
            ladder: cfg.ladder,
            slo_p99_ms: cfg.slo_p99_ms,
            budgets,
            steal: cfg.steal,
            learn_weights: cfg.learn_weights,
            hub: Arc::new(SignalHub::new()),
            flight: Arc::new(FlightRecorder::new(flight_cap)),
        }
    }

    /// The `(workers, queue_depth)` budget of `model_id`'s lanes.  Models
    /// the startup budget never saw (a runtime `load_model` of a new id)
    /// keep the flat per-lane split.
    pub fn budget_for(&self, model_id: &str) -> (usize, usize) {
        self.budgets.budget_for(model_id)
    }

    /// Full budget record for stats surfaces; the fallback mirrors
    /// [`LaneConfig::budget_for`] (`share` 0.0 flags a model outside the
    /// startup budget).
    pub fn budget(&self, model_id: &str) -> LaneBudget {
        self.budgets.budget(model_id)
    }

    /// The dispatcher-pin set: every configured core, flattened in order.
    /// Worker `w` of a lane pins to `flat[w % len]` (empty = unpinned).
    fn flat_cores(&self) -> Vec<usize> {
        self.pin_cores.iter().flatten().copied().collect()
    }
}

/// Per-lane observability: what each dispatcher worker of the shard set did,
/// plus the lane's own request-latency histogram.
pub struct LaneStats {
    task: String,
    continuous: bool,
    pub worker_batches: Vec<AtomicU64>,
    pub worker_rows: Vec<AtomicU64>,
    /// Core each dispatcher worker observed itself pinned to (`-1` = not
    /// pinned: no `--pin-cores`, or `sched_setaffinity` failed/unavailable).
    pub worker_pinned: Vec<AtomicI64>,
    pub latency: Histogram,
    /// Recent-request latency (rolling window, ages out) — the ladder
    /// controller's SLO signal, unlike the monotonic `latency` histogram.
    /// Only *served* rows are recorded here: sheds and deadline drops
    /// answer in microseconds and would skew the window downward, masking
    /// the very pressure the ladder is supposed to react to.
    pub recent: RollingWindow,
    /// Per-stage latency histograms (queue / form / forward / gemm /
    /// decode), recorded by the dispatcher for every served row.
    pub stages: StageStats,
    /// Batches this lane's workers stole from sibling lanes and ran for
    /// them (the thief-side count).
    pub steals_in: AtomicU64,
    /// Batches formed from THIS lane's queue but run by a sibling lane's
    /// worker (the victim-side count).
    pub steals_out: AtomicU64,
    /// Rows carried by the `steals_out` batches; they served this lane's
    /// traffic, so [`LaneStats::rows`] includes them.
    pub stolen_rows: AtomicU64,
    /// Rolling per-served-rung latency windows: the observed end-to-end
    /// cost of each precision level this lane actually served
    /// (`samp_rung_latency_us` and the `/v1/models` `rung_latency` block).
    pub rung_latency: RungLatency,
}

/// Per-`served_precision` rolling latency windows of one lane.  Rung keys
/// are variant names; windows appear lazily the first time a rung serves a
/// row.  The set is tiny (2–3 ladder rungs), so a mutexed vec beats a map.
#[derive(Default)]
pub struct RungLatency {
    windows: Mutex<Vec<(String, Arc<RollingWindow>)>>,
}

impl RungLatency {
    /// Record one served row's end-to-end latency under its served rung.
    pub fn record_us(&self, rung: &str, us: f64) {
        let window = {
            let mut w = self.windows.lock().unwrap();
            match w.iter().find(|(r, _)| r == rung) {
                Some((_, win)) => win.clone(),
                None => {
                    let win = Arc::new(RollingWindow::default());
                    w.push((rung.to_string(), win.clone()));
                    win
                }
            }
        };
        window.record_us(us);
    }

    /// `(rung, window)` snapshot, sorted by rung name.
    pub fn snapshot(&self) -> Vec<(String, Arc<RollingWindow>)> {
        let mut v: Vec<(String, Arc<RollingWindow>)> =
            self.windows.lock().unwrap().clone();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}

impl LaneStats {
    fn new(task: &str, continuous: bool, workers: usize) -> LaneStats {
        LaneStats {
            task: task.to_string(),
            continuous,
            worker_batches: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            worker_rows: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            worker_pinned: (0..workers).map(|_| AtomicI64::new(-1)).collect(),
            latency: Histogram::new(),
            recent: RollingWindow::default(),
            stages: StageStats::default(),
            steals_in: AtomicU64::new(0),
            steals_out: AtomicU64::new(0),
            stolen_rows: AtomicU64::new(0),
            rung_latency: RungLatency::default(),
        }
    }

    pub fn task(&self) -> &str {
        &self.task
    }

    pub fn continuous(&self) -> bool {
        self.continuous
    }

    pub fn workers(&self) -> usize {
        self.worker_batches.len()
    }

    /// Batches that served this lane's traffic: its own shard set's plus
    /// the ones sibling workers stole and ran for it.
    pub fn batches(&self) -> u64 {
        self.worker_batches
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum::<u64>()
            + self.steals_out.load(Ordering::Relaxed)
    }

    pub fn rows(&self) -> u64 {
        self.worker_rows
            .iter()
            .map(|r| r.load(Ordering::Relaxed))
            .sum::<u64>()
            + self.stolen_rows.load(Ordering::Relaxed)
    }

    pub fn batch_fill(&self) -> f64 {
        let b = self.batches();
        if b == 0 {
            return 0.0;
        }
        self.rows() as f64 / b as f64
    }
}

/// The SLO-aware precision degradation ladder of one lane: 2–3 variants on
/// the planner frontier, ordered from the lane's default rung (index 0)
/// down to the fully-quantized frontier.  A per-lane controller thread
/// ([`Deployment`] spawns it next to the dispatcher shard set) shifts the
/// served rung *down* while the lane is under pressure — queue depth past
/// half its admission cap, or rolling p99 past `--slo-p99-ms` — and back
/// *up* once pressure stays clear, trading a little accuracy for staying
/// inside the latency SLO instead of shedding 429s.
pub struct Ladder {
    /// Variant per rung; `rungs[0]` is the lane's default.
    rungs: Vec<String>,
    level: AtomicUsize,
}

impl Ladder {
    /// Pressure must stay clear this long before the ladder shifts back up
    /// one rung (down-shifts act on the next controller tick).
    const UP_HOLD: Duration = Duration::from_millis(250);
    /// Controller tick.
    const TICK: Duration = Duration::from_millis(10);

    /// Derive the rung list for `spec` with `default_variant` on top: the
    /// deepest-INT8 variant forms the bottom rung, plus one middle planner
    /// rung when the frontier has an intermediate point (a variant named
    /// `auto` — the planner's own pick — is preferred as the middle).
    /// Variants no more quantized than the default never become rungs: the
    /// ladder only ever trades accuracy *down* for latency.
    fn rungs_for(spec: &ModelSpec, default_variant: &str) -> Vec<String> {
        let dq = spec
            .variants
            .get(default_variant)
            .map(|v| v.quantized_layers())
            .unwrap_or(0);
        let mut deeper: Vec<(usize, String)> = spec
            .variants
            .values()
            .filter(|v| v.quantized_layers() > dq)
            .map(|v| (v.quantized_layers(), v.name.clone()))
            .collect();
        deeper.sort();
        deeper.dedup_by_key(|(q, _)| *q);
        let mut rungs = vec![default_variant.to_string()];
        if let Some((_, last)) = deeper.last().cloned() {
            if deeper.len() > 1 {
                let mid = deeper
                    .iter()
                    .find(|(_, n)| n == "auto")
                    .cloned()
                    .unwrap_or_else(|| deeper[(deeper.len() - 1) / 2].clone());
                if mid.1 != last {
                    rungs.push(mid.1);
                }
            }
            rungs.push(last);
        }
        rungs
    }

    /// The rung variants, default first.
    pub fn rungs(&self) -> &[String] {
        &self.rungs
    }

    /// Currently-served rung index (0 = the lane default).
    pub fn level(&self) -> usize {
        self.level.load(Ordering::Relaxed).min(self.rungs.len() - 1)
    }

    /// The variant the ladder currently serves.
    pub fn served(&self) -> &str {
        &self.rungs[self.level()]
    }
}

/// One task's serving lane inside a deployment: the admission-controlled
/// batcher queue, the engine replica set, and the dispatcher shard set
/// draining the queue.
pub struct TaskLane {
    pub batcher: Arc<Batcher<Reply>>,
    pub replicas: Arc<ReplicaSet>,
    pub stats: Arc<LaneStats>,
    /// The lane's precision ladder (`None`: `--ladder` off, a PJRT lane, or
    /// a variant frontier with fewer than two rungs).
    pub ladder: Option<Arc<Ladder>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl TaskLane {
    /// Join the lane's dispatcher workers (idempotent; callers close the
    /// batcher first or this blocks forever).
    fn join_workers(&self) {
        let handles: Vec<_> = {
            let mut w = self.workers.lock().unwrap();
            w.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Cap on the steal-probe backoff, in idle polls: a worker whose probes
/// keep failing still re-probes within ~64 poll intervals, so a traffic
/// shift onto a sibling model is picked up in well under a second.
const MAX_STEAL_BACKOFF: u32 = 64;

/// Everything one dispatcher worker needs to run a batch against a lane.
/// Bundled so the same executor serves both the worker's own lane and a
/// stolen sibling lane (where every field is the *victim's*).
struct LaneCtx {
    batcher: Arc<Batcher<Reply>>,
    replicas: Arc<ReplicaSet>,
    stats: Arc<LaneStats>,
    counters: Arc<Counters>,
    model_id: String,
    heal_tx: Option<mpsc::Sender<String>>,
    /// The registry's flight recorder; lifecycle hooks (form, dispatch,
    /// heal, reply) record against `model_id` + the lane's task.  For a
    /// stolen batch this is the *victim's* identity, like every other
    /// field — the trace shows the batch on the lane it served.
    flight: Arc<FlightRecorder>,
}

/// Cross-lane steal coordination, shared by every deployment generation of
/// every model.  Holds weak [`ModelEntry`] references — a thief resolves
/// each candidate's *current* generation per probe, so a hot reload
/// retargets stealers onto the fresh generation for free — plus the
/// registry-lifetime `(from, to)` steal counts behind
/// `samp_lane_steals_total` (monotone across reloads, like [`Counters`]).
pub struct StealRouter {
    enabled: bool,
    targets: RwLock<Vec<(String, std::sync::Weak<ModelEntry>)>>,
    pairs: Mutex<BTreeMap<(String, String), u64>>,
}

impl StealRouter {
    fn new(enabled: bool) -> Arc<StealRouter> {
        Arc::new(StealRouter {
            enabled,
            targets: RwLock::new(Vec::new()),
            pairs: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    fn register(&self, id: &str, entry: std::sync::Weak<ModelEntry>) {
        let mut targets = self.targets.write().unwrap();
        if targets.iter().all(|(t, _)| t != id) {
            targets.push((id.to_string(), entry));
        }
    }

    fn record(&self, from: &str, to: &str) {
        *self
            .pairs
            .lock()
            .unwrap()
            .entry((from.to_string(), to.to_string()))
            .or_insert(0) += 1;
    }

    /// Snapshot of the `(victim, thief, batches)` steal counts.
    pub fn pairs(&self) -> Vec<(String, String, u64)> {
        self.pairs
            .lock()
            .unwrap()
            .iter()
            .map(|((f, t), n)| (f.clone(), t.clone(), *n))
            .collect()
    }

    /// The most-backlogged lane a thief serving `thief_model` may steal
    /// from: a non-draining lane of *another* model, of the same backend
    /// kind (`continuous`), with the deepest non-empty queue (replica
    /// in-flight load breaks ties).  Returns the victim deployment too, so
    /// the thief keeps the generation alive while running the stolen batch.
    fn victim(&self, thief_model: &str, continuous: bool)
              -> Option<(Arc<Deployment>, Arc<TaskLane>)> {
        if !self.enabled {
            return None;
        }
        let targets = self.targets.read().unwrap();
        type Best = Option<((usize, usize), Arc<Deployment>, Arc<TaskLane>)>;
        let mut best: Best = None;
        for (id, weak) in targets.iter() {
            if id == thief_model {
                continue;
            }
            let Some(entry) = weak.upgrade() else { continue };
            let dep = entry.current();
            if dep.is_draining() {
                continue;
            }
            for lane in dep.lanes_snapshot() {
                if lane.stats.continuous() != continuous {
                    continue;
                }
                let depth = lane.batcher.len();
                if depth == 0 {
                    continue;
                }
                let key = (depth, lane.replicas.in_flight_total());
                let deeper = match &best {
                    Some((k, _, _)) => key > *k,
                    None => true,
                };
                if deeper {
                    best = Some((key, dep.clone(), lane));
                }
            }
        }
        best.map(|(_, dep, lane)| (dep, lane))
    }
}

/// One immutable generation of one model: manifest + router + lanes +
/// replica sets.  Built off-path, warmed, swapped in atomically, and drained
/// (never mutated) when the next generation replaces it.
pub struct Deployment {
    pub model_id: String,
    pub generation: u64,
    pub router: Arc<Router>,
    cfg: LaneConfig,
    counters: Arc<Counters>,
    lanes: RwLock<HashMap<String, Arc<TaskLane>>>,
    draining: AtomicBool,
    /// Registry heal-request channel: dispatcher workers send the model id
    /// here after healing a poisoned replica in place, so the registry can
    /// retire this generation and swap a cleanly rebuilt one behind the
    /// in-place fix (see [`Registry::heal_requests`]).
    heal_tx: Mutex<Option<mpsc::Sender<String>>>,
    /// The registry's steal router (None until [`set_steal_router`] runs;
    /// lanes started before that never steal).
    ///
    /// [`set_steal_router`]: Deployment::set_steal_router
    steal: Mutex<Option<Arc<StealRouter>>>,
    /// Stolen batches of THIS generation currently running on a foreign
    /// lane's worker.  A thief increments it *before* probing the queue and
    /// decrements after recycling the block, so the reaper can wait for
    /// foreign workers the way `join_workers` waits for its own.
    stolen_inflight: AtomicUsize,
}

impl Deployment {
    /// Build a fresh generation from on-disk artifacts: its own [`Runtime`]
    /// (native weight caches die with the generation), its own [`Router`],
    /// lanes started lazily (or eagerly by [`Deployment::warm`]).
    pub fn build(model_id: &str, generation: u64, artifacts_dir: &Path,
                 cfg: LaneConfig, counters: Arc<Counters>)
                 -> Result<Arc<Deployment>> {
        let manifest = Manifest::load(artifacts_dir).with_context(|| {
            format!("loading model `{model_id}` from {}",
                    artifacts_dir.display())
        })?;
        let runtime = Arc::new(Runtime::cpu()?);
        let router = Arc::new(Router::new(runtime, manifest)?);
        let dep = Self::from_router(model_id, generation, router, cfg,
                                    counters);
        if let Some(v) = dep.cfg.default_variant.clone() {
            dep.activate_all(&v)?;
        }
        Ok(dep)
    }

    /// Wrap an already-built router as a generation (the single-model
    /// compatibility path `Server::new` uses; no default-variant application,
    /// the caller controls the router's active pipelines).
    pub fn from_router(model_id: &str, generation: u64, router: Arc<Router>,
                       cfg: LaneConfig, counters: Arc<Counters>)
                       -> Arc<Deployment> {
        // install the kernel policy before any lane builds replica
        // pipelines, so every native model this generation caches is born
        // with its GEMM pool and core set
        router.runtime.set_kernel_config(KernelConfig {
            gemm_threads: cfg.gemm_threads.max(1),
            pin_cores: cfg.pin_cores.clone(),
        });
        Arc::new(Deployment {
            model_id: model_id.to_string(),
            generation,
            router,
            cfg,
            counters,
            lanes: RwLock::new(HashMap::new()),
            draining: AtomicBool::new(false),
            heal_tx: Mutex::new(None),
            steal: Mutex::new(None),
            stolen_inflight: AtomicUsize::new(0),
        })
    }

    /// Install the registry's heal-request channel; lanes created after
    /// this call notify the registry whenever they heal a poisoned replica
    /// in place, triggering a full generation rebuild behind the fix.
    pub fn set_heal_notifier(&self, tx: mpsc::Sender<String>) {
        *self.heal_tx.lock().unwrap() = Some(tx);
    }

    /// Install the registry's steal router; lanes created after this call
    /// probe sibling models' lanes whenever their own queue runs dry.
    pub fn set_steal_router(&self, router: Arc<StealRouter>) {
        *self.steal.lock().unwrap() = Some(router);
    }

    /// Stolen batches of this generation currently running on foreign
    /// workers (stats surface; see [`Deployment::await_stolen`]).
    pub fn stolen_inflight(&self) -> usize {
        self.stolen_inflight.load(Ordering::SeqCst)
    }

    /// Block until no foreign (stealing) worker still holds one of this
    /// generation's batches.  [`Deployment::join_workers`] only covers this
    /// deployment's own threads; a sibling lane's dispatcher may have
    /// formed a stolen batch just before the drain closed the queues, and
    /// retiring the generation out from under it would drop those rows.
    pub fn await_stolen(&self) {
        while self.stolen_inflight.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    pub fn tasks(&self) -> Vec<String> {
        self.router.tasks()
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Live lanes, sorted by task (stats surfaces).
    pub fn lanes_snapshot(&self) -> Vec<Arc<TaskLane>> {
        let lanes = self.lanes.read().unwrap();
        let mut v: Vec<Arc<TaskLane>> = lanes.values().cloned().collect();
        v.sort_by(|a, b| a.stats.task().cmp(b.stats.task()));
        v
    }

    /// Get or start the lane for `task`.  `Ok(None)` means this generation
    /// is draining — callers re-resolve the current generation and retry
    /// (the swap happens before the drain, so a fresh resolve sees the new
    /// one).  Steady state takes a read lock only; creation double-checks
    /// the draining flag under the write lock, so `begin_drain` can never
    /// miss a lane.
    pub fn lane(&self, task: &str) -> Result<Option<Arc<TaskLane>>> {
        if self.is_draining() {
            return Ok(None);
        }
        if let Some(l) = self.lanes.read().unwrap().get(task) {
            return Ok(Some(l.clone()));
        }
        let pipe = self.router.pipeline(task)?; // may compile; outside locks
        let replicas = Arc::new(ReplicaSet::build(
            self.router.clone(), task, self.cfg.replicas_per_lane)?);
        let mut lanes = self.lanes.write().unwrap();
        if self.is_draining() {
            // begin_drain closes the lanes it can see under this lock; a
            // lane inserted after the flag flips would never be closed
            return Ok(None);
        }
        if let Some(l) = lanes.get(task) {
            return Ok(Some(l.clone()));
        }
        // Continuous (token-budget, variable-shape) forming needs a backend
        // without a static-shape constraint; PJRT lanes keep fixed forming.
        let continuous = pipe.backend_name() == "native";
        let timeout = Duration::from_millis(self.cfg.batch_timeout_ms);
        // the model's weighted slice of the global worker/queue pool (the
        // flat per-lane split for models outside the startup budget)
        let (n_workers, depth) = self.cfg.budget_for(&self.model_id);
        let batcher = if continuous {
            Batcher::<Reply>::continuous(
                pipe.spec.batch,
                pipe.spec.seq_len,
                timeout,
                depth,
                Batcher::<Reply>::default_granularity(pipe.spec.seq_len),
            )
        } else {
            Batcher::<Reply>::with_queue_depth(
                pipe.spec.batch, pipe.spec.seq_len, timeout, depth)
        };
        let batcher = Arc::new(batcher.with_counters(self.counters.clone()));
        let stats = Arc::new(LaneStats::new(task, continuous, n_workers));
        let pin_set = self.cfg.flat_cores();
        let heal_tx = self.heal_tx.lock().unwrap().clone();
        let steal = self
            .steal
            .lock()
            .unwrap()
            .clone()
            .filter(|sr| self.cfg.steal && sr.enabled());
        // idle-probe cadence: a fraction of the forming timeout, so a
        // stealable backlog is found about as fast as a partial batch forms
        let poll = Duration::from_millis(self.cfg.batch_timeout_ms.clamp(1, 20));
        let mut workers: Vec<std::thread::JoinHandle<()>> = (0..n_workers)
            .map(|w| {
                let ctx = LaneCtx {
                    batcher: batcher.clone(),
                    replicas: replicas.clone(),
                    stats: stats.clone(),
                    counters: self.counters.clone(),
                    model_id: self.model_id.clone(),
                    heal_tx: heal_tx.clone(),
                    flight: self.cfg.flight.clone(),
                };
                let steal = steal.clone();
                let core = (!pin_set.is_empty())
                    .then(|| pin_set[w % pin_set.len()]);
                std::thread::spawn(move || {
                    // best-effort: the worker serves unpinned (and the stats
                    // slot stays -1) when sched_setaffinity is unavailable
                    if let Some(c) = core.and_then(crate::util::affinity::try_pin)
                    {
                        ctx.stats.worker_pinned[w].store(c as i64,
                                                         Ordering::Relaxed);
                    }
                    Self::dispatch_loop(&ctx, w, steal.as_deref(), poll)
                })
            })
            .collect();
        // the precision ladder rides native lanes only: rung shifts rebuild
        // replica pipelines, which PJRT's static-shape artifact cache makes
        // pointless (every variant is a separate compiled executable anyway)
        let ladder = (self.cfg.ladder && continuous)
            .then(|| {
                let rungs = Ladder::rungs_for(&pipe.spec, &pipe.variant);
                (rungs.len() > 1).then(|| {
                    Arc::new(Ladder { rungs, level: AtomicUsize::new(0) })
                })
            })
            .flatten();
        if let Some(ladder) = ladder.clone() {
            let b2 = batcher.clone();
            let counters = self.counters.clone();
            let router = self.router.clone();
            let model_id = self.model_id.clone();
            let task_name = task.to_string();
            let hub = self.cfg.hub.clone();
            let flight = self.cfg.flight.clone();
            let slo_us = (self.cfg.slo_p99_ms as f64) * 1000.0;
            workers.push(std::thread::spawn(move || {
                Self::ladder_loop(&b2, &ladder, &router, &model_id,
                                  &task_name, &counters, &hub, &flight,
                                  slo_us)
            }));
        }
        let lane = Arc::new(TaskLane {
            batcher,
            replicas,
            stats,
            ladder,
            workers: Mutex::new(workers),
        });
        lanes.insert(task.to_string(), lane.clone());
        Ok(Some(lane))
    }

    /// The per-lane ladder controller: watch queue depth and rolling p99,
    /// shift the served variant down the precision ladder under pressure
    /// and back up once pressure stays clear for [`Ladder::UP_HOLD`].  Runs
    /// as one extra lane worker thread; exits when the lane's batcher
    /// closes (generation drain / retire — the batcher is consulted for
    /// lifecycle only).
    ///
    /// Every *decision* input comes from [`SignalHub`] queries — the same
    /// sampled series `/metrics` exports — not from direct queue or stats
    /// reads, so a dashboard showing `samp_lane_queue_depth` and
    /// `samp_lane_recent_p99_us` shows exactly what the controller saw.
    /// Until the collector has sampled the lane once (its tick is half the
    /// controller's), the queries miss and the lane reads as unpressured —
    /// the same as an idle lane.
    #[allow(clippy::too_many_arguments)]
    fn ladder_loop(batcher: &Batcher<Reply>, ladder: &Ladder, router: &Router,
                   model_id: &str, task: &str, counters: &Counters,
                   hub: &SignalHub, flight: &FlightRecorder,
                   slo_p99_us: f64) {
        let mut clear_since: Option<Instant> = None;
        while !batcher.is_closed() {
            std::thread::sleep(Ladder::TICK);
            let depth = hub.latest(model_id, task, "queue_depth")
                .unwrap_or(0.0);
            let capacity = hub.latest(model_id, task, "queue_capacity")
                .unwrap_or(f64::INFINITY);
            let p99 = hub.latest(model_id, task, "recent_p99_us");
            let pressured = depth * 2.0 > capacity
                || (slo_p99_us > 0.0
                    && p99.is_some_and(|v| v > slo_p99_us));
            let level = ladder.level();
            if pressured {
                clear_since = None;
                if level + 1 < ladder.rungs.len() {
                    let next = &ladder.rungs[level + 1];
                    match router.activate(task, next) {
                        Ok(_) => {
                            ladder.level.store(level + 1, Ordering::Relaxed);
                            counters.inc_ladder_shifts();
                            hub.record(model_id, task, "rung_shift",
                                       (level + 1) as f64);
                            flight.instant(
                                model_id, task, "rung_shift", 0,
                                format!("down to `{next}` (queue {depth})"));
                            eprintln!("[ladder] {task}: pressure (queue \
                                       {depth}) — shifting down to `{next}`");
                        }
                        Err(e) => eprintln!(
                            "[ladder] {task}: activating `{next}` failed: \
                             {e:#}"),
                    }
                }
            } else if level > 0 {
                match clear_since {
                    None => clear_since = Some(Instant::now()),
                    Some(t) if t.elapsed() >= Ladder::UP_HOLD => {
                        let prev = &ladder.rungs[level - 1];
                        match router.activate(task, prev) {
                            Ok(_) => {
                                ladder.level.store(level - 1,
                                                   Ordering::Relaxed);
                                counters.inc_ladder_shifts();
                                hub.record(model_id, task, "rung_shift",
                                           (level - 1) as f64);
                                flight.instant(model_id, task, "rung_shift",
                                               0,
                                               format!("up to `{prev}`"));
                                // the next up-shift needs its own window
                                clear_since = None;
                                eprintln!("[ladder] {task}: pressure clear — \
                                           shifting back up to `{prev}`");
                            }
                            Err(e) => eprintln!(
                                "[ladder] {task}: activating `{prev}` \
                                 failed: {e:#}"),
                        }
                    }
                    Some(_) => {}
                }
            } else {
                clear_since = None;
            }
        }
    }

    /// One dispatcher worker of a lane's shard set: drain batches from the
    /// shared queue, run the least-loaded engine replica, then complete rows
    /// individually — each reply fires the moment its own row is decoded.
    ///
    /// With a [`StealRouter`] installed the worker is *elastic*: whenever
    /// its own queue stays steal-hungry (empty, or every bucket below half
    /// a formable batch) through one idle poll, it probes the
    /// most-backlogged sibling lane of the same backend kind and runs one
    /// stolen batch for it — on the **victim's** replicas, so outputs,
    /// `served_precision` and heal identity are exactly what the victim's
    /// own workers would have produced; only the thread is borrowed.  The
    /// own queue is re-checked first on every iteration, and failed probes
    /// back off exponentially, so a lane with work never donates workers.
    fn dispatch_loop(ctx: &LaneCtx, worker: usize,
                     steal: Option<&StealRouter>, poll: Duration) {
        let Some(sr) = steal else {
            // static partitioning (--no-steal, or a pre-router lane): block
            // on the own queue forever, exactly the pre-steal behavior
            while let Some(fb) = ctx.batcher.next_batch() {
                Self::execute_batch(ctx, fb, Some(worker));
            }
            return;
        };
        let mut backoff = 1u32; // failed-probe backoff, in idle polls
        let mut skip = 0u32;
        loop {
            match ctx.batcher.next_batch_timeout(poll) {
                BatchWait::Formed(fb) => {
                    backoff = 1;
                    skip = 0;
                    Self::execute_batch(ctx, fb, Some(worker));
                }
                BatchWait::Closed => return,
                BatchWait::Idle => {
                    if skip > 0 {
                        skip -= 1;
                        continue;
                    }
                    if !ctx.batcher.is_hungry() {
                        continue;
                    }
                    let stole = match sr.victim(&ctx.model_id,
                                                ctx.stats.continuous()) {
                        Some((dep, lane)) => {
                            Self::run_stolen(ctx, sr, &dep, &lane)
                        }
                        None => false,
                    };
                    if stole {
                        backoff = 1;
                    } else {
                        skip = backoff;
                        backoff = (backoff * 2).min(MAX_STEAL_BACKOFF);
                    }
                }
            }
        }
    }

    /// Steal one batch from `lane` (of `dep`) and run it there: the formed
    /// bucket comes off the victim's queue under the victim's mutex, and
    /// execution uses the victim's replicas, stats, model id and heal
    /// channel — the thief contributes nothing but the thread.  Returns
    /// whether a batch was actually taken.
    fn run_stolen(ctx: &LaneCtx, sr: &StealRouter, dep: &Arc<Deployment>,
                  lane: &Arc<TaskLane>) -> bool {
        // count the would-be stolen batch on the victim generation *before*
        // probing its queue: the reaper checks this counter only after the
        // victim's own workers joined, so by incrementing first the thief
        // guarantees the reaper can never observe zero while a batch that
        // will form is unaccounted for (the reload-vs-steal race)
        dep.stolen_inflight.fetch_add(1, Ordering::SeqCst);
        let Some(fb) = lane.batcher.steal_bucket() else {
            dep.stolen_inflight.fetch_sub(1, Ordering::SeqCst);
            return false;
        };
        ctx.stats.steals_in.fetch_add(1, Ordering::Relaxed);
        lane.stats.steals_out.fetch_add(1, Ordering::Relaxed);
        ctx.counters.inc_lane_steals();
        sr.record(&dep.model_id, &ctx.model_id);
        ctx.flight.instant(&dep.model_id, lane.stats.task(), "steal",
                           fb.rows as u64,
                           format!("by `{}`", ctx.model_id));
        let victim = LaneCtx {
            batcher: lane.batcher.clone(),
            replicas: lane.replicas.clone(),
            stats: lane.stats.clone(),
            counters: ctx.counters.clone(),
            model_id: dep.model_id.clone(),
            heal_tx: dep.heal_tx.lock().unwrap().clone(),
            flight: ctx.flight.clone(),
        };
        Self::execute_batch(&victim, fb, None);
        dep.stolen_inflight.fetch_sub(1, Ordering::SeqCst);
        true
    }

    /// Run one formed batch against `ctx`'s lane: answer deadline-expired
    /// rows, run the least-loaded replica (with an in-place
    /// [`ReplicaSet::heal`] + one retry on a poisoned GEMM pool, so
    /// injected worker panics drop zero in-flight rows), decode and reply
    /// per row, and recycle the block into the lane's own pool.  `worker`
    /// is the owning shard slot; `None` marks a stolen batch run by a
    /// sibling's worker — its rows land on the lane's steal counters
    /// instead of a worker slot.
    fn execute_batch(ctx: &LaneCtx, fb: crate::coordinator::FormedBatch<Reply>,
                     worker: Option<usize>) {
        let crate::coordinator::FormedBatch {
            block, replies, rows, expired, waits, form_time, ..
        } = fb;
        if !expired.is_empty() {
            ctx.counters.inc_deadline_expired(expired.len() as u64);
            ctx.counters.inc_errors_n(expired.len() as u64);
            for reply in expired {
                let _ = reply.send(Err(RowError::DeadlineExceeded));
            }
        }
        if rows == 0 {
            // every formed row had expired; nothing to run
            ctx.batcher.recycle(block);
            return;
        }
        ctx.counters.inc_batches(rows as u64);
        match worker {
            Some(w) => {
                ctx.stats.worker_batches[w].fetch_add(1, Ordering::Relaxed);
                ctx.stats.worker_rows[w].fetch_add(rows as u64,
                                                   Ordering::Relaxed);
            }
            None => {
                ctx.stats.stolen_rows.fetch_add(rows as u64,
                                                Ordering::Relaxed);
            }
        }
        let task = ctx.stats.task().to_string();
        ctx.flight.span(&ctx.model_id, &task, "form",
                        form_time.as_micros() as u64, rows as u64, "");
        // least-loaded replica, re-resolved per batch (one read lock) so
        // Router::activate switches a live lane to the new variant.
        // The GEMM scope pins kernel-clock attribution to THIS batch: a
        // stolen batch runs on a thief thread, and the scope guarantees its
        // kernel time lands on the victim lane's `gemm` histogram (via this
        // ctx) rather than wherever the thread's clock last pointed.
        let gemm_scope = telemetry::GemmScope::begin();
        let forward_start = Instant::now();
        let mut result = Self::run_batch(&ctx.replicas, &block);
        if result.is_err() && ctx.replicas.any_poisoned() {
            let healed = ctx.replicas.heal();
            if healed > 0 {
                ctx.counters.inc_replicas_healed(healed as u64);
                if let Some(tx) = ctx.heal_tx.as_ref() {
                    let _ = tx.send(ctx.model_id.clone());
                }
                ctx.flight.instant(&ctx.model_id, &task, "heal",
                                   healed as u64, "poisoned replica rebuilt");
                result = Self::run_batch(&ctx.replicas, &block);
            }
        }
        // forward (and its GEMM share) covers the heal-retry if one ran
        let forward_us = forward_start.elapsed().as_micros() as u64;
        let gemm_us = gemm_scope.take_us();
        let form_us = form_time.as_micros() as u64;
        match result {
            Ok((guard, logits)) => {
                guard.record_batch();
                let served = guard.pipeline().variant.clone();
                ctx.flight.span(&ctx.model_id, &task, "dispatch", forward_us,
                                rows as u64, format!("rung `{served}`"));
                for (row, reply) in replies.into_iter().enumerate() {
                    let decode_start = Instant::now();
                    let out = guard.pipeline().decode_row(&logits, &block,
                                                          row);
                    let timings = RowTimings {
                        tokenize_us: 0, // the server fills this in
                        queue_us: waits
                            .get(row)
                            .map_or(0, |w| w.as_micros() as u64),
                        form_us,
                        forward_us,
                        gemm_us,
                        decode_us: decode_start.elapsed().as_micros() as u64,
                    };
                    ctx.stats.stages.record(&timings);
                    let _ = reply.send(Ok(RowOutput {
                        output: out,
                        served_variant: served.clone(),
                        timings: Some(timings),
                    }));
                }
                ctx.flight.instant(&ctx.model_id, &task, "reply",
                                   rows as u64, format!("rung `{served}`"));
            }
            Err(e) => {
                ctx.counters.inc_errors();
                let msg = format!("inference failed: {e:#}");
                ctx.flight.instant(&ctx.model_id, &task, "reply",
                                   rows as u64, msg.clone());
                for reply in replies {
                    let _ = reply.send(Err(RowError::Failed(msg.clone())));
                }
            }
        }
        // hand the tensor block back for the next form()
        ctx.batcher.recycle(block);
    }

    /// Acquire the least-loaded replica and run one formed block on it.
    fn run_batch<'a>(replicas: &'a ReplicaSet, block: &EncoderBatch)
                     -> Result<(ReplicaGuard<'a>, Vec<f32>)> {
        let guard = replicas.acquire()?;
        let logits = guard.pipeline().run_block(block)?;
        Ok((guard, logits))
    }

    /// Warm every task lane off-path: start its shard set and run one
    /// synthetic 1-row batch through every engine replica, so packed
    /// weights, scratch pools and block pools exist before the generation
    /// takes live traffic.
    pub fn warm(&self) -> Result<()> {
        for task in self.router.tasks() {
            let lane = self
                .lane(&task)?
                .context("deployment is draining during warm")?;
            for i in 0..lane.replicas.len() {
                let pipe = lane.replicas.pipeline_at(i);
                let enc = pipe.encode_text("warmup");
                // the spec's full [batch, seq] shape, so PJRT engines (static
                // shape) warm exactly like native ones
                let mut block = EncoderBatch::zeros(pipe.spec.batch.max(1),
                                                    pipe.spec.seq_len);
                block.set_row(0, &enc.ids, &enc.segment_ids,
                              &enc.attention_mask);
                let logits = pipe.run_block(&block).with_context(|| {
                    format!("warming {task} replica {i}")
                })?;
                let _ = pipe.decode_row(&logits, &block, 0);
            }
        }
        Ok(())
    }

    /// Stop accepting work: every lane's batcher closes, new `lane()` calls
    /// return `None`.  Queued rows still dispatch (the batcher drains
    /// residual requests after close), so in-flight work finishes on this
    /// generation's own engines.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
        let lanes = self.lanes.write().unwrap();
        for lane in lanes.values() {
            lane.batcher.close();
        }
    }

    /// Join every lane's dispatcher workers (call after [`begin_drain`];
    /// returns once the queues are drained and the threads exited).
    ///
    /// [`begin_drain`]: Deployment::begin_drain
    pub fn join_workers(&self) {
        let lanes: Vec<Arc<TaskLane>> =
            self.lanes.read().unwrap().values().cloned().collect();
        for lane in &lanes {
            lane.join_workers();
        }
    }

    /// Synchronous drain + join: the abort path for a generation that was
    /// built but will never serve (failed activation/warm, lost an insert
    /// race, or raced a shutdown).
    fn retire_now(&self) {
        self.begin_drain();
        self.join_workers();
    }

    /// Activate `variant` on every task, retiring this generation on the
    /// first failure (it never served, so the drain is instant).
    fn activate_all(&self, variant: &str) -> Result<()> {
        for task in self.router.tasks() {
            if let Err(e) = self.router.activate(&task, variant) {
                self.retire_now();
                return Err(e).with_context(|| format!(
                    "activating variant `{variant}` for {task}"));
            }
        }
        Ok(())
    }
}

/// One registered model: its artifacts directory and the atomic pointer to
/// the current deployment generation.
pub struct ModelEntry {
    pub id: String,
    pub artifacts_dir: PathBuf,
    generation: AtomicU64,
    current: RwLock<Arc<Deployment>>,
    reload_lock: Mutex<()>,
}

impl ModelEntry {
    /// The generation currently serving this model (the request path's
    /// resolve: one read lock + one Arc clone).
    pub fn current(&self) -> Arc<Deployment> {
        self.current.read().unwrap().clone()
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }
}

/// The model-lifecycle owner: `model_id -> ModelEntry`, reload/drain
/// orchestration, and the registry-wide aggregate counters.
pub struct Registry {
    cfg: LaneConfig,
    counters: Arc<Counters>,
    /// Registry-lifetime steal coordination (see [`StealRouter`]); handed
    /// to every generation of every model so dispatcher workers can probe
    /// sibling lanes.
    steal: Arc<StealRouter>,
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    reloads: AtomicU64,
    retired: Arc<AtomicU64>,
    /// Reaper threads of generations still retiring in the background;
    /// `drain_all` joins them so shutdown never abandons a retiring
    /// generation mid-drain.
    reapers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    closed: AtomicBool,
    /// Heal-request fan-in: dispatcher workers that healed a poisoned
    /// replica in place send the model id here; a server-side healer thread
    /// takes the receiver ([`Registry::heal_requests`]) and answers each
    /// request with a full [`Registry::reload`] — generation retire + swap —
    /// so the process self-heals instead of dying.
    heal_tx: mpsc::Sender<String>,
    heal_rx: Mutex<Option<mpsc::Receiver<String>>>,
    /// Whether the signal-collector thread has been claimed
    /// ([`Registry::begin_collector`]; the server spawns exactly one).
    collector: AtomicBool,
}

impl Registry {
    pub fn new(cfg: LaneConfig, counters: Arc<Counters>) -> Registry {
        let (heal_tx, heal_rx) = mpsc::channel();
        let steal = StealRouter::new(cfg.steal);
        Registry {
            cfg,
            counters,
            steal,
            models: RwLock::new(BTreeMap::new()),
            reloads: AtomicU64::new(0),
            retired: Arc::new(AtomicU64::new(0)),
            reapers: Mutex::new(Vec::new()),
            closed: AtomicBool::new(false),
            heal_tx,
            heal_rx: Mutex::new(Some(heal_rx)),
            collector: AtomicBool::new(false),
        }
    }

    /// Claim the signal-collector role (first caller wins).  The collector
    /// thread samples every lane into the registry's [`SignalHub`] and runs
    /// the `--learn-weights` apportioner; see
    /// [`telemetry::hub::spawn_signal_collector`].
    pub fn begin_collector(&self) -> bool {
        !self.collector.swap(true, Ordering::SeqCst)
    }

    /// The registry's signal hub (the controllers' time-series store).
    pub fn signal_hub(&self) -> Arc<SignalHub> {
        self.cfg.hub.clone()
    }

    /// The registry's black-box flight recorder (`GET /v1/debug/trace`).
    pub fn flight_recorder(&self) -> Arc<FlightRecorder> {
        self.cfg.flight.clone()
    }

    /// Take the heal-request receiver (once).  The server spawns a healer
    /// thread around it that reloads each model a dispatcher worker healed
    /// in place, retiring the wounded generation for a cleanly rebuilt one.
    pub fn heal_requests(&self) -> Option<mpsc::Receiver<String>> {
        self.heal_rx.lock().unwrap().take()
    }

    pub fn counters(&self) -> Arc<Counters> {
        self.counters.clone()
    }

    pub fn lane_config(&self) -> &LaneConfig {
        &self.cfg
    }

    /// The registry's cross-lane steal coordinator (stats surfaces read
    /// its `(from, to)` pair counts).
    pub fn steal_router(&self) -> Arc<StealRouter> {
        self.steal.clone()
    }

    /// Register a model and build its generation-1 deployment from disk.
    pub fn load_model(&self, id: &str, artifacts_dir: &Path)
                      -> Result<Arc<Deployment>> {
        if self.models.read().unwrap().contains_key(id) {
            bail!("model `{id}` is already registered");
        }
        let dep = Deployment::build(id, 1, artifacts_dir, self.cfg.clone(),
                                    self.counters.clone())?;
        dep.set_heal_notifier(self.heal_tx.clone());
        dep.set_steal_router(self.steal.clone());
        if let Err(e) =
            self.insert_entry(id, artifacts_dir.to_path_buf(), dep.clone())
        {
            dep.retire_now();
            return Err(e);
        }
        Ok(dep)
    }

    /// Register an already-built router as a model's generation 1 (the
    /// `Server::new` compatibility path).  The entry's artifacts directory
    /// is the router's manifest root, so reload works the same way.
    pub fn install_router(&self, id: &str, router: Arc<Router>)
                          -> Result<Arc<Deployment>> {
        let dir = router.manifest.root.clone();
        let dep = Deployment::from_router(id, 1, router, self.cfg.clone(),
                                          self.counters.clone());
        dep.set_heal_notifier(self.heal_tx.clone());
        dep.set_steal_router(self.steal.clone());
        self.insert_entry(id, dir, dep.clone())?;
        Ok(dep)
    }

    /// Insert a fresh entry, re-checking the id under the write lock so two
    /// concurrent registrations of the same id cannot silently overwrite
    /// each other (the loser's deployment is the caller's to retire).
    fn insert_entry(&self, id: &str, artifacts_dir: PathBuf,
                    dep: Arc<Deployment>) -> Result<()> {
        let entry = Arc::new(ModelEntry {
            id: id.to_string(),
            artifacts_dir,
            generation: AtomicU64::new(dep.generation),
            current: RwLock::new(dep),
            reload_lock: Mutex::new(()),
        });
        let mut models = self.models.write().unwrap();
        if models.contains_key(id) {
            bail!("model `{id}` is already registered");
        }
        self.steal.register(id, Arc::downgrade(&entry));
        models.insert(id.to_string(), entry);
        Ok(())
    }

    /// Registered models, sorted by id.
    pub fn entries(&self) -> Vec<Arc<ModelEntry>> {
        self.models.read().unwrap().values().cloned().collect()
    }

    pub fn entry(&self, id: &str) -> Option<Arc<ModelEntry>> {
        self.models.read().unwrap().get(id).cloned()
    }

    pub fn model_count(&self) -> usize {
        self.models.read().unwrap().len()
    }

    /// Resolve a request's model address: an explicit id, the only model
    /// when exactly one is registered, or `default`.
    pub fn resolve_entry(&self, model: Option<&str>)
                         -> Result<Arc<ModelEntry>> {
        let models = self.models.read().unwrap();
        match model {
            Some(id) => models
                .get(id)
                .cloned()
                .with_context(|| format!("unknown model `{id}`")),
            None => {
                if models.len() == 1 {
                    return Ok(models.values().next().unwrap().clone());
                }
                models.get("default").cloned().with_context(|| {
                    format!("no `model` given and no `default` among {} \
                             registered models", models.len())
                })
            }
        }
    }

    /// The deployment currently serving `model` (see
    /// [`Registry::resolve_entry`]).
    pub fn resolve(&self, model: Option<&str>) -> Result<Arc<Deployment>> {
        Ok(self.resolve_entry(model)?.current())
    }

    /// Zero-downtime reload: build generation N+1 off-path from the entry's
    /// artifacts directory, optionally activate `variant` on every task,
    /// warm it, swap it in, then drain + retire the old generation in the
    /// background.  On any failure — including a warm failure, which the
    /// boot path merely logs — the old generation keeps serving and the
    /// error is returned: a generation that cannot run one synthetic batch
    /// is never swapped in front of one that is at least accepting traffic.
    pub fn reload(&self, id: &str, variant: Option<&str>)
                  -> Result<Arc<Deployment>> {
        if self.closed.load(Ordering::SeqCst) {
            bail!("registry is shutting down");
        }
        let entry = self
            .entry(id)
            .with_context(|| format!("unknown model `{id}`"))?;
        // serializes reloads of one model AND excludes drain_all (which
        // takes the same lock), so a reload can never swap live lanes in
        // behind a completed shutdown's back
        let _serialize = entry.reload_lock.lock().unwrap();
        let generation = entry.generation.load(Ordering::SeqCst) + 1;
        let dep = Deployment::build(&entry.id, generation,
                                    &entry.artifacts_dir, self.cfg.clone(),
                                    self.counters.clone())?;
        dep.set_heal_notifier(self.heal_tx.clone());
        dep.set_steal_router(self.steal.clone());
        if let Some(v) = variant {
            dep.activate_all(v)?;
        }
        if let Err(e) = dep.warm() {
            dep.retire_now();
            return Err(e);
        }
        if self.closed.load(Ordering::SeqCst) {
            // a drain_all raced the build (it blocks on reload_lock, so it
            // has not drained this entry yet — but it will, and only the
            // generation it can see)
            dep.retire_now();
            bail!("registry is shutting down");
        }
        // the swap: new generation becomes visible *before* the old one
        // refuses work, so a request that hits a closed old queue re-resolves
        // straight onto this one — zero requests fail across the reload
        let old = {
            let mut cur = entry.current.write().unwrap();
            std::mem::replace(&mut *cur, dep.clone())
        };
        entry.generation.store(generation, Ordering::SeqCst);
        self.reloads.fetch_add(1, Ordering::SeqCst);
        old.begin_drain();
        let retired = self.retired.clone();
        let reaper = std::thread::spawn(move || {
            // in-flight rows finish on their original engines; once the
            // queues drain the workers exit and the generation retires.
            // Foreign workers may still be running batches they stole off
            // this generation's queues — wait those out too (they were
            // pre-counted before the thief probed the queue, so no stolen
            // batch can slip past this check), or their rows would be
            // dropped with the generation.
            old.join_workers();
            old.await_stolen();
            retired.fetch_add(1, Ordering::SeqCst);
        });
        {
            // prune finished reapers so a long-lived --watch-manifest server
            // doesn't grow the list once per reload forever
            let mut reapers = self.reapers.lock().unwrap();
            reapers.retain(|r| !r.is_finished());
            reapers.push(reaper);
        }
        Ok(dep)
    }

    /// Graceful shutdown: every model's current generation drains through
    /// the same close -> finish-in-flight -> join path a retiring generation
    /// takes, and every still-retiring old generation is waited for — no
    /// batch is abandoned mid-drain.  Idempotent.
    pub fn drain_all(&self) {
        self.closed.store(true, Ordering::SeqCst);
        for entry in self.entries() {
            // excludes an in-flight reload of this entry: either its swap
            // completed (we drain the new generation) or its closed re-check
            // fires (it retires the never-installed generation itself)
            let _serialize = entry.reload_lock.lock().unwrap();
            let dep = entry.current();
            dep.begin_drain();
            dep.join_workers();
            dep.await_stolen();
        }
        // wait out generations still retiring from recent reloads
        let reapers: Vec<_> = {
            let mut r = self.reapers.lock().unwrap();
            r.drain(..).collect()
        };
        for r in reapers {
            let _ = r.join();
        }
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Successful reloads since construction.
    pub fn reload_count(&self) -> u64 {
        self.reloads.load(Ordering::SeqCst)
    }

    /// Old generations fully drained and joined since construction.
    pub fn retired_count(&self) -> u64 {
        self.retired.load(Ordering::SeqCst)
    }
}
