//! Model registry: lifecycle owner for every loaded model.
//!
//! The server process used to load exactly one `manifest.json` at boot and
//! could never change it without a restart.  The registry closes that gap by
//! making every loaded model an immutable **deployment generation**:
//!
//! ```text
//!   Registry ─ model_id ─> ModelEntry ─ atomic swap ─> Arc<Deployment>
//!                                                        ├ Runtime (own native caches)
//!                                                        ├ Router  (manifest + pipelines)
//!                                                        └ lanes: task -> TaskLane
//!                                                            ├ Batcher (shared queue)
//!                                                            ├ ReplicaSet (N engines)
//!                                                            └ dispatcher shard set
//! ```
//!
//! * **Load** — [`Registry::load_model`] builds generation 1 of a model from
//!   an artifacts directory (`--artifacts id=dir` makes this repeatable).
//! * **Reload** — [`Registry::reload`] builds the *next* generation entirely
//!   off-path (own `Runtime`, so native weights/packs are fresh and the old
//!   generation's memory dies with it), warms it (one synthetic batch per
//!   task per replica), atomically swaps it in, and only then drains the old
//!   generation: its batchers close, in-flight rows finish on their original
//!   engines (the batcher drains residual rows after `close()`), and the
//!   generation retires once nothing holds its `Arc` any more.  A request
//!   that raced the swap and hit a closed queue gets a typed `Closed`
//!   rejection and retries against the freshly-swapped generation — the
//!   pointer swap happens *before* the old lanes close, so zero requests
//!   fail across a reload.
//! * **Retire** — a reaper thread joins the drained generation's dispatcher
//!   workers and counts the retirement; block pools, packed weights and
//!   engines are freed when the last `Arc<Deployment>` drops.
//! * **Drain** — [`Registry::drain_all`] routes graceful shutdown
//!   (SIGTERM / ctrl-c) through the same path: close, drain, join — no
//!   batch is aborted mid-flight.
//!
//! Aggregate [`Counters`] are registry-wide and outlive every generation, so
//! shed/pool totals stay monotonic across reloads (the PR #4 invariant,
//! extended).

pub mod replica;

pub use replica::{ReplicaGuard, ReplicaSet};

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::{Manifest, ServerConfig};
use crate::coordinator::batcher::Batcher;
use crate::coordinator::{Router, TaskOutput};
use crate::metrics::{Counters, Histogram};
use crate::runtime::{EncoderBatch, KernelConfig, Runtime};

/// Reply handle of one enqueued row (the submitting thread blocks on the
/// receiving end).
pub type Reply = mpsc::Sender<Result<TaskOutput, String>>;

/// Per-generation lane tuning, distilled from [`ServerConfig`]: the registry
/// applies the same knobs to every generation it builds.
#[derive(Debug, Clone)]
pub struct LaneConfig {
    pub batch_timeout_ms: u64,
    /// Dispatcher workers per lane (resolved, >= 1).
    pub workers_per_lane: usize,
    /// Engine replicas per lane (>= 1; see [`ReplicaSet`]).
    pub replicas_per_lane: usize,
    pub max_queue_depth: usize,
    /// Variant to activate on every task of every new generation (reload
    /// keeps serving the variant policy the process was started with unless
    /// the reload request names one explicitly).
    pub default_variant: Option<String>,
    /// Threads one native GEMM is split across (resolved, >= 1).
    pub gemm_threads: usize,
    /// `--pin-cores` core sets: replica `r` pins its GEMM pool to set
    /// `r % len`, dispatcher workers round-robin the flattened union.
    pub pin_cores: Vec<Vec<usize>>,
}

impl LaneConfig {
    pub fn from_server(cfg: &ServerConfig) -> LaneConfig {
        LaneConfig {
            batch_timeout_ms: cfg.batch_timeout_ms,
            workers_per_lane: cfg.resolved_workers_per_lane().max(1),
            replicas_per_lane: cfg.replicas_per_lane.max(1),
            max_queue_depth: cfg.max_queue_depth.max(1),
            default_variant: cfg.default_variant.clone(),
            gemm_threads: cfg.resolved_gemm_threads().max(1),
            pin_cores: cfg.pin_cores.clone(),
        }
    }

    /// The dispatcher-pin set: every configured core, flattened in order.
    /// Worker `w` of a lane pins to `flat[w % len]` (empty = unpinned).
    fn flat_cores(&self) -> Vec<usize> {
        self.pin_cores.iter().flatten().copied().collect()
    }
}

/// Per-lane observability: what each dispatcher worker of the shard set did,
/// plus the lane's own request-latency histogram.
pub struct LaneStats {
    task: String,
    continuous: bool,
    pub worker_batches: Vec<AtomicU64>,
    pub worker_rows: Vec<AtomicU64>,
    /// Core each dispatcher worker observed itself pinned to (`-1` = not
    /// pinned: no `--pin-cores`, or `sched_setaffinity` failed/unavailable).
    pub worker_pinned: Vec<AtomicI64>,
    pub latency: Histogram,
}

impl LaneStats {
    fn new(task: &str, continuous: bool, workers: usize) -> LaneStats {
        LaneStats {
            task: task.to_string(),
            continuous,
            worker_batches: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            worker_rows: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            worker_pinned: (0..workers).map(|_| AtomicI64::new(-1)).collect(),
            latency: Histogram::new(),
        }
    }

    pub fn task(&self) -> &str {
        &self.task
    }

    pub fn continuous(&self) -> bool {
        self.continuous
    }

    pub fn workers(&self) -> usize {
        self.worker_batches.len()
    }

    pub fn batches(&self) -> u64 {
        self.worker_batches
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum()
    }

    pub fn rows(&self) -> u64 {
        self.worker_rows.iter().map(|r| r.load(Ordering::Relaxed)).sum()
    }

    pub fn batch_fill(&self) -> f64 {
        let b = self.batches();
        if b == 0 {
            return 0.0;
        }
        self.rows() as f64 / b as f64
    }
}

/// One task's serving lane inside a deployment: the admission-controlled
/// batcher queue, the engine replica set, and the dispatcher shard set
/// draining the queue.
pub struct TaskLane {
    pub batcher: Arc<Batcher<Reply>>,
    pub replicas: Arc<ReplicaSet>,
    pub stats: Arc<LaneStats>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl TaskLane {
    /// Join the lane's dispatcher workers (idempotent; callers close the
    /// batcher first or this blocks forever).
    fn join_workers(&self) {
        let handles: Vec<_> = {
            let mut w = self.workers.lock().unwrap();
            w.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

/// One immutable generation of one model: manifest + router + lanes +
/// replica sets.  Built off-path, warmed, swapped in atomically, and drained
/// (never mutated) when the next generation replaces it.
pub struct Deployment {
    pub model_id: String,
    pub generation: u64,
    pub router: Arc<Router>,
    cfg: LaneConfig,
    counters: Arc<Counters>,
    lanes: RwLock<HashMap<String, Arc<TaskLane>>>,
    draining: AtomicBool,
}

impl Deployment {
    /// Build a fresh generation from on-disk artifacts: its own [`Runtime`]
    /// (native weight caches die with the generation), its own [`Router`],
    /// lanes started lazily (or eagerly by [`Deployment::warm`]).
    pub fn build(model_id: &str, generation: u64, artifacts_dir: &Path,
                 cfg: LaneConfig, counters: Arc<Counters>)
                 -> Result<Arc<Deployment>> {
        let manifest = Manifest::load(artifacts_dir).with_context(|| {
            format!("loading model `{model_id}` from {}",
                    artifacts_dir.display())
        })?;
        let runtime = Arc::new(Runtime::cpu()?);
        let router = Arc::new(Router::new(runtime, manifest)?);
        let dep = Self::from_router(model_id, generation, router, cfg,
                                    counters);
        if let Some(v) = dep.cfg.default_variant.clone() {
            dep.activate_all(&v)?;
        }
        Ok(dep)
    }

    /// Wrap an already-built router as a generation (the single-model
    /// compatibility path `Server::new` uses; no default-variant application,
    /// the caller controls the router's active pipelines).
    pub fn from_router(model_id: &str, generation: u64, router: Arc<Router>,
                       cfg: LaneConfig, counters: Arc<Counters>)
                       -> Arc<Deployment> {
        // install the kernel policy before any lane builds replica
        // pipelines, so every native model this generation caches is born
        // with its GEMM pool and core set
        router.runtime.set_kernel_config(KernelConfig {
            gemm_threads: cfg.gemm_threads.max(1),
            pin_cores: cfg.pin_cores.clone(),
        });
        Arc::new(Deployment {
            model_id: model_id.to_string(),
            generation,
            router,
            cfg,
            counters,
            lanes: RwLock::new(HashMap::new()),
            draining: AtomicBool::new(false),
        })
    }

    pub fn tasks(&self) -> Vec<String> {
        self.router.tasks()
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Live lanes, sorted by task (stats surfaces).
    pub fn lanes_snapshot(&self) -> Vec<Arc<TaskLane>> {
        let lanes = self.lanes.read().unwrap();
        let mut v: Vec<Arc<TaskLane>> = lanes.values().cloned().collect();
        v.sort_by(|a, b| a.stats.task().cmp(b.stats.task()));
        v
    }

    /// Get or start the lane for `task`.  `Ok(None)` means this generation
    /// is draining — callers re-resolve the current generation and retry
    /// (the swap happens before the drain, so a fresh resolve sees the new
    /// one).  Steady state takes a read lock only; creation double-checks
    /// the draining flag under the write lock, so `begin_drain` can never
    /// miss a lane.
    pub fn lane(&self, task: &str) -> Result<Option<Arc<TaskLane>>> {
        if self.is_draining() {
            return Ok(None);
        }
        if let Some(l) = self.lanes.read().unwrap().get(task) {
            return Ok(Some(l.clone()));
        }
        let pipe = self.router.pipeline(task)?; // may compile; outside locks
        let replicas = Arc::new(ReplicaSet::build(
            self.router.clone(), task, self.cfg.replicas_per_lane)?);
        let mut lanes = self.lanes.write().unwrap();
        if self.is_draining() {
            // begin_drain closes the lanes it can see under this lock; a
            // lane inserted after the flag flips would never be closed
            return Ok(None);
        }
        if let Some(l) = lanes.get(task) {
            return Ok(Some(l.clone()));
        }
        // Continuous (token-budget, variable-shape) forming needs a backend
        // without a static-shape constraint; PJRT lanes keep fixed forming.
        let continuous = pipe.backend_name() == "native";
        let timeout = Duration::from_millis(self.cfg.batch_timeout_ms);
        let depth = self.cfg.max_queue_depth.max(1);
        let batcher = if continuous {
            Batcher::<Reply>::continuous(
                pipe.spec.batch,
                pipe.spec.seq_len,
                timeout,
                depth,
                Batcher::<Reply>::default_granularity(pipe.spec.seq_len),
            )
        } else {
            Batcher::<Reply>::with_queue_depth(
                pipe.spec.batch, pipe.spec.seq_len, timeout, depth)
        };
        let batcher = Arc::new(batcher.with_counters(self.counters.clone()));
        let n_workers = self.cfg.workers_per_lane.max(1);
        let stats = Arc::new(LaneStats::new(task, continuous, n_workers));
        let pin_set = self.cfg.flat_cores();
        let workers = (0..n_workers)
            .map(|w| {
                let counters = self.counters.clone();
                let b2 = batcher.clone();
                let stats = stats.clone();
                let replicas = replicas.clone();
                let core = (!pin_set.is_empty())
                    .then(|| pin_set[w % pin_set.len()]);
                std::thread::spawn(move || {
                    // best-effort: the worker serves unpinned (and the stats
                    // slot stays -1) when sched_setaffinity is unavailable
                    if let Some(c) = core.and_then(crate::util::affinity::try_pin)
                    {
                        stats.worker_pinned[w].store(c as i64,
                                                     Ordering::Relaxed);
                    }
                    Self::dispatch_loop(&b2, &replicas, &counters, &stats, w)
                })
            })
            .collect();
        let lane = Arc::new(TaskLane {
            batcher,
            replicas,
            stats,
            workers: Mutex::new(workers),
        });
        lanes.insert(task.to_string(), lane.clone());
        Ok(Some(lane))
    }

    /// One dispatcher worker of a lane's shard set: drain batches from the
    /// shared queue, run the least-loaded engine replica, then complete rows
    /// individually — each reply fires the moment its own row is decoded.
    fn dispatch_loop(batcher: &Batcher<Reply>, replicas: &ReplicaSet,
                     counters: &Counters, stats: &LaneStats, worker: usize) {
        while let Some(fb) = batcher.next_batch() {
            counters.inc_batches(fb.rows as u64);
            stats.worker_batches[worker].fetch_add(1, Ordering::Relaxed);
            stats.worker_rows[worker].fetch_add(fb.rows as u64,
                                                Ordering::Relaxed);
            let crate::coordinator::FormedBatch { block, replies, .. } = fb;
            // least-loaded replica, re-resolved per batch (one read lock) so
            // Router::activate switches a live lane to the new variant
            let result = replicas.acquire().and_then(|guard| {
                let logits = guard.pipeline().run_block(&block)?;
                Ok((guard, logits))
            });
            match result {
                Ok((guard, logits)) => {
                    guard.record_batch();
                    for (row, reply) in replies.into_iter().enumerate() {
                        let out = guard.pipeline().decode_row(&logits, &block,
                                                              row);
                        let _ = reply.send(Ok(out));
                    }
                }
                Err(e) => {
                    counters.inc_errors();
                    let msg = format!("inference failed: {e:#}");
                    for reply in replies {
                        let _ = reply.send(Err(msg.clone()));
                    }
                }
            }
            // hand the tensor block back for the next form()
            batcher.recycle(block);
        }
    }

    /// Warm every task lane off-path: start its shard set and run one
    /// synthetic 1-row batch through every engine replica, so packed
    /// weights, scratch pools and block pools exist before the generation
    /// takes live traffic.
    pub fn warm(&self) -> Result<()> {
        for task in self.router.tasks() {
            let lane = self
                .lane(&task)?
                .context("deployment is draining during warm")?;
            for i in 0..lane.replicas.len() {
                let pipe = lane.replicas.pipeline_at(i);
                let enc = pipe.encode_text("warmup");
                // the spec's full [batch, seq] shape, so PJRT engines (static
                // shape) warm exactly like native ones
                let mut block = EncoderBatch::zeros(pipe.spec.batch.max(1),
                                                    pipe.spec.seq_len);
                block.set_row(0, &enc.ids, &enc.segment_ids,
                              &enc.attention_mask);
                let logits = pipe.run_block(&block).with_context(|| {
                    format!("warming {task} replica {i}")
                })?;
                let _ = pipe.decode_row(&logits, &block, 0);
            }
        }
        Ok(())
    }

    /// Stop accepting work: every lane's batcher closes, new `lane()` calls
    /// return `None`.  Queued rows still dispatch (the batcher drains
    /// residual requests after close), so in-flight work finishes on this
    /// generation's own engines.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
        let lanes = self.lanes.write().unwrap();
        for lane in lanes.values() {
            lane.batcher.close();
        }
    }

    /// Join every lane's dispatcher workers (call after [`begin_drain`];
    /// returns once the queues are drained and the threads exited).
    ///
    /// [`begin_drain`]: Deployment::begin_drain
    pub fn join_workers(&self) {
        let lanes: Vec<Arc<TaskLane>> =
            self.lanes.read().unwrap().values().cloned().collect();
        for lane in &lanes {
            lane.join_workers();
        }
    }

    /// Synchronous drain + join: the abort path for a generation that was
    /// built but will never serve (failed activation/warm, lost an insert
    /// race, or raced a shutdown).
    fn retire_now(&self) {
        self.begin_drain();
        self.join_workers();
    }

    /// Activate `variant` on every task, retiring this generation on the
    /// first failure (it never served, so the drain is instant).
    fn activate_all(&self, variant: &str) -> Result<()> {
        for task in self.router.tasks() {
            if let Err(e) = self.router.activate(&task, variant) {
                self.retire_now();
                return Err(e).with_context(|| format!(
                    "activating variant `{variant}` for {task}"));
            }
        }
        Ok(())
    }
}

/// One registered model: its artifacts directory and the atomic pointer to
/// the current deployment generation.
pub struct ModelEntry {
    pub id: String,
    pub artifacts_dir: PathBuf,
    generation: AtomicU64,
    current: RwLock<Arc<Deployment>>,
    reload_lock: Mutex<()>,
}

impl ModelEntry {
    /// The generation currently serving this model (the request path's
    /// resolve: one read lock + one Arc clone).
    pub fn current(&self) -> Arc<Deployment> {
        self.current.read().unwrap().clone()
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }
}

/// The model-lifecycle owner: `model_id -> ModelEntry`, reload/drain
/// orchestration, and the registry-wide aggregate counters.
pub struct Registry {
    cfg: LaneConfig,
    counters: Arc<Counters>,
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    reloads: AtomicU64,
    retired: Arc<AtomicU64>,
    /// Reaper threads of generations still retiring in the background;
    /// `drain_all` joins them so shutdown never abandons a retiring
    /// generation mid-drain.
    reapers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    closed: AtomicBool,
}

impl Registry {
    pub fn new(cfg: LaneConfig, counters: Arc<Counters>) -> Registry {
        Registry {
            cfg,
            counters,
            models: RwLock::new(BTreeMap::new()),
            reloads: AtomicU64::new(0),
            retired: Arc::new(AtomicU64::new(0)),
            reapers: Mutex::new(Vec::new()),
            closed: AtomicBool::new(false),
        }
    }

    pub fn counters(&self) -> Arc<Counters> {
        self.counters.clone()
    }

    pub fn lane_config(&self) -> &LaneConfig {
        &self.cfg
    }

    /// Register a model and build its generation-1 deployment from disk.
    pub fn load_model(&self, id: &str, artifacts_dir: &Path)
                      -> Result<Arc<Deployment>> {
        if self.models.read().unwrap().contains_key(id) {
            bail!("model `{id}` is already registered");
        }
        let dep = Deployment::build(id, 1, artifacts_dir, self.cfg.clone(),
                                    self.counters.clone())?;
        if let Err(e) =
            self.insert_entry(id, artifacts_dir.to_path_buf(), dep.clone())
        {
            dep.retire_now();
            return Err(e);
        }
        Ok(dep)
    }

    /// Register an already-built router as a model's generation 1 (the
    /// `Server::new` compatibility path).  The entry's artifacts directory
    /// is the router's manifest root, so reload works the same way.
    pub fn install_router(&self, id: &str, router: Arc<Router>)
                          -> Result<Arc<Deployment>> {
        let dir = router.manifest.root.clone();
        let dep = Deployment::from_router(id, 1, router, self.cfg.clone(),
                                          self.counters.clone());
        self.insert_entry(id, dir, dep.clone())?;
        Ok(dep)
    }

    /// Insert a fresh entry, re-checking the id under the write lock so two
    /// concurrent registrations of the same id cannot silently overwrite
    /// each other (the loser's deployment is the caller's to retire).
    fn insert_entry(&self, id: &str, artifacts_dir: PathBuf,
                    dep: Arc<Deployment>) -> Result<()> {
        let entry = Arc::new(ModelEntry {
            id: id.to_string(),
            artifacts_dir,
            generation: AtomicU64::new(dep.generation),
            current: RwLock::new(dep),
            reload_lock: Mutex::new(()),
        });
        let mut models = self.models.write().unwrap();
        if models.contains_key(id) {
            bail!("model `{id}` is already registered");
        }
        models.insert(id.to_string(), entry);
        Ok(())
    }

    /// Registered models, sorted by id.
    pub fn entries(&self) -> Vec<Arc<ModelEntry>> {
        self.models.read().unwrap().values().cloned().collect()
    }

    pub fn entry(&self, id: &str) -> Option<Arc<ModelEntry>> {
        self.models.read().unwrap().get(id).cloned()
    }

    pub fn model_count(&self) -> usize {
        self.models.read().unwrap().len()
    }

    /// Resolve a request's model address: an explicit id, the only model
    /// when exactly one is registered, or `default`.
    pub fn resolve_entry(&self, model: Option<&str>)
                         -> Result<Arc<ModelEntry>> {
        let models = self.models.read().unwrap();
        match model {
            Some(id) => models
                .get(id)
                .cloned()
                .with_context(|| format!("unknown model `{id}`")),
            None => {
                if models.len() == 1 {
                    return Ok(models.values().next().unwrap().clone());
                }
                models.get("default").cloned().with_context(|| {
                    format!("no `model` given and no `default` among {} \
                             registered models", models.len())
                })
            }
        }
    }

    /// The deployment currently serving `model` (see
    /// [`Registry::resolve_entry`]).
    pub fn resolve(&self, model: Option<&str>) -> Result<Arc<Deployment>> {
        Ok(self.resolve_entry(model)?.current())
    }

    /// Zero-downtime reload: build generation N+1 off-path from the entry's
    /// artifacts directory, optionally activate `variant` on every task,
    /// warm it, swap it in, then drain + retire the old generation in the
    /// background.  On any failure — including a warm failure, which the
    /// boot path merely logs — the old generation keeps serving and the
    /// error is returned: a generation that cannot run one synthetic batch
    /// is never swapped in front of one that is at least accepting traffic.
    pub fn reload(&self, id: &str, variant: Option<&str>)
                  -> Result<Arc<Deployment>> {
        if self.closed.load(Ordering::SeqCst) {
            bail!("registry is shutting down");
        }
        let entry = self
            .entry(id)
            .with_context(|| format!("unknown model `{id}`"))?;
        // serializes reloads of one model AND excludes drain_all (which
        // takes the same lock), so a reload can never swap live lanes in
        // behind a completed shutdown's back
        let _serialize = entry.reload_lock.lock().unwrap();
        let generation = entry.generation.load(Ordering::SeqCst) + 1;
        let dep = Deployment::build(&entry.id, generation,
                                    &entry.artifacts_dir, self.cfg.clone(),
                                    self.counters.clone())?;
        if let Some(v) = variant {
            dep.activate_all(v)?;
        }
        if let Err(e) = dep.warm() {
            dep.retire_now();
            return Err(e);
        }
        if self.closed.load(Ordering::SeqCst) {
            // a drain_all raced the build (it blocks on reload_lock, so it
            // has not drained this entry yet — but it will, and only the
            // generation it can see)
            dep.retire_now();
            bail!("registry is shutting down");
        }
        // the swap: new generation becomes visible *before* the old one
        // refuses work, so a request that hits a closed old queue re-resolves
        // straight onto this one — zero requests fail across the reload
        let old = {
            let mut cur = entry.current.write().unwrap();
            std::mem::replace(&mut *cur, dep.clone())
        };
        entry.generation.store(generation, Ordering::SeqCst);
        self.reloads.fetch_add(1, Ordering::SeqCst);
        old.begin_drain();
        let retired = self.retired.clone();
        let reaper = std::thread::spawn(move || {
            // in-flight rows finish on their original engines; once the
            // queues drain the workers exit and the generation retires
            old.join_workers();
            retired.fetch_add(1, Ordering::SeqCst);
        });
        {
            // prune finished reapers so a long-lived --watch-manifest server
            // doesn't grow the list once per reload forever
            let mut reapers = self.reapers.lock().unwrap();
            reapers.retain(|r| !r.is_finished());
            reapers.push(reaper);
        }
        Ok(dep)
    }

    /// Graceful shutdown: every model's current generation drains through
    /// the same close -> finish-in-flight -> join path a retiring generation
    /// takes, and every still-retiring old generation is waited for — no
    /// batch is abandoned mid-drain.  Idempotent.
    pub fn drain_all(&self) {
        self.closed.store(true, Ordering::SeqCst);
        for entry in self.entries() {
            // excludes an in-flight reload of this entry: either its swap
            // completed (we drain the new generation) or its closed re-check
            // fires (it retires the never-installed generation itself)
            let _serialize = entry.reload_lock.lock().unwrap();
            let dep = entry.current();
            dep.begin_drain();
            dep.join_workers();
        }
        // wait out generations still retiring from recent reloads
        let reapers: Vec<_> = {
            let mut r = self.reapers.lock().unwrap();
            r.drain(..).collect()
        };
        for r in reapers {
            let _ = r.join();
        }
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Successful reloads since construction.
    pub fn reload_count(&self) -> u64 {
        self.reloads.load(Ordering::SeqCst)
    }

    /// Old generations fully drained and joined since construction.
    pub fn retired_count(&self) -> u64 {
        self.retired.load(Ordering::SeqCst)
    }
}
