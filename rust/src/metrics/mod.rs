//! Serving + evaluation metrics: latency percentiles, throughput counters,
//! task accuracy/F1.
//!
//! Two latency recorders with different tradeoffs:
//!
//! * [`LatencyRecorder`] — exact percentiles from stored samples; needs `&mut`
//!   (or a caller-side lock), fine for bounded offline runs.
//! * [`Histogram`] — lock-free log-scaled atomic buckets for the serving hot
//!   path: `record_us` is a couple of relaxed atomic adds, safe to call from
//!   every worker thread with zero contention; percentiles are approximate
//!   within one sub-bucket (≤ 12.5% relative error).

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-buckets per power-of-two octave (8 → ≤ 12.5% relative error).
const SUB_BITS: usize = 3;
const SUB: usize = 1 << SUB_BITS;
/// Octaves above the linear region: covers 1us .. ~2^40 us (~12.7 days).
const OCTAVES: usize = 40;
const BUCKETS: usize = (OCTAVES + 1) << SUB_BITS;

/// Lock-free latency histogram (HDR-style log-linear buckets).
#[derive(Debug)]
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

fn bucket_index(us: u64) -> usize {
    if us < SUB as u64 {
        return us as usize; // exact linear region
    }
    let l = 63 - us.leading_zeros() as usize; // floor(log2), >= SUB_BITS
    let frac = ((us >> (l - SUB_BITS)) as usize) - SUB;
    (((l - SUB_BITS + 1) << SUB_BITS) + frac).min(BUCKETS - 1)
}

/// Midpoint of the value range bucket `idx` covers (inverse of
/// `bucket_index`, up to sub-bucket resolution).
fn bucket_value(idx: usize) -> f64 {
    if idx < SUB {
        return idx as f64;
    }
    let l = (idx >> SUB_BITS) + SUB_BITS - 1;
    let frac = (idx & (SUB - 1)) as u64;
    let lo = (1u64 << l) + (frac << (l - SUB_BITS));
    let hi = lo + (1u64 << (l - SUB_BITS));
    (lo + hi) as f64 / 2.0
}

/// Inclusive upper bound (microseconds) of the value range bucket `idx`
/// covers; `None` for the final catch-all bucket (unbounded above).
fn bucket_upper(idx: usize) -> Option<u64> {
    if idx >= BUCKETS - 1 {
        return None;
    }
    if idx < SUB {
        return Some(idx as u64); // linear region: bucket holds exactly `idx`
    }
    let l = (idx >> SUB_BITS) + SUB_BITS - 1;
    let frac = (idx & (SUB - 1)) as u64;
    let lo = (1u64 << l) + (frac << (l - SUB_BITS));
    let hi = lo + (1u64 << (l - SUB_BITS));
    Some(hi - 1) // samples land in [lo, hi)
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one sample (microseconds).  Lock-free; relaxed ordering is
    /// enough because readers only need eventually-consistent aggregates.
    pub fn record_us(&self, us: f64) {
        let us = if us.is_finite() && us > 0.0 { us.round() as u64 } else { 0 };
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn len(&self) -> usize {
        self.count.load(Ordering::Relaxed) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate percentile, nearest-rank over buckets; `p` in [0, 100].
    /// p=100 returns the exact maximum (tracked separately).
    pub fn percentile_us(&self, p: f64) -> f64 {
        let n = self.count.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        if p >= 100.0 {
            return self.max_us.load(Ordering::Relaxed) as f64;
        }
        let rank = (((p / 100.0) * n as f64).ceil() as u64).max(1);
        let max = self.max_us.load(Ordering::Relaxed) as f64;
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                // bucket midpoint can overshoot the true extremum; keep the
                // summary monotone (p50 <= ... <= max)
                return bucket_value(idx).min(max);
            }
        }
        max
    }

    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.len(),
            mean_us: self.mean_us(),
            p50_us: self.percentile_us(50.0),
            p95_us: self.percentile_us(95.0),
            p99_us: self.percentile_us(99.0),
            max_us: self.percentile_us(100.0),
        }
    }

    /// Running sum of every recorded sample, in microseconds.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Cumulative view of the *occupied* native buckets, as
    /// `(inclusive upper bound in us, cumulative count)` pairs with strictly
    /// increasing bounds — exactly the shape a Prometheus `le`-bucketed
    /// histogram exposition needs.  Samples that fell into the final
    /// catch-all bucket are not listed (the `+Inf` bucket, i.e. [`len`],
    /// still covers them).
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            cum += n;
            if let Some(upper) = bucket_upper(idx) {
                out.push((upper, cum));
            }
        }
        out
    }
}

/// Latency recorder with exact percentiles (stores samples; serving runs here
/// are bounded, so exactness beats HDR approximation).
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    /// Exact percentile (classic nearest-rank: ceil(p/100 * n)). `p` in
    /// [0, 100]; p=0 returns the minimum.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_us.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        let n = v.len() as f64;
        let rank = ((p / 100.0) * n).ceil() as usize;
        v[rank.saturating_sub(1).min(v.len() - 1)]
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.len(),
            mean_us: self.mean_us(),
            p50_us: self.percentile_us(50.0),
            p95_us: self.percentile_us(95.0),
            p99_us: self.percentile_us(99.0),
            max_us: self.percentile_us(100.0),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl std::fmt::Display for LatencySummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f,
               "n={} mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us max={:.1}us",
               self.count, self.mean_us, self.p50_us, self.p95_us, self.p99_us,
               self.max_us)
    }
}

/// Rolling latency window: the last `capacity` samples in a lock-free ring
/// of atomic slots.  Unlike [`Histogram`] (monotonic since construction),
/// percentiles here reflect only *recent* traffic, which is what a feedback
/// controller needs — old samples age out as new ones overwrite their slot.
///
/// Writers race benignly: a slot may briefly hold a sample that is about to
/// be overwritten, and percentile reads are eventually consistent.  That is
/// fine for control decisions taken every few ticks.
#[derive(Debug)]
pub struct RollingWindow {
    slots: Box<[AtomicU64]>,
    /// total samples ever written (slot = next % capacity)
    next: AtomicU64,
}

impl RollingWindow {
    /// Default window size: enough for a p99 to be meaningful, small enough
    /// that a burst ages out within a few hundred requests.
    pub const DEFAULT_CAPACITY: usize = 512;

    pub fn new(capacity: usize) -> RollingWindow {
        let capacity = capacity.max(1);
        RollingWindow {
            slots: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            next: AtomicU64::new(0),
        }
    }

    /// Record one sample (microseconds).
    pub fn record_us(&self, us: f64) {
        let us = if us.is_finite() && us > 0.0 { us.round() as u64 } else { 0 };
        let i = self.next.fetch_add(1, Ordering::Relaxed) as usize;
        self.slots[i % self.slots.len()].store(us, Ordering::Relaxed);
    }

    /// Samples currently in the window.
    pub fn len(&self) -> usize {
        (self.next.load(Ordering::Relaxed) as usize).min(self.slots.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// [`RollingWindow::percentile_us`] that distinguishes "no sample yet"
    /// from "p-th percentile is 0us": dashboards rendering the raw 0 of an
    /// idle lane show a misleading flatline, so exposition paths omit the
    /// sample (Prometheus) or emit `null` (JSON) instead.
    pub fn percentile_opt_us(&self, p: f64) -> Option<f64> {
        if self.is_empty() {
            None
        } else {
            Some(self.percentile_us(p))
        }
    }

    /// Total samples ever recorded (monotone — the window itself only holds
    /// the last `capacity` of them).
    pub fn total(&self) -> u64 {
        self.next.load(Ordering::Relaxed)
    }

    /// Exact percentile over the current window (snapshot + sort; the window
    /// is small, so this is a few microseconds — fine off the hot path).
    /// `p` in [0, 100]; 0 with an empty window.
    pub fn percentile_us(&self, p: f64) -> f64 {
        let n = self.len();
        if n == 0 {
            return 0.0;
        }
        let mut v: Vec<u64> = self.slots[..n]
            .iter()
            .map(|s| s.load(Ordering::Relaxed))
            .collect();
        v.sort_unstable();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        v[rank.saturating_sub(1).min(n - 1)] as f64
    }
}

impl Default for RollingWindow {
    fn default() -> Self {
        RollingWindow::new(Self::DEFAULT_CAPACITY)
    }
}

/// Lock-free serving counters (shared across worker threads).
///
/// The shed / pool counters are *aggregate* server totals: lanes and their
/// batchers/pools report into this struct (as well as their own local
/// atomics), so the server-level numbers stay monotonic even if a lane is
/// ever torn down and rebuilt — per-lane counters die with the lane, these
/// do not.
#[derive(Debug, Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batch_rows: AtomicU64,
    pub errors: AtomicU64,
    /// Pushes rejected by admission control across every lane, ever.
    pub shed: AtomicU64,
    /// Block-pool checkouts served from a pooled block, across every lane.
    pub pool_hits: AtomicU64,
    /// Block-pool checkouts that had to allocate, across every lane.
    pub pool_misses: AtomicU64,
    /// Rows answered 504: their deadline expired before the forward pass.
    pub deadline_expired: AtomicU64,
    /// Swap-retry loops that exhausted every backoff attempt.
    pub swap_retry_exhausted: AtomicU64,
    /// Poisoned replicas rebuilt in place by the self-healing path.
    pub replicas_healed: AtomicU64,
    /// Precision-ladder variant switches (down- and up-shifts).
    pub ladder_shifts: AtomicU64,
    /// Batches a dispatcher worker stole from a sibling model's lane and
    /// ran on the victim's replicas (cross-lane work stealing).
    pub lane_steals: AtomicU64,
    /// End-to-end request latency as the submitting worker observes it.
    pub latency: Histogram,
    /// Recent-request latency for SLO feedback (ages out, unlike `latency`).
    pub recent_latency: RollingWindow,
}

impl Counters {
    pub fn inc_requests(&self, n: u64) {
        self.requests.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc_batches(&self, rows: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_rows.fetch_add(rows, Ordering::Relaxed);
    }

    pub fn inc_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_pool_hit(&self) {
        self.pool_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_pool_miss(&self) {
        self.pool_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_errors(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// N rows dropped before the forward pass because their deadline passed.
    pub fn inc_deadline_expired(&self, n: u64) {
        self.deadline_expired.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc_swap_retry_exhausted(&self) {
        self.swap_retry_exhausted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_replicas_healed(&self, n: u64) {
        self.replicas_healed.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc_ladder_shifts(&self) {
        self.ladder_shifts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_lane_steals(&self) {
        self.lane_steals.fetch_add(1, Ordering::Relaxed);
    }

    /// N requests failed at once (per-row error accounting for batch
    /// requests: `errors / requests` stays a meaningful failure rate).
    pub fn inc_errors_n(&self, n: u64) {
        self.errors.fetch_add(n, Ordering::Relaxed);
    }

    /// Mean rows per executed batch — batching efficiency.
    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_rows.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (self.requests.load(Ordering::Relaxed),
         self.batches.load(Ordering::Relaxed),
         self.batch_rows.load(Ordering::Relaxed),
         self.errors.load(Ordering::Relaxed))
    }
}

/// Classification accuracy.
pub fn accuracy(pred: &[usize], gold: &[i32]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hit = pred.iter().zip(gold).filter(|(p, g)| **p as i32 == **g).count();
    hit as f64 / pred.len() as f64
}

/// Token accuracy over masked positions (NER).
pub fn token_accuracy(pred: &[usize], gold: &[i32], mask: &[i32]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    assert_eq!(pred.len(), mask.len());
    let mut hit = 0usize;
    let mut tot = 0usize;
    for i in 0..pred.len() {
        if mask[i] != 0 {
            tot += 1;
            if pred[i] as i32 == gold[i] {
                hit += 1;
            }
        }
    }
    if tot == 0 {
        0.0
    } else {
        hit as f64 / tot as f64
    }
}

/// Span-level micro-F1 for BIO tagging (the CLUENER metric).
pub fn span_f1(pred_tags: &[Vec<usize>], gold_tags: &[Vec<i32>],
               labels: &[String]) -> f64 {
    let mut tp = 0usize;
    let mut n_pred = 0usize;
    let mut n_gold = 0usize;
    for (p, g) in pred_tags.iter().zip(gold_tags) {
        let ps = extract_spans(&p.iter().map(|&x| x as i32).collect::<Vec<_>>(), labels);
        let gs = extract_spans(g, labels);
        n_pred += ps.len();
        n_gold += gs.len();
        tp += ps.iter().filter(|s| gs.contains(s)).count();
    }
    if n_pred == 0 || n_gold == 0 {
        return 0.0;
    }
    let p = tp as f64 / n_pred as f64;
    let r = tp as f64 / n_gold as f64;
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// (start, end_exclusive, type) spans from BIO labels.
fn extract_spans(tags: &[i32], labels: &[String]) -> Vec<(usize, usize, String)> {
    let mut spans = Vec::new();
    let mut cur: Option<(usize, String)> = None;
    for (i, &t) in tags.iter().enumerate() {
        let name = labels.get(t as usize).map(|s| s.as_str()).unwrap_or("O");
        if let Some(ty) = name.strip_prefix("B-") {
            if let Some((s, t0)) = cur.take() {
                spans.push((s, i, t0));
            }
            cur = Some((i, ty.to_string()));
        } else if let Some(ty) = name.strip_prefix("I-") {
            match &cur {
                Some((_, t0)) if t0 == ty => {}
                _ => {
                    // I- without matching B-: treat as span start (lenient)
                    if let Some((s, t0)) = cur.take() {
                        spans.push((s, i, t0));
                    }
                    cur = Some((i, ty.to_string()));
                }
            }
        } else {
            if let Some((s, t0)) = cur.take() {
                spans.push((s, i, t0));
            }
        }
    }
    if let Some((s, t0)) = cur {
        spans.push((s, tags.len(), t0));
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record_us(i as f64);
        }
        assert_eq!(r.percentile_us(50.0), 50.0);
        assert_eq!(r.percentile_us(99.0), 99.0);
        assert_eq!(r.percentile_us(100.0), 100.0);
        assert!((r.mean_us() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder_is_zero() {
        let r = LatencyRecorder::new();
        assert_eq!(r.percentile_us(99.0), 0.0);
        assert_eq!(r.mean_us(), 0.0);
    }

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(token_accuracy(&[1, 1, 1], &[1, 0, 1], &[1, 0, 1]), 1.0);
    }

    #[test]
    fn histogram_percentiles_approximate_exact_recorder() {
        let h = Histogram::new();
        let mut exact = LatencyRecorder::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
            exact.record_us(i as f64);
        }
        assert_eq!(h.len(), 1000);
        for p in [50.0, 90.0, 95.0, 99.0] {
            let want = exact.percentile_us(p);
            let got = h.percentile_us(p);
            let rel = (got - want).abs() / want;
            assert!(rel <= 0.125, "p{p}: got {got}, want {want} (rel {rel})");
        }
        // mean is exact to integer-us truncation; max is exact
        assert!((h.mean_us() - 500.5).abs() < 1.0);
        assert_eq!(h.percentile_us(100.0), 1000.0);
    }

    #[test]
    fn histogram_small_values_are_exact() {
        let h = Histogram::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            h.record_us(v);
        }
        assert_eq!(h.percentile_us(25.0), 1.0);
        assert_eq!(h.percentile_us(100.0), 4.0);
    }

    #[test]
    fn histogram_empty_and_degenerate_inputs() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(99.0), 0.0);
        assert_eq!(h.mean_us(), 0.0);
        h.record_us(f64::NAN);
        h.record_us(-5.0);
        assert_eq!(h.len(), 2);
        assert_eq!(h.percentile_us(100.0), 0.0);
    }

    #[test]
    fn histogram_is_thread_safe() {
        let h = std::sync::Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        h.record_us((t * 1000 + i) as f64);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.len(), 4000);
        assert_eq!(h.percentile_us(100.0), 3999.0);
    }

    /// The rolling window forgets old samples: a latency spike ages out once
    /// enough fresh samples overwrite its slots — the property the ladder
    /// controller relies on to shift back up after load clears.
    #[test]
    fn rolling_window_ages_out_old_samples() {
        let w = RollingWindow::new(8);
        assert!(w.is_empty());
        assert_eq!(w.percentile_us(99.0), 0.0);
        for _ in 0..8 {
            w.record_us(50_000.0); // slow era
        }
        assert_eq!(w.len(), 8);
        assert_eq!(w.percentile_us(99.0), 50_000.0);
        for _ in 0..8 {
            w.record_us(1_000.0); // fast era overwrites every slot
        }
        assert_eq!(w.len(), 8, "window length is capped at capacity");
        assert_eq!(w.percentile_us(99.0), 1_000.0,
                   "old spike must have aged out");
        assert_eq!(w.percentile_us(0.0), 1_000.0);
    }

    #[test]
    fn counters_fill() {
        let c = Counters::default();
        c.inc_batches(8);
        c.inc_batches(4);
        assert_eq!(c.mean_batch_fill(), 6.0);
    }

    fn lbl() -> Vec<String> {
        ["O", "B-PER", "I-PER", "B-ORG", "I-ORG"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    }

    #[test]
    fn span_extraction_and_f1() {
        // gold: PER at [1,3), ORG at [4,5)
        let gold = vec![vec![0, 1, 2, 0, 3]];
        let perfect = vec![vec![0usize, 1, 2, 0, 3]];
        assert_eq!(span_f1(&perfect, &gold, &lbl()), 1.0);
        // half-right: only the ORG span
        let half = vec![vec![0usize, 0, 0, 0, 3]];
        let f1 = span_f1(&half, &gold, &lbl());
        assert!((f1 - 2.0 * 0.5 * 1.0 / 1.5).abs() < 1e-9, "f1={f1}");
    }

    #[test]
    fn bio_i_without_b_is_lenient() {
        let gold = vec![vec![0, 2, 2, 0, 0]]; // I-PER I-PER with no B
        let pred = vec![vec![0usize, 2, 2, 0, 0]];
        assert_eq!(span_f1(&pred, &gold, &lbl()), 1.0);
    }
}
