//! Production telemetry: Prometheus text exposition, per-request stage
//! tracing, and the native GEMM kernel clock.
//!
//! Three pieces, all feeding `GET /metrics`:
//!
//! * [`render_prometheus`] — walks the live [`Registry`] and renders every
//!   serving counter, gauge, and latency histogram in the Prometheus text
//!   exposition format (version 0.0.4).  Global counters come from the
//!   registry-wide [`Counters`], which survive hot reloads, so
//!   `samp_requests_total` and friends are monotone across generation
//!   swaps; per-lane series carry `{model, generation, task}` labels and
//!   simply start fresh series when a reload bumps the generation.
//! * [`StageStats`] / [`RowTimings`] — the stage-tracing substrate: each
//!   lane records per-stage latency histograms (queue-wait, batch-form,
//!   forward, GEMM share of forward, decode), and every served row carries
//!   its own [`RowTimings`] so a slow response is attributable to queueing
//!   vs. kernel vs. decode at a glance (`"timings"` on the response behind
//!   `--trace-responses` / `X-SAMP-Trace: 1`).
//! * [`gemm_clock_add`] / [`gemm_clock_take`] — a thread-local nanosecond
//!   accumulator the native GEMM entry points charge their wall time to.
//!   The dispatcher worker resets it before a forward pass and reads it
//!   after, splitting kernel time out of the forward stage without
//!   threading a context handle through every layer of the encoder.

use std::cell::Cell;
use std::fmt::Write as _;
use std::sync::atomic::Ordering;

use crate::metrics::{Counters, Histogram};
use crate::registry::Registry;

pub mod flight;
pub mod hub;

pub use flight::FlightRecorder;
pub use hub::{spawn_signal_collector, SignalHub};

// ---------------------------------------------------------------------------
// GEMM kernel clock
// ---------------------------------------------------------------------------

thread_local! {
    /// Nanoseconds of native GEMM wall time charged to this thread since the
    /// last [`gemm_clock_take`].  The pool-parallel GEMM entry points block
    /// the calling thread until every chunk finishes, so caller-side wall
    /// time is the true kernel share of the forward pass.
    static GEMM_CLOCK_NS: Cell<u64> = const { Cell::new(0) };
}

/// Charge `ns` nanoseconds of GEMM wall time to the calling thread.
pub fn gemm_clock_add(ns: u64) {
    GEMM_CLOCK_NS.with(|c| c.set(c.get().saturating_add(ns)));
}

/// Read and reset the calling thread's accumulated GEMM nanoseconds.
pub fn gemm_clock_take() -> u64 {
    GEMM_CLOCK_NS.with(|c| c.replace(0))
}

/// Per-batch GEMM attribution scope.  Work stealing runs a *victim* lane's
/// batch on a *thief* lane's thread, so charging the thread-local clock to
/// "whatever stats this thread belongs to" misattributes stolen kernel
/// time.  A scope pins attribution to the batch instead: `begin()` clears
/// any stale charge left on the thread (e.g. warmup passes or an aborted
/// batch), `take_us()` reads exactly the kernel time this batch accrued —
/// and the caller records it into the batch's *owning* (victim) lane.
#[derive(Debug)]
pub struct GemmScope {
    _private: (),
}

impl GemmScope {
    /// Open a scope for one batch, discarding stale thread-local charge.
    pub fn begin() -> GemmScope {
        gemm_clock_take();
        GemmScope { _private: () }
    }

    /// Close the scope: microseconds of GEMM wall time this batch charged
    /// to the executing thread.
    pub fn take_us(self) -> u64 {
        gemm_clock_take() / 1_000
    }
}

// ---------------------------------------------------------------------------
// Stage tracing
// ---------------------------------------------------------------------------

/// Per-row stage timings (microseconds), filled in by the dispatcher as the
/// row moves admission → queue → batch-form → forward → decode.  The server
/// adds `tokenize_us` (measured before the row is enqueued) when echoing
/// timings on a traced response.
#[derive(Debug, Clone, Copy, Default)]
pub struct RowTimings {
    /// Encoding the request text into token ids (server-side, pre-queue).
    pub tokenize_us: u64,
    /// Enqueue to the moment batch forming picked the row.
    pub queue_us: u64,
    /// Assembling the block the row rode in (shared by its batch mates).
    pub form_us: u64,
    /// Encoder + head forward pass of the row's batch.
    pub forward_us: u64,
    /// Share of `forward_us` spent inside native GEMM kernels.
    pub gemm_us: u64,
    /// Decoding the row's logits into a task output.
    pub decode_us: u64,
}

impl RowTimings {
    /// Sum of the traced stages (tokenize + queue + form + forward +
    /// decode; `gemm_us` is a subset of `forward_us`, not an addend).
    pub fn stage_sum_us(&self) -> u64 {
        self.tokenize_us + self.queue_us + self.form_us + self.forward_us
            + self.decode_us
    }
}

/// Per-lane stage histograms: one [`Histogram`] per pipeline stage, recorded
/// by the dispatcher shard set for every served row.
#[derive(Debug, Default)]
pub struct StageStats {
    pub queue: Histogram,
    pub form: Histogram,
    pub forward: Histogram,
    pub gemm: Histogram,
    pub decode: Histogram,
}

impl StageStats {
    /// `(stage name, histogram)` pairs in pipeline order, for exposition.
    pub fn stages(&self) -> [(&'static str, &Histogram); 5] {
        [("queue", &self.queue),
         ("form", &self.form),
         ("forward", &self.forward),
         ("gemm", &self.gemm),
         ("decode", &self.decode)]
    }

    /// Record one served row's dispatcher-side stages.
    pub fn record(&self, t: &RowTimings) {
        self.queue.record_us(t.queue_us as f64);
        self.form.record_us(t.form_us as f64);
        self.forward.record_us(t.forward_us as f64);
        self.gemm.record_us(t.gemm_us as f64);
        self.decode.record_us(t.decode_us as f64);
    }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------------

/// Escape a label value per the text exposition format: backslash, double
/// quote, and newline must be escaped inside the quoted value.
pub fn escape_label_value(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// One metric family under construction: HELP/TYPE header emitted once,
/// then any number of `name{labels} value` sample lines.
struct Family<'a> {
    out: &'a mut String,
    name: &'static str,
}

impl<'a> Family<'a> {
    fn new(out: &'a mut String, name: &'static str, kind: &str, help: &str)
           -> Family<'a> {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        Family { out, name }
    }

    /// `name{labels} value` (labels pre-rendered, "" = no label set).
    fn sample(&mut self, labels: &str, value: f64) {
        self.sample_named(self.name, labels, value);
    }

    fn sample_named(&mut self, name: &str, labels: &str, value: f64) {
        if labels.is_empty() {
            let _ = writeln!(self.out, "{name} {value}");
        } else {
            let _ = writeln!(self.out, "{name}{{{labels}}} {value}");
        }
    }

    /// Full histogram exposition: cumulative `le` buckets from the
    /// histogram's occupied native buckets, a `+Inf` bucket, `_sum`, and
    /// `_count`, all sharing `labels`.
    fn histogram(&mut self, labels: &str, h: &Histogram) {
        let bucket = format!("{}_bucket", self.name);
        for (upper_us, cum) in h.cumulative_buckets() {
            let le = if labels.is_empty() {
                format!("le=\"{upper_us}\"")
            } else {
                format!("{labels},le=\"{upper_us}\"")
            };
            self.sample_named(&bucket, &le, cum as f64);
        }
        let inf = if labels.is_empty() {
            "le=\"+Inf\"".to_string()
        } else {
            format!("{labels},le=\"+Inf\"")
        };
        self.sample_named(&bucket, &inf, h.len() as f64);
        self.sample_named(&format!("{}_sum", self.name), labels,
                          h.sum_us() as f64);
        self.sample_named(&format!("{}_count", self.name), labels,
                          h.len() as f64);
    }
}

/// A lane's label set, rendered once and shared by every family that tags
/// samples with it.
struct LaneLabels {
    base: String,
}

impl LaneLabels {
    fn new(model: &str, generation: u64, task: &str) -> LaneLabels {
        LaneLabels {
            base: format!("model=\"{}\",generation=\"{}\",task=\"{}\"",
                          escape_label_value(model), generation,
                          escape_label_value(task)),
        }
    }

    fn with(&self, extra: &str) -> String {
        format!("{},{}", self.base, extra)
    }
}

/// Render the full metric set of a live registry in the Prometheus text
/// exposition format.  Global counters are registry-wide (monotone across
/// hot reloads); per-lane series are labeled `{model, generation, task}` and
/// per-worker series add `worker`; ladder lanes expose their rung state with
/// a `rung` label per served-precision variant.
pub fn render_prometheus(registry: &Registry) -> String {
    let mut out = String::with_capacity(8 * 1024);
    let counters = registry.counters();
    render_global(&mut out, registry, &counters);

    // Snapshot every lane of every model's *current* generation once, then
    // emit family-by-family so HELP/TYPE appear exactly once per family.
    let mut lanes = Vec::new();
    for entry in registry.entries() {
        let dep = entry.current();
        for lane in dep.lanes_snapshot() {
            let labels = LaneLabels::new(&entry.id, dep.generation,
                                         lane.stats.task());
            lanes.push((labels, lane));
        }
    }

    {
        let mut f = Family::new(&mut out, "samp_lane_queue_depth", "gauge",
                                "Rows waiting in the lane's batcher queue.");
        for (l, lane) in &lanes {
            f.sample(&l.base, lane.batcher.len() as f64);
        }
    }
    {
        let mut f =
            Family::new(&mut out, "samp_lane_queue_capacity", "gauge",
                        "Admission-control cap on the lane's batcher queue.");
        for (l, lane) in &lanes {
            f.sample(&l.base, lane.batcher.max_depth as f64);
        }
    }
    {
        let mut f = Family::new(&mut out, "samp_lane_batches_total", "counter",
                                "Batches this lane's dispatchers executed.");
        for (l, lane) in &lanes {
            f.sample(&l.base, lane.stats.batches() as f64);
        }
    }
    {
        let mut f = Family::new(&mut out, "samp_lane_rows_total", "counter",
                                "Rows this lane's dispatchers served.");
        for (l, lane) in &lanes {
            f.sample(&l.base, lane.stats.rows() as f64);
        }
    }
    {
        let mut f = Family::new(
            &mut out, "samp_lane_recent_p99_us", "gauge",
            "Rolling-window p99 latency (us) — the ladder controller's SLO \
             signal; sheds and deadline drops are excluded.  Lanes with an \
             empty window (no recent traffic) omit the sample rather than \
             flatline at 0.");
        for (l, lane) in &lanes {
            if let Some(p99) = lane.stats.recent.percentile_opt_us(99.0) {
                f.sample(&l.base, p99);
            }
        }
    }
    {
        let mut f = Family::new(
            &mut out, "samp_rung_latency_us", "gauge",
            "Rolling per-served-rung end-to-end latency (us), quantile per \
             sample — the observed cost of each precision level.");
        for (l, lane) in &lanes {
            for (rung, window) in lane.stats.rung_latency.snapshot() {
                let (Some(p50), Some(p99)) =
                    (window.percentile_opt_us(50.0),
                     window.percentile_opt_us(99.0))
                else {
                    continue;
                };
                let rung = escape_label_value(&rung);
                f.sample(&l.with(&format!(
                    "rung=\"{rung}\",quantile=\"0.5\"")), p50);
                f.sample(&l.with(&format!(
                    "rung=\"{rung}\",quantile=\"0.99\"")), p99);
            }
        }
    }
    {
        let mut f = Family::new(
            &mut out, "samp_rung_rows_total", "counter",
            "Rows served per precision rung (monotone within a generation; \
             the windowed quantiles above cover the last rows per rung).");
        for (l, lane) in &lanes {
            for (rung, window) in lane.stats.rung_latency.snapshot() {
                let rung = escape_label_value(&rung);
                f.sample(&l.with(&format!("rung=\"{rung}\"")),
                         window.total() as f64);
            }
        }
    }
    {
        let mut f = Family::new(&mut out, "samp_lane_latency_us", "histogram",
                                "End-to-end request latency per lane (us).");
        for (l, lane) in &lanes {
            f.histogram(&l.base, &lane.stats.latency);
        }
    }
    {
        let mut f = Family::new(
            &mut out, "samp_stage_latency_us", "histogram",
            "Per-stage row latency (us): queue, form, forward, gemm \
             (kernel share of forward), decode.");
        for (l, lane) in &lanes {
            for (stage, h) in lane.stats.stages.stages() {
                f.histogram(&l.with(&format!("stage=\"{stage}\"")), h);
            }
        }
    }
    {
        let mut f = Family::new(&mut out, "samp_worker_batches_total",
                                "counter",
                                "Batches executed per dispatcher worker.");
        for (l, lane) in &lanes {
            for (w, b) in lane.stats.worker_batches.iter().enumerate() {
                f.sample(&l.with(&format!("worker=\"{w}\"")),
                         b.load(Ordering::Relaxed) as f64);
            }
        }
    }
    {
        let mut f = Family::new(&mut out, "samp_worker_rows_total", "counter",
                                "Rows served per dispatcher worker.");
        for (l, lane) in &lanes {
            for (w, r) in lane.stats.worker_rows.iter().enumerate() {
                f.sample(&l.with(&format!("worker=\"{w}\"")),
                         r.load(Ordering::Relaxed) as f64);
            }
        }
    }
    {
        let mut f = Family::new(
            &mut out, "samp_lane_steals_total", "counter",
            "Batches stolen across lanes, labeled {from = victim model, \
             to = thief model}; monotone across reloads.");
        for (from, to, n) in registry.steal_router().pairs() {
            let labels = format!(
                "from=\"{}\",to=\"{}\"", escape_label_value(&from),
                escape_label_value(&to));
            f.sample(&labels, n as f64);
        }
    }
    {
        let mut f = Family::new(
            &mut out, "samp_lane_weight", "gauge",
            "Raw --lane-weight of each registered model (1 = unweighted).");
        for entry in registry.entries() {
            let b = registry.lane_config().budget(&entry.id);
            let labels =
                format!("model=\"{}\"", escape_label_value(&entry.id));
            f.sample(&labels, b.weight);
        }
    }
    {
        let mut f = Family::new(
            &mut out, "samp_lane_worker_budget", "gauge",
            "Dispatcher workers each of the model's lanes is budgeted \
             (the model's weighted slice of the global worker pool).");
        for entry in registry.entries() {
            let b = registry.lane_config().budget(&entry.id);
            let labels =
                format!("model=\"{}\"", escape_label_value(&entry.id));
            f.sample(&labels, b.workers as f64);
        }
    }
    {
        let mut f = Family::new(
            &mut out, "samp_lane_queue_budget", "gauge",
            "Batcher queue depth each of the model's lanes is budgeted \
             (the model's weighted slice of the global queue pool).");
        for entry in registry.entries() {
            let b = registry.lane_config().budget(&entry.id);
            let labels =
                format!("model=\"{}\"", escape_label_value(&entry.id));
            f.sample(&labels, b.queue_depth as f64);
        }
    }
    {
        let mut f = Family::new(
            &mut out, "samp_ladder_level", "gauge",
            "Currently-served rung index of the lane's precision ladder \
             (0 = default rung).");
        for (l, lane) in &lanes {
            if let Some(ladder) = &lane.ladder {
                f.sample(&l.base, ladder.level() as f64);
            }
        }
    }
    {
        let mut f = Family::new(
            &mut out, "samp_ladder_rung_active", "gauge",
            "1 for the precision rung the ladder currently serves, 0 for \
             the other rungs of the lane.");
        for (l, lane) in &lanes {
            if let Some(ladder) = &lane.ladder {
                let level = ladder.level();
                for (i, rung) in ladder.rungs().iter().enumerate() {
                    let labels = l.with(&format!(
                        "rung=\"{}\"", escape_label_value(rung)));
                    f.sample(&labels, if i == level { 1.0 } else { 0.0 });
                }
            }
        }
    }
    out
}

/// Registry-wide counters and gauges — one unlabeled sample each, monotone
/// across hot reloads because the backing [`Counters`] outlive generations.
fn render_global(out: &mut String, registry: &Registry, c: &Counters) {
    let pairs: [(&'static str, &str, u64); 12] = [
        ("samp_requests_total", "Rows admitted across every model and lane.",
         c.requests.load(Ordering::Relaxed)),
        ("samp_batches_total", "Batches executed across every lane.",
         c.batches.load(Ordering::Relaxed)),
        ("samp_batch_rows_total", "Rows executed inside batches.",
         c.batch_rows.load(Ordering::Relaxed)),
        ("samp_errors_total", "Rows that failed (any non-2xx outcome).",
         c.errors.load(Ordering::Relaxed)),
        ("samp_shed_total",
         "Rows rejected by admission control (HTTP 429).",
         c.shed.load(Ordering::Relaxed)),
        ("samp_deadline_expired_total",
         "Rows dropped at form time because their deadline passed (504).",
         c.deadline_expired.load(Ordering::Relaxed)),
        ("samp_pool_hits_total", "Block-pool checkouts served from the pool.",
         c.pool_hits.load(Ordering::Relaxed)),
        ("samp_pool_misses_total", "Block-pool checkouts that allocated.",
         c.pool_misses.load(Ordering::Relaxed)),
        ("samp_swap_retry_exhausted_total",
         "Generation-swap retry loops that exhausted every attempt.",
         c.swap_retry_exhausted.load(Ordering::Relaxed)),
        ("samp_replicas_healed_total",
         "Poisoned engine replicas rebuilt in place.",
         c.replicas_healed.load(Ordering::Relaxed)),
        ("samp_ladder_shifts_total",
         "Precision-ladder variant switches (down- and up-shifts).",
         c.ladder_shifts.load(Ordering::Relaxed)),
        ("samp_steals_total",
         "Batches dispatcher workers stole across lanes, in total (see \
          samp_lane_steals_total for the {from,to} breakdown).",
         c.lane_steals.load(Ordering::Relaxed)),
    ];
    for (name, help, v) in pairs {
        let mut f = Family::new(out, name, "counter", help);
        f.sample("", v as f64);
    }
    {
        let mut f = Family::new(out, "samp_reloads_total", "counter",
                                "Completed hot reloads (generation swaps).");
        f.sample("", registry.reload_count() as f64);
    }
    {
        let mut f = Family::new(out, "samp_generations_retired_total",
                                "counter",
                                "Old generations fully drained and retired.");
        f.sample("", registry.retired_count() as f64);
    }
    {
        let mut f = Family::new(out, "samp_models", "gauge",
                                "Models currently registered.");
        f.sample("", registry.model_count() as f64);
    }
    {
        let mut f = Family::new(
            out, "samp_request_latency_us", "histogram",
            "End-to-end request latency (us) across every model and lane.");
        f.histogram("", &c.latency);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_label_values() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn gemm_clock_accumulates_and_resets() {
        assert_eq!(gemm_clock_take(), 0);
        gemm_clock_add(100);
        gemm_clock_add(23);
        assert_eq!(gemm_clock_take(), 123);
        assert_eq!(gemm_clock_take(), 0);
    }

    #[test]
    fn gemm_clock_is_per_thread() {
        gemm_clock_add(50);
        let other = std::thread::spawn(|| {
            gemm_clock_add(7);
            gemm_clock_take()
        });
        assert_eq!(other.join().unwrap(), 7);
        assert_eq!(gemm_clock_take(), 50);
    }

    #[test]
    fn stage_sum_excludes_gemm_subset() {
        let t = RowTimings {
            tokenize_us: 1,
            queue_us: 2,
            form_us: 3,
            forward_us: 10,
            gemm_us: 8,
            decode_us: 4,
        };
        assert_eq!(t.stage_sum_us(), 20);
    }

    #[test]
    fn histogram_exposition_buckets_are_cumulative() {
        let h = Histogram::new();
        for us in [3.0, 3.0, 100.0, 10_000.0] {
            h.record_us(us);
        }
        let mut out = String::new();
        let mut f = Family::new(&mut out, "samp_test_us", "histogram", "t.");
        f.histogram("model=\"m\"", &h);
        let mut last = 0u64;
        let mut bucket_lines = 0;
        for line in out.lines().filter(|l| !l.starts_with('#')) {
            if line.starts_with("samp_test_us_bucket") {
                let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v as u64 >= last, "non-cumulative buckets: {out}");
                last = v as u64;
                bucket_lines += 1;
            }
        }
        assert!(bucket_lines >= 4, "expected per-value buckets + +Inf: {out}");
        assert!(out.contains("le=\"+Inf\"} 4"), "{out}");
        assert!(out.contains("samp_test_us_count{model=\"m\"} 4"), "{out}");
    }
}
