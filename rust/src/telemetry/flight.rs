//! Black-box flight recorder: a bounded per-lane ring of batch/row
//! lifecycle events, dumped on demand as Chrome trace-event JSON.
//!
//! Every lane records admit / form / steal / dispatch / rung-shift / heal /
//! reply events (and an automatic `slow_row` capture for any row whose
//! end-to-end latency exceeded the lane SLO, carrying its full
//! [`RowTimings`](super::RowTimings) breakdown).  `GET /v1/debug/trace?secs=N`
//! renders the last N seconds as a `{"traceEvents": [...]}` document that
//! loads directly in `chrome://tracing` / Perfetto: one track (`tid`) per
//! lane, `ph: "X"` complete events for spans with a duration, `ph: "i"`
//! instants for the rest.
//!
//! The recorder is bounded (default 4096 events per lane, oldest dropped
//! first) and registry-lifetime: lane keys are `(model, task)` without the
//! generation, so a hot reload keeps appending to the same track and a
//! reload-during-incident is visible *inside* the trace instead of wiping
//! it.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One recorded lifecycle event.  `ts_us`/`dur_us` are microseconds since
/// the recorder's epoch; `dur_us > 0` renders as a complete span ending at
/// `ts_us` (the recording site timestamps completion), `dur_us == 0` as an
/// instant.
#[derive(Debug, Clone)]
pub struct FlightEvent {
    pub ts_us: u64,
    pub dur_us: u64,
    /// Event kind: `admit`, `form`, `steal`, `dispatch`, `rung_shift`,
    /// `heal`, `reply`, or `slow_row`.
    pub kind: &'static str,
    /// Rows the event covers (0 when not meaningful).
    pub rows: u64,
    /// Free-form detail rendered into the event's `args` (`""` = none).
    pub detail: String,
}

type LaneRing = Arc<Mutex<VecDeque<FlightEvent>>>;

/// The recorder itself: one bounded ring per `(model, task)` lane.
/// `cap == 0` disables recording entirely (every hook no-ops).
#[derive(Debug)]
pub struct FlightRecorder {
    epoch: Instant,
    cap: usize,
    lanes: RwLock<HashMap<(String, String), LaneRing>>,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder {
            epoch: Instant::now(),
            cap,
            lanes: RwLock::new(HashMap::new()),
        }
    }

    /// Whether hooks record anything (`--no-flight-recorder` sets cap 0).
    pub fn enabled(&self) -> bool {
        self.cap > 0
    }

    /// Microseconds since the recorder's epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn ring(&self, model: &str, task: &str) -> LaneRing {
        if let Some(r) = self.lanes.read().unwrap()
            .get(&(model.to_string(), task.to_string()))
        {
            return r.clone();
        }
        let mut w = self.lanes.write().unwrap();
        w.entry((model.to_string(), task.to_string()))
            .or_insert_with(|| {
                Arc::new(Mutex::new(VecDeque::with_capacity(self.cap.min(256))))
            })
            .clone()
    }

    fn push(&self, model: &str, task: &str, ev: FlightEvent) {
        if self.cap == 0 {
            return;
        }
        let ring = self.ring(model, task);
        let mut ring = ring.lock().unwrap();
        if ring.len() >= self.cap {
            ring.pop_front();
        }
        ring.push_back(ev);
    }

    /// Record an instant event (`ph: "i"`).
    pub fn instant(&self, model: &str, task: &str, kind: &'static str,
                   rows: u64, detail: impl Into<String>) {
        if self.cap == 0 {
            return;
        }
        self.push(model, task, FlightEvent {
            ts_us: self.now_us(),
            dur_us: 0,
            kind,
            rows,
            detail: detail.into(),
        });
    }

    /// Record a span that just *completed* and took `dur_us` (`ph: "X"`;
    /// the start is back-dated from now).
    pub fn span(&self, model: &str, task: &str, kind: &'static str,
                dur_us: u64, rows: u64, detail: impl Into<String>) {
        if self.cap == 0 {
            return;
        }
        self.push(model, task, FlightEvent {
            ts_us: self.now_us(),
            dur_us: dur_us.max(1),
            kind,
            rows,
            detail: detail.into(),
        });
    }

    /// Events of one lane inside the trailing window, oldest first
    /// (mostly for tests).
    pub fn events(&self, model: &str, task: &str, last: Duration)
                  -> Vec<FlightEvent> {
        let cutoff = self.now_us().saturating_sub(last.as_micros() as u64);
        let map = self.lanes.read().unwrap();
        match map.get(&(model.to_string(), task.to_string())) {
            Some(r) => r.lock().unwrap().iter()
                .filter(|e| e.ts_us >= cutoff)
                .cloned()
                .collect(),
            None => Vec::new(),
        }
    }

    /// Count events of a kind across every lane in the trailing window.
    pub fn count_kind(&self, kind: &str, last: Duration) -> usize {
        let cutoff = self.now_us().saturating_sub(last.as_micros() as u64);
        let map = self.lanes.read().unwrap();
        map.values()
            .map(|r| {
                r.lock().unwrap().iter()
                    .filter(|e| e.kind == kind && e.ts_us >= cutoff)
                    .count()
            })
            .sum()
    }

    /// Render the last `last` of every lane's ring as a Chrome trace-event
    /// JSON document (`{"traceEvents": [...]}`).  One `tid` per lane (named
    /// via `thread_name` metadata), `pid` 1 throughout; events are sorted
    /// by timestamp so `ts` is monotone per track.  Spans are emitted as
    /// complete (`ph: "X"`) events with `ts` back-dated to their start.
    pub fn trace_json(&self, last: Duration) -> Json {
        let cutoff = self.now_us().saturating_sub(last.as_micros() as u64);
        let mut keys: Vec<(String, String)> =
            self.lanes.read().unwrap().keys().cloned().collect();
        keys.sort();

        // (sort timestamp, event json): metadata first (ts 0), then events
        // ordered by *start* time so each track's ts column is monotone.
        let mut events: Vec<(u64, Json)> = Vec::new();
        for (tid, (model, task)) in keys.iter().enumerate() {
            let tid = tid as u64 + 1;
            events.push((0, Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("ts", Json::num(0.0)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid as f64)),
                ("args", Json::obj(vec![
                    ("name", Json::str(format!("{model}/{task}"))),
                ])),
            ])));
            let map = self.lanes.read().unwrap();
            let Some(ring) = map.get(&(model.clone(), task.clone())) else {
                continue;
            };
            let ring = ring.lock().unwrap();
            for ev in ring.iter().filter(|e| e.ts_us >= cutoff) {
                let start = ev.ts_us.saturating_sub(ev.dur_us);
                let mut args = vec![("rows", Json::num(ev.rows as f64))];
                if !ev.detail.is_empty() {
                    args.push(("detail", Json::str(ev.detail.clone())));
                }
                let mut fields = vec![
                    ("name", Json::str(ev.kind)),
                    ("cat", Json::str("samp")),
                    ("ph", Json::str(if ev.dur_us > 0 { "X" } else { "i" })),
                    ("ts", Json::num(start as f64)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(tid as f64)),
                    ("args", Json::obj(args)),
                ];
                if ev.dur_us > 0 {
                    fields.push(("dur", Json::num(ev.dur_us as f64)));
                } else {
                    // Instant scope: thread-local.
                    fields.push(("s", Json::str("t")));
                }
                events.push((start, Json::obj(fields)));
            }
        }
        events.sort_by_key(|(ts, _)| *ts);
        Json::obj(vec![
            ("traceEvents",
             Json::arr(events.into_iter().map(|(_, e)| e))),
            ("displayTimeUnit", Json::str("ms")),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let fr = FlightRecorder::new(0);
        fr.instant("m", "t", "admit", 2, "");
        fr.span("m", "t", "dispatch", 100, 2, "");
        assert!(!fr.enabled());
        assert!(fr.events("m", "t", Duration::from_secs(60)).is_empty());
        let trace = fr.trace_json(Duration::from_secs(60));
        assert_eq!(trace.get("traceEvents").as_arr().unwrap().len(), 0);
    }

    #[test]
    fn ring_is_bounded_and_drops_oldest() {
        let fr = FlightRecorder::new(4);
        for i in 0..10 {
            fr.instant("m", "t", "admit", i, "");
        }
        let evs = fr.events("m", "t", Duration::from_secs(60));
        assert_eq!(evs.len(), 4);
        assert_eq!(evs.first().unwrap().rows, 6);
        assert_eq!(evs.last().unwrap().rows, 9);
    }

    #[test]
    fn trace_json_is_sorted_with_metadata_and_span_fields() {
        let fr = FlightRecorder::new(64);
        fr.instant("m", "t", "admit", 3, "");
        fr.span("m", "t", "dispatch", 500, 3, "fp16");
        fr.instant("other", "t", "reply", 1, "");
        let trace = fr.trace_json(Duration::from_secs(60));
        let evs = trace.get("traceEvents").as_arr().unwrap();
        // 2 lanes -> 2 thread_name metadata events + 3 recorded events.
        assert_eq!(evs.len(), 5);
        let mut last_ts_per_tid: HashMap<i64, f64> = HashMap::new();
        let mut kinds = Vec::new();
        for e in evs {
            let ph = e.get("ph").as_str().unwrap();
            let ts = e.get("ts").as_f64().unwrap();
            let tid = e.get("tid").as_i64().unwrap();
            assert_eq!(e.get("pid").as_i64(), Some(1));
            if ph == "M" {
                continue;
            }
            if ph == "X" {
                assert!(e.get("dur").as_f64().unwrap() >= 1.0);
            } else {
                assert_eq!(ph, "i");
            }
            let last = last_ts_per_tid.entry(tid).or_insert(0.0);
            assert!(ts >= *last, "ts not monotone per track");
            *last = ts;
            kinds.push(e.get("name").as_str().unwrap().to_string());
        }
        assert!(kinds.contains(&"admit".to_string()), "{kinds:?}");
        assert!(kinds.contains(&"dispatch".to_string()), "{kinds:?}");
        assert!(kinds.contains(&"reply".to_string()), "{kinds:?}");
    }
}
