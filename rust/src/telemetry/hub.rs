//! The signal hub: an in-process time-series core the closed-loop
//! controllers read instead of poking at private serving state.
//!
//! A registry-lifetime collector thread ([`spawn_signal_collector`]) samples
//! every per-lane series the Prometheus layer exports — queue depth and
//! capacity, rows/batches/steals (as per-tick deltas), per-stage latency
//! histogram deltas, rolling p99, ladder level — into fixed-window
//! lock-free ring buffers ([`Series`]).  Consumers query the hub:
//!
//! * the **ladder controller** reads `queue_depth` / `queue_capacity` /
//!   `recent_p99_us` for its pressure test (no direct batcher or window
//!   reads remain in controller code);
//! * the **lane-weight re-apportioner** (`--learn-weights`) re-derives
//!   [`LaneBudget`](crate::registry::LaneBudget) shares from observed
//!   per-model arrival rates and queue-wait sums over a trailing window,
//!   writing them through the shared
//!   [`BudgetTable`](crate::registry::BudgetTable) so they survive hot
//!   reloads and surface on `/v1/models` + the budget gauges;
//! * `/metrics` and `/v1/stats` keep reading the live counters directly —
//!   the hub is the controllers' view, not a replacement exporter.
//!
//! Rings are single-writer (the collector) / many-reader: each slot is an
//! `(AtomicU64 timestamp, AtomicU64 f64-bits)` pair and the head index is
//! published with `Release` after the slot is filled, so readers never see
//! a torn sample — at worst they miss the newest slot or skip an
//! overwritten one.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::registry::Registry;

/// Samples a series ring holds (at the collector's tick this is tens of
/// seconds of history — plenty for pressure tests and learning windows).
const SERIES_CAP: usize = 2048;

/// Collector tick.  Must stay at or below the ladder controller's own tick
/// (10ms) so hub-backed pressure decisions are as fresh as the direct reads
/// they replaced.
pub const COLLECT_TICK: Duration = Duration::from_millis(5);

/// Re-apportion lane weights every this many collector ticks (~250ms).
const LEARN_TICKS: u64 = 50;

/// Trailing window the weight learner scores arrival rates over.
const LEARN_WINDOW: Duration = Duration::from_secs(2);

/// Minimum rows observed across all models inside [`LEARN_WINDOW`] before
/// the learner trusts the window enough to move budgets.
const LEARN_MIN_ROWS: f64 = 32.0;

/// Blend factor toward the freshly-observed share (1.0 = jump straight to
/// the observed traffic split; lower = smoother).
const LEARN_ALPHA: f64 = 0.5;

/// No model's share learns below this floor, so a cold lane keeps at least
/// a sliver of budget to serve its first request from.
const LEARN_MIN_SHARE: f64 = 0.05;

/// Mean queue-wait (ms) that doubles a model's score: a lane whose rows
/// wait 10ms on average counts double vs. an unqueued lane at equal rate.
const LEARN_WAIT_NORM_MS: f64 = 10.0;

/// One fixed-capacity ring of `(timestamp_us, f64)` samples.
#[derive(Debug)]
struct Series {
    ts_us: Box<[AtomicU64]>,
    bits: Box<[AtomicU64]>,
    /// Total samples ever written; slot = `(head - 1) % cap` is the newest.
    head: AtomicU64,
}

impl Series {
    fn new(cap: usize) -> Series {
        Series {
            ts_us: (0..cap).map(|_| AtomicU64::new(0)).collect(),
            bits: (0..cap).map(|_| AtomicU64::new(0)).collect(),
            head: AtomicU64::new(0),
        }
    }

    fn push(&self, ts_us: u64, value: f64) {
        let head = self.head.load(Ordering::Relaxed);
        let i = (head as usize) % self.ts_us.len();
        self.ts_us[i].store(ts_us, Ordering::Relaxed);
        self.bits[i].store(value.to_bits(), Ordering::Relaxed);
        self.head.store(head + 1, Ordering::Release);
    }

    fn latest(&self) -> Option<(u64, f64)> {
        let head = self.head.load(Ordering::Acquire);
        if head == 0 {
            return None;
        }
        let i = ((head - 1) as usize) % self.ts_us.len();
        Some((self.ts_us[i].load(Ordering::Relaxed),
              f64::from_bits(self.bits[i].load(Ordering::Relaxed))))
    }

    /// Walk samples newest → oldest, stopping at the first one older than
    /// `cutoff_us` (ring order is time order for a single writer).
    fn for_each_since(&self, cutoff_us: u64, mut f: impl FnMut(u64, f64)) {
        let head = self.head.load(Ordering::Acquire) as usize;
        let cap = self.ts_us.len();
        let n = head.min(cap);
        for k in 0..n {
            let i = (head - 1 - k) % cap;
            let ts = self.ts_us[i].load(Ordering::Relaxed);
            if ts < cutoff_us {
                break;
            }
            f(ts, f64::from_bits(self.bits[i].load(Ordering::Relaxed)));
        }
    }
}

/// Key of one per-lane series: `(model, task, series name)`.  Generations
/// are deliberately *not* part of the key — a hot reload continues the same
/// logical series, with counter deltas re-based by the collector.
type SeriesKey = (String, String, &'static str);

/// The in-process time-series store.  One lives per [`LaneConfig`] (shared
/// by every deployment generation the registry builds from it).
#[derive(Debug)]
pub struct SignalHub {
    epoch: Instant,
    series: RwLock<HashMap<SeriesKey, Arc<Series>>>,
}

impl Default for SignalHub {
    fn default() -> Self {
        Self::new()
    }
}

impl SignalHub {
    pub fn new() -> SignalHub {
        SignalHub { epoch: Instant::now(), series: RwLock::new(HashMap::new()) }
    }

    /// Microseconds since the hub's epoch (the time axis of every ring).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn series(&self, model: &str, task: &str, name: &'static str)
              -> Arc<Series> {
        if let Some(s) = self.series.read().unwrap()
            .get(&(model.to_string(), task.to_string(), name))
        {
            return s.clone();
        }
        let mut w = self.series.write().unwrap();
        w.entry((model.to_string(), task.to_string(), name))
            .or_insert_with(|| Arc::new(Series::new(SERIES_CAP)))
            .clone()
    }

    /// Append one sample (collector-side; single writer per series).
    pub fn record(&self, model: &str, task: &str, name: &'static str,
                  value: f64) {
        let now = self.now_us();
        self.series(model, task, name).push(now, value);
    }

    /// Newest sample of a series, if any has ever been recorded.
    pub fn latest(&self, model: &str, task: &str, name: &str) -> Option<f64> {
        let map = self.series.read().unwrap();
        map.get(&(model.to_string(), task.to_string(), name_static(name)?))
            .and_then(|s| s.latest())
            .map(|(_, v)| v)
    }

    /// Sum of a model's samples (across tasks) within the trailing window —
    /// the learner's view of "rows served in the last N seconds" when the
    /// series holds per-tick deltas.
    pub fn window_sum_model(&self, model: &str, name: &str, window: Duration)
                            -> f64 {
        let cutoff = self.now_us().saturating_sub(window.as_micros() as u64);
        let mut sum = 0.0;
        let map = self.series.read().unwrap();
        for ((m, _task, n), s) in map.iter() {
            if m == model && *n == name {
                s.for_each_since(cutoff, |_, v| sum += v);
            }
        }
        sum
    }

    /// Sum of one lane's series within the trailing window.
    pub fn window_sum(&self, model: &str, task: &str, name: &str,
                      window: Duration) -> f64 {
        let cutoff = self.now_us().saturating_sub(window.as_micros() as u64);
        let mut sum = 0.0;
        let map = self.series.read().unwrap();
        if let Some(key) = name_static(name) {
            if let Some(s) =
                map.get(&(model.to_string(), task.to_string(), key))
            {
                s.for_each_since(cutoff, |_, v| sum += v);
            }
        }
        sum
    }

    /// Series names with at least one sample for `(model, task)` — mostly
    /// for tests and debugging.
    pub fn series_names(&self, model: &str, task: &str) -> Vec<&'static str> {
        let map = self.series.read().unwrap();
        let mut names: Vec<&'static str> = map.keys()
            .filter(|(m, t, _)| m == model && t == task)
            .map(|(_, _, n)| *n)
            .collect();
        names.sort_unstable();
        names
    }
}

/// Intern a runtime series name to the `&'static str` the keys use.  The
/// set is closed (the collector defines it), so unknown names simply miss.
fn name_static(name: &str) -> Option<&'static str> {
    const NAMES: [&str; 19] = [
        "queue_depth", "queue_capacity", "ladder_level", "recent_p99_us",
        "rows", "batches", "steals_in", "steals_out",
        "stage_queue_count", "stage_queue_sum_us",
        "stage_form_count", "stage_form_sum_us",
        "stage_forward_count", "stage_forward_sum_us",
        "stage_gemm_count", "stage_gemm_sum_us",
        "stage_decode_count", "stage_decode_sum_us",
        "rung_shift",
    ];
    NAMES.iter().find(|n| **n == name).copied()
}

/// Last-seen counter values of one lane, for delta series.  Keyed by
/// generation: a reload restarts lane counters at zero, so a generation
/// change re-bases the deltas at the fresh values.
#[derive(Default)]
struct LanePrev {
    generation: u64,
    rows: u64,
    batches: u64,
    steals_in: u64,
    steals_out: u64,
    /// `(count, sum_us)` per stage, in [`StageStats::stages`] order.
    stages: [(u64, u64); 5],
}

/// Spawn the registry-lifetime collector thread: samples every lane into
/// the hub at [`COLLECT_TICK`] and, when `--learn-weights` is on, re-runs
/// the lane-weight apportioner every [`LEARN_TICKS`] ticks.  Idempotent —
/// the first caller wins, later calls are no-ops.
pub fn spawn_signal_collector(registry: &Arc<Registry>) {
    if !registry.begin_collector() {
        return;
    }
    let registry = Arc::clone(registry);
    std::thread::Builder::new()
        .name("samp-signals".to_string())
        .spawn(move || {
            let hub = registry.lane_config().hub.clone();
            let mut prev: HashMap<(String, String), LanePrev> = HashMap::new();
            let mut tick: u64 = 0;
            while !registry.is_closed() {
                for entry in registry.entries() {
                    let dep = entry.current();
                    for lane in dep.lanes_snapshot() {
                        sample_lane(&hub, &entry.id, dep.generation, &lane,
                                    &mut prev);
                    }
                }
                tick += 1;
                if registry.lane_config().learn_weights
                    && tick % LEARN_TICKS == 0
                {
                    relearn_weights(&registry, &hub);
                }
                std::thread::sleep(COLLECT_TICK);
            }
        })
        .expect("spawning signal collector");
}

fn sample_lane(hub: &SignalHub, model: &str, generation: u64,
               lane: &Arc<crate::registry::TaskLane>,
               prev: &mut HashMap<(String, String), LanePrev>) {
    let task = lane.stats.task();
    hub.record(model, task, "queue_depth", lane.batcher.len() as f64);
    hub.record(model, task, "queue_capacity", lane.batcher.max_depth as f64);
    if let Some(ladder) = &lane.ladder {
        hub.record(model, task, "ladder_level", ladder.level() as f64);
    }
    // Empty rolling window = no recent traffic: skip the sample rather than
    // record a misleading 0 (the controller treats "no sample" as no SLO
    // pressure, exactly like the old direct read of an empty window).
    if let Some(p99) = lane.stats.recent.percentile_opt_us(99.0) {
        hub.record(model, task, "recent_p99_us", p99);
    }

    let key = (model.to_string(), task.to_string());
    let p = prev.entry(key).or_default();
    if p.generation != generation {
        // Reload: lane counters restarted at zero — re-base.
        *p = LanePrev { generation, ..LanePrev::default() };
    }
    let mut delta = |cur: u64, last: &mut u64, name: &'static str| {
        let d = cur.saturating_sub(*last);
        *last = cur;
        hub.record(model, task, name, d as f64);
    };
    delta(lane.stats.rows(), &mut p.rows, "rows");
    delta(lane.stats.batches(), &mut p.batches, "batches");
    delta(lane.stats.steals_in.load(Ordering::Relaxed), &mut p.steals_in,
          "steals_in");
    delta(lane.stats.steals_out.load(Ordering::Relaxed), &mut p.steals_out,
          "steals_out");
    const STAGE_NAMES: [(&str, &str); 5] = [
        ("stage_queue_count", "stage_queue_sum_us"),
        ("stage_form_count", "stage_form_sum_us"),
        ("stage_forward_count", "stage_forward_sum_us"),
        ("stage_gemm_count", "stage_gemm_sum_us"),
        ("stage_decode_count", "stage_decode_sum_us"),
    ];
    for (i, (_, h)) in lane.stats.stages.stages().iter().enumerate() {
        let (count_name, sum_name) = STAGE_NAMES[i];
        let (last_count, last_sum) = &mut p.stages[i];
        let count = h.len() as u64;
        let sum = h.sum_us();
        hub.record(model, task, name_static(count_name).unwrap(),
                   count.saturating_sub(*last_count) as f64);
        hub.record(model, task, name_static(sum_name).unwrap(),
                   sum.saturating_sub(*last_sum) as f64);
        *last_count = count;
        *last_sum = sum;
    }
}

/// Re-derive lane-budget shares from the hub's trailing window: each
/// model's score is its arrival rate weighted up by observed mean queue
/// wait, blended with the current share and floored so cold lanes keep a
/// minimum budget.  Applied through the shared [`BudgetTable`], so the new
/// shares take effect on the live generation *and* survive hot reloads.
fn relearn_weights(registry: &Registry, hub: &SignalHub) {
    let ids: Vec<String> =
        registry.entries().iter().map(|e| e.id.clone()).collect();
    if ids.len() < 2 {
        return;
    }
    let window_s = LEARN_WINDOW.as_secs_f64();
    let mut total_rows = 0.0;
    let mut scores: Vec<(String, f64)> = Vec::with_capacity(ids.len());
    for id in &ids {
        let rows = hub.window_sum_model(id, "rows", LEARN_WINDOW);
        let wait_sum = hub.window_sum_model(id, "stage_queue_sum_us",
                                            LEARN_WINDOW);
        let wait_count = hub.window_sum_model(id, "stage_queue_count",
                                              LEARN_WINDOW);
        let mean_wait_ms = if wait_count > 0.0 {
            wait_sum / wait_count / 1000.0
        } else {
            0.0
        };
        total_rows += rows;
        let rate = rows / window_s;
        scores.push((id.clone(),
                     rate * (1.0 + mean_wait_ms / LEARN_WAIT_NORM_MS)));
    }
    if total_rows < LEARN_MIN_ROWS {
        return;
    }
    let score_sum: f64 = scores.iter().map(|(_, s)| s).sum();
    if score_sum <= 0.0 {
        return;
    }
    let table = &registry.lane_config().budgets;
    let mut shares: Vec<(String, f64)> = scores.iter()
        .map(|(id, score)| {
            let observed = score / score_sum;
            let current = table.budget(id).share;
            let blended = (1.0 - LEARN_ALPHA) * current
                + LEARN_ALPHA * observed;
            (id.clone(), blended.max(LEARN_MIN_SHARE))
        })
        .collect();
    let norm: f64 = shares.iter().map(|(_, s)| s).sum();
    for (_, s) in shares.iter_mut() {
        *s /= norm;
    }
    let max_shift = shares.iter()
        .map(|(id, s)| (s - table.budget(id).share).abs())
        .fold(0.0, f64::max);
    table.apply_shares(&shares);
    if max_shift > 0.02 {
        let detail: Vec<String> = shares.iter()
            .map(|(id, s)| {
                let b = table.budget(id);
                format!("{id}={:.2} ({} workers)", s, b.workers)
            })
            .collect();
        eprintln!("[samp] learn-weights re-apportioned lane budgets: {}",
                  detail.join(", "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latest_and_window_sum_see_recorded_samples() {
        let hub = SignalHub::new();
        assert_eq!(hub.latest("m", "t", "queue_depth"), None);
        hub.record("m", "t", "queue_depth", 3.0);
        hub.record("m", "t", "queue_depth", 7.0);
        assert_eq!(hub.latest("m", "t", "queue_depth"), Some(7.0));
        hub.record("m", "t", "rows", 4.0);
        hub.record("m", "t", "rows", 5.0);
        hub.record("m", "other", "rows", 2.0);
        assert_eq!(hub.window_sum("m", "t", "rows",
                                  Duration::from_secs(60)), 9.0);
        assert_eq!(hub.window_sum_model("m", "rows",
                                        Duration::from_secs(60)), 11.0);
    }

    #[test]
    fn ring_overwrites_oldest_beyond_capacity() {
        let s = Series::new(4);
        for i in 0..10u64 {
            s.push(i, i as f64);
        }
        assert_eq!(s.latest(), Some((9, 9.0)));
        let mut seen = Vec::new();
        s.for_each_since(0, |ts, _| seen.push(ts));
        assert_eq!(seen, vec![9, 8, 7, 6]);
    }

    #[test]
    fn window_cutoff_excludes_old_samples() {
        let s = Series::new(8);
        s.push(100, 1.0);
        s.push(200, 2.0);
        s.push(300, 4.0);
        let mut sum = 0.0;
        s.for_each_since(150, |_, v| sum += v);
        assert_eq!(sum, 6.0);
    }

    #[test]
    fn unknown_series_name_misses_cleanly() {
        let hub = SignalHub::new();
        hub.record("m", "t", "rows", 1.0);
        assert_eq!(hub.latest("m", "t", "not_a_series"), None);
        assert_eq!(hub.window_sum("m", "t", "not_a_series",
                                  Duration::from_secs(1)), 0.0);
    }
}
